"""Execute every ```python code block in README.md and docs/*.md so the
documentation cannot rot (CI runs this as the `docs` job).

Rules:
  * only fences tagged exactly ``python`` run; ``python no-run`` (or any
    other info string) is skipped, as are ``bash`` blocks;
  * blocks within one file share a namespace and run top to bottom, so a
    later snippet may reuse names (e.g. ``cd``) from an earlier one;
  * the repo's ``src/`` is put on ``sys.path`` — snippets are written
    exactly as a user would run them with ``PYTHONPATH=src``.

Usage:  python tools/check_doc_snippets.py [files...]
"""

from __future__ import annotations

import pathlib
import re
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```(\S*)[ \t]*(.*)$")


def extract(path: pathlib.Path):
    """Yield (lineno, code) for each runnable ```python block."""
    lines = path.read_text().splitlines()
    block, start, lang = None, 0, None
    for i, line in enumerate(lines, 1):
        m = FENCE.match(line.strip())
        if m and block is None:
            lang = (m.group(1), m.group(2).strip())
            block, start = [], i + 1
        elif m and block is not None:
            if lang == ("python", ""):
                yield start, "\n".join(block)
            block, lang = None, None
        elif block is not None:
            block.append(line)


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(ROOT))
    except ValueError:
        return str(path)


def main(argv=None):
    args = (argv if argv is not None else sys.argv[1:])
    files = ([pathlib.Path(a).resolve() for a in args] if args
             else [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))])
    sys.path.insert(0, str(ROOT / "src"))
    failures = 0
    for path in files:
        namespace: dict = {"__name__": f"docsnippet:{path.name}"}
        n = 0
        for lineno, code in extract(path):
            n += 1
            t0 = time.perf_counter()
            try:
                exec(compile(code, f"{path}:{lineno}", "exec"), namespace)
            except Exception as e:                  # noqa: BLE001
                failures += 1
                print(f"FAIL {_rel(path)}:{lineno}: "
                      f"{type(e).__name__}: {e}")
                continue
            print(f"ok   {_rel(path)}:{lineno} "
                  f"({time.perf_counter() - t0:.1f}s)")
        if not n:
            print(f"     {_rel(path)}: no python snippets")
    if failures:
        print(f"{failures} snippet(s) failed")
        return 1
    print("all doc snippets passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
