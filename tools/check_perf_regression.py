"""Gate scheduler-decision perf against the committed baseline.

Compares a fresh ``bench_sched_overhead`` JSON (written by
``benchmarks/scheduler_experiments.py --sched-json``) against the
committed ``BENCH_SCHED.json`` baseline and fails (exit 1) if per-tick
decision time regressed by more than ``--threshold`` (default 30%).

CI runners differ wildly in absolute speed, so the default gate compares
the *hardware-independent* ``speedup_vs_uncached`` ratios: both sides of
that ratio are measured in the same process on the same machine, so a
drop means the incremental path itself got slower relative to the
full-matrix rebuild — a real regression, not runner noise.  Pass
``--absolute`` to additionally gate the raw ``mean_tick_ms`` numbers
(useful when baseline and fresh run on pinned identical hardware).

The headline floors (cached >= 5x uncached at the 10k-job x 64-pool
backlog; hierarchical >= 4x flat at the region-sharded W=2048 fleet,
``regions_headline`` from ``bench_regions``; stale-profile violations
>= 5x online-loop violations under unmodeled drift, ``drift_headline``
from ``bench_drift_recovery``; energy-or-carbon-aware cut >= 20% at
<= +10% extra violations, ``energy_headline`` from ``bench_energy``;
controlled >= 1.5x uncontrolled goodput under 2x sustained overload at
a bounded p99 queue depth, ``overload_headline`` from
``bench_overload``) are always enforced when the fresh run contains
those configs.  ``speedup_hier_vs_flat`` entries are gated
exactly like ``speedup_vs_uncached`` — both sides measured in-process,
so the ratio is hardware-independent.  The drift ratio is not even a
timing: fixed seeds and a fixed degradation timeline make the
violation counts deterministic, so any drift at all is a code change.

``pallas-resident`` variant keys (and their ``-compiled`` twins) are
**parity-gated, ratio-tracked**: an ``assignments_match_cached: false``
in the fresh run fails the gate unconditionally, while their speed
ratios are printed (``trk``) but never floored — interpret-mode
wall-clock is an emulation artifact, and no compiled accelerator
baseline is committed yet.

Usage:  python tools/check_perf_regression.py BENCH_SCHED.json fresh.json
"""

from __future__ import annotations

import argparse
import json
import sys

HEADLINE_FLOOR = 5.0        # cached vs uncached at J=10k, W=64
REGIONS_FLOOR = 4.0         # hierarchical vs flat at W=2048, k>=16
DRIFT_FLOOR = 5.0           # stale vs online violations under drift
ENERGY_FLOOR = 0.20         # aware-vs-blind energy *or* carbon cut
ENERGY_VIOL_CEIL = 0.10     # allowed extra QoS violations, relative
OVERLOAD_FLOOR = 1.5        # controlled vs uncontrolled goodput

# the hardware-independent per-config ratios the gate watches
_SPEEDUPS = ("speedup_vs_uncached", "speedup_hier_vs_flat",
             "violation_ratio_stale_vs_online",
             "energy_reduction_vs_blind", "carbon_reduction_vs_blind",
             "goodput_ratio_controlled_vs_uncontrolled")


def _index(blob):
    return {(c["variant"], c["J"], c["W"], c.get("serving", "job"),
             c.get("regions", 0)): c
            for c in blob.get("configs", [])}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("baseline", help="committed BENCH_SCHED.json")
    p.add_argument("fresh", help="freshly measured bench JSON")
    p.add_argument("--threshold", type=float, default=0.30,
                   help="allowed relative regression (default 0.30)")
    p.add_argument("--absolute", action="store_true",
                   help="also gate raw mean_tick_ms (pinned hardware)")
    args = p.parse_args(argv)
    with open(args.baseline) as f:
        base = _index(json.load(f))
    with open(args.fresh) as f:
        fresh_blob = json.load(f)
    fresh = _index(fresh_blob)

    failures = []
    for key, fc in fresh.items():
        # the device-resident variants are parity-gated: a recorded
        # assignment divergence from the cached numpy path fails the
        # gate on its own, baseline or not
        if fc.get("assignments_match_cached") is False:
            print(f"FAIL {key}: assignments diverged from the cached "
                  f"numpy path")
            failures.append(
                f"{key}: assignments_match_cached is False — the "
                f"Pallas path lost bit-for-bit parity")
        # pallas-resident speed ratios are *tracked*, never floored:
        # interpret-mode wall-clock is an emulation artifact, and no
        # compiled accelerator baseline is committed yet
        tracked = key[0].startswith("pallas-resident")
        bc = base.get(key)
        if bc is None:
            print(f"note {key}: no baseline entry, skipping")
            continue
        for speed_key in _SPEEDUPS:
            b_speed = bc.get(speed_key)
            f_speed = fc.get(speed_key)
            if b_speed and f_speed:
                ratio = f_speed / b_speed
                if tracked:
                    print(f"trk  {key}: {speed_key} {b_speed:.2f}x -> "
                          f"{f_speed:.2f}x ({ratio:.2f} of baseline, "
                          f"ratio-tracked only)")
                    continue
                tag = "ok  " if ratio >= 1.0 - args.threshold else "FAIL"
                print(f"{tag} {key}: {speed_key} {b_speed:.2f}x -> "
                      f"{f_speed:.2f}x ({ratio:.2f} of baseline)")
                if ratio < 1.0 - args.threshold:
                    failures.append(
                        f"{key}: {speed_key} regressed to "
                        f"{ratio:.2f} of baseline (threshold "
                        f"{1.0 - args.threshold:.2f})")
        if args.absolute and not tracked:
            ratio = fc["mean_tick_ms"] / bc["mean_tick_ms"]
            tag = "ok  " if ratio <= 1.0 + args.threshold else "FAIL"
            print(f"{tag} {key}: mean_tick_ms {bc['mean_tick_ms']:.2f} "
                  f"-> {fc['mean_tick_ms']:.2f} ({ratio:.2f}x)")
            if ratio > 1.0 + args.threshold:
                failures.append(
                    f"{key}: mean_tick_ms regressed {ratio:.2f}x "
                    f"(threshold {1.0 + args.threshold:.2f}x)")
    head = fresh_blob.get("headline")
    if head:
        speed = head.get("speedup_cached_vs_uncached", 0.0)
        tag = "ok  " if speed >= HEADLINE_FLOOR else "FAIL"
        print(f"{tag} headline J={head.get('J')} W={head.get('W')}: "
              f"cached {speed:.2f}x uncached "
              f"(floor {HEADLINE_FLOOR:.0f}x)")
        if speed < HEADLINE_FLOOR:
            failures.append(
                f"headline cached-vs-uncached speedup {speed:.2f}x "
                f"below the {HEADLINE_FLOOR:.0f}x acceptance floor")
    rhead = fresh_blob.get("regions_headline")
    if rhead:
        speed = rhead.get("speedup_hier_vs_flat", 0.0)
        tag = "ok  " if speed >= REGIONS_FLOOR else "FAIL"
        print(f"{tag} regions_headline J={rhead.get('J')} "
              f"W={rhead.get('W')} k={rhead.get('regions')}: "
              f"hierarchical {speed:.2f}x flat "
              f"(floor {REGIONS_FLOOR:.0f}x)")
        if speed < REGIONS_FLOOR:
            failures.append(
                f"regions_headline hier-vs-flat speedup {speed:.2f}x "
                f"below the {REGIONS_FLOOR:.0f}x acceptance floor")
    dhead = fresh_blob.get("drift_headline")
    if dhead:
        ratio = dhead.get("violation_ratio_stale_vs_online", 0.0)
        tag = "ok  " if ratio >= DRIFT_FLOOR else "FAIL"
        print(f"{tag} drift_headline J={dhead.get('J')} "
              f"W={dhead.get('W')} factor={dhead.get('factor')}: "
              f"stale {ratio:.2f}x online violations "
              f"(floor {DRIFT_FLOOR:.0f}x)")
        if ratio < DRIFT_FLOOR:
            failures.append(
                f"drift_headline stale-vs-online violation ratio "
                f"{ratio:.2f}x below the {DRIFT_FLOOR:.0f}x "
                f"acceptance floor")
    ehead = fresh_blob.get("energy_headline")
    if ehead:
        # deterministic like the drift ratio: fixed seeds, no timing.
        # acceptance is energy OR carbon >= 20% cut, at <= +10% extra
        # QoS violations vs the energy-blind baseline.
        cut = max(ehead.get("energy_reduction", 0.0),
                  ehead.get("carbon_reduction", 0.0))
        over = ehead.get("violation_overhead", 0.0)
        ok = cut >= ENERGY_FLOOR and over <= ENERGY_VIOL_CEIL
        tag = "ok  " if ok else "FAIL"
        print(f"{tag} energy_headline J={ehead.get('J')} "
              f"W={ehead.get('W')}: best aware-vs-blind cut "
              f"{cut:.3f} (floor {ENERGY_FLOOR:.2f}), violation "
              f"overhead {over:+.3f} (ceiling "
              f"+{ENERGY_VIOL_CEIL:.2f})")
        if cut < ENERGY_FLOOR:
            failures.append(
                f"energy_headline aware-vs-blind cut {cut:.3f} below "
                f"the {ENERGY_FLOOR:.2f} acceptance floor")
        if over > ENERGY_VIOL_CEIL:
            failures.append(
                f"energy_headline violation overhead {over:+.3f} above "
                f"the +{ENERGY_VIOL_CEIL:.2f} ceiling")
    ohead = fresh_blob.get("overload_headline")
    if ohead:
        # deterministic like the drift ratio: fixed seeds and a fixed
        # fault timeline — goodput counts, not wall-clock.  acceptance
        # is controlled >= 1.5x uncontrolled goodput at a p99 queue
        # depth under the recorded bound.
        ratio = ohead.get("goodput_ratio_controlled_vs_uncontrolled", 0.0)
        p99 = ohead.get("queue_depth_p99_controlled", float("inf"))
        bound = ohead.get("queue_depth_bound", 0.0)
        ok = ratio >= OVERLOAD_FLOOR and p99 <= bound
        tag = "ok  " if ok else "FAIL"
        print(f"{tag} overload_headline J={ohead.get('J')} "
              f"W={ohead.get('W')}: controlled {ratio:.2f}x "
              f"uncontrolled goodput (floor {OVERLOAD_FLOOR:.1f}x), "
              f"depth p99 {p99:.0f} (bound {bound:.0f})")
        if ratio < OVERLOAD_FLOOR:
            failures.append(
                f"overload_headline controlled-vs-uncontrolled goodput "
                f"{ratio:.2f}x below the {OVERLOAD_FLOOR:.1f}x "
                f"acceptance floor")
        if p99 > bound:
            failures.append(
                f"overload_headline controlled p99 queue depth "
                f"{p99:.0f} above the {bound:.0f} bound")
    if failures:
        print("\nperf regression gate FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("perf regression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
