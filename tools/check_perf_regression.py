"""Gate scheduler-decision perf against the committed baseline.

Compares a fresh ``bench_sched_overhead`` JSON (written by
``benchmarks/scheduler_experiments.py --sched-json``) against the
committed ``BENCH_SCHED.json`` baseline and fails (exit 1) if per-tick
decision time regressed by more than ``--threshold`` (default 30%).

CI runners differ wildly in absolute speed, so the default gate compares
the *hardware-independent* ``speedup_vs_uncached`` ratios: both sides of
that ratio are measured in the same process on the same machine, so a
drop means the incremental path itself got slower relative to the
full-matrix rebuild — a real regression, not runner noise.  Pass
``--absolute`` to additionally gate the raw ``mean_tick_ms`` numbers
(useful when baseline and fresh run on pinned identical hardware).

The headline floor (cached >= 5x uncached at the 10k-job x 64-pool
backlog, the PR acceptance bar) is always enforced when the fresh run
contains that config.

Usage:  python tools/check_perf_regression.py BENCH_SCHED.json fresh.json
"""

from __future__ import annotations

import argparse
import json
import sys

HEADLINE_FLOOR = 5.0        # cached vs uncached at J=10k, W=64


def _index(blob):
    return {(c["variant"], c["J"], c["W"], c.get("serving", "job")): c
            for c in blob.get("configs", [])}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("baseline", help="committed BENCH_SCHED.json")
    p.add_argument("fresh", help="freshly measured bench JSON")
    p.add_argument("--threshold", type=float, default=0.30,
                   help="allowed relative regression (default 0.30)")
    p.add_argument("--absolute", action="store_true",
                   help="also gate raw mean_tick_ms (pinned hardware)")
    args = p.parse_args(argv)
    with open(args.baseline) as f:
        base = _index(json.load(f))
    with open(args.fresh) as f:
        fresh_blob = json.load(f)
    fresh = _index(fresh_blob)

    failures = []
    for key, fc in fresh.items():
        bc = base.get(key)
        if bc is None:
            print(f"note {key}: no baseline entry, skipping")
            continue
        b_speed = bc.get("speedup_vs_uncached")
        f_speed = fc.get("speedup_vs_uncached")
        if b_speed and f_speed:
            ratio = f_speed / b_speed
            tag = "ok  " if ratio >= 1.0 - args.threshold else "FAIL"
            print(f"{tag} {key}: speedup {b_speed:.2f}x -> "
                  f"{f_speed:.2f}x ({ratio:.2f} of baseline)")
            if ratio < 1.0 - args.threshold:
                failures.append(
                    f"{key}: speedup_vs_uncached regressed to "
                    f"{ratio:.2f} of baseline (threshold "
                    f"{1.0 - args.threshold:.2f})")
        if args.absolute:
            ratio = fc["mean_tick_ms"] / bc["mean_tick_ms"]
            tag = "ok  " if ratio <= 1.0 + args.threshold else "FAIL"
            print(f"{tag} {key}: mean_tick_ms {bc['mean_tick_ms']:.2f} "
                  f"-> {fc['mean_tick_ms']:.2f} ({ratio:.2f}x)")
            if ratio > 1.0 + args.threshold:
                failures.append(
                    f"{key}: mean_tick_ms regressed {ratio:.2f}x "
                    f"(threshold {1.0 + args.threshold:.2f}x)")
    head = fresh_blob.get("headline")
    if head:
        speed = head.get("speedup_cached_vs_uncached", 0.0)
        tag = "ok  " if speed >= HEADLINE_FLOOR else "FAIL"
        print(f"{tag} headline J={head.get('J')} W={head.get('W')}: "
              f"cached {speed:.2f}x uncached "
              f"(floor {HEADLINE_FLOOR:.0f}x)")
        if speed < HEADLINE_FLOOR:
            failures.append(
                f"headline cached-vs-uncached speedup {speed:.2f}x "
                f"below the {HEADLINE_FLOOR:.0f}x acceptance floor")
    if failures:
        print("\nperf regression gate FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    print("perf regression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
