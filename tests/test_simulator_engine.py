"""Event-heap simulator engine: exact seed-semantics equivalence against
the preserved tick-scanning loop, same-seed determinism, conservation
invariants at fleet scale, and the 10k-job x 64-pool MMPP acceptance run."""

import numpy as np
import pytest

from repro.core.baselines import RoundRobin, StrictRoundRobin
from repro.core.job import make_experiment
from repro.core.scheduler import SynergAI
from repro.core.simulator import FailureEvent, Simulator
from repro.core.simulator_legacy import LegacySimulator
from repro.core.slo_mael import SloMael
from repro.core.workers import synth_fleet
from repro.core.workload import scenario, synth_failures

POLICIES = [RoundRobin, StrictRoundRobin, SloMael, SynergAI]


def _key(results):
    # decision_s is wall-clock (non-deterministic); everything else is
    # simulated time and must match bit-for-bit
    return [(r.job.id, r.worker, r.config, r.start, r.end, r.waiting,
             r.exec_s, r.e2e, r.violated, r.excess, r.overhead_s,
             r.speculated) for r in results]


# ----------------------------------------------------------------------------
# seed-semantics equivalence (the legacy loop is the oracle)


@pytest.mark.parametrize("policy_cls", POLICIES)
@pytest.mark.parametrize("exp", [("DL", "FL"), ("DL", "FH"), ("DH", "FH")])
def test_event_heap_matches_seed_semantics(configdict, policy_cls, exp):
    jobs = make_experiment(configdict, *exp, seed=3)
    new = Simulator(configdict, policy_cls(), seed=3).run(jobs)
    old = LegacySimulator(configdict, policy_cls(), seed=3).run(jobs)
    assert _key(new) == _key(old)


def test_event_heap_matches_seed_with_failures_and_speculation(configdict):
    jobs = make_experiment(configdict, "DL", "FH", seed=2)
    kw = dict(speculative=True, straggler_prob=0.3, straggler_factor=5.0,
              failures=[FailureEvent("edge-large", 30.0, 200.0),
                        FailureEvent("cloud-pod", 80.0, 150.0)], seed=2)
    new = Simulator(configdict, SynergAI(), **kw).run(jobs)
    old = LegacySimulator(configdict, SynergAI(), **kw).run(jobs)
    assert _key(new) == _key(old)
    assert sorted(r.job.id for r in new) == sorted(j.id for j in jobs)


@pytest.mark.parametrize("seed", [7, 22, 31])
def test_event_heap_matches_seed_speculation_failure_interleavings(
        configdict, seed):
    """Regression: a failure that kills a *speculated* job invalidates its
    completion wake, but the original worker still frees at the backup's
    finish time — the heap must index that wake independently (seed 22
    exercised the divergent interleaving)."""
    rng = np.random.default_rng(seed)
    failures = [FailureEvent(w, float(rng.uniform(10, 300)),
                             float(rng.uniform(30, 200)))
                for w in ("cloud-pod", "edge-large", "edge-small")]
    jobs = make_experiment(configdict, "DH", "FH", seed=seed)
    kw = dict(speculative=True, straggler_prob=0.4, straggler_factor=6.0,
              failures=failures, seed=seed)
    new = Simulator(configdict, SynergAI(), **kw).run(jobs)
    old = LegacySimulator(configdict, SynergAI(), **kw).run(jobs)
    assert _key(new) == _key(old)


def test_event_heap_matches_seed_with_elastic_scaling(configdict):
    jobs = make_experiment(configdict, "DH", "FH", seed=4, intensity=12.0)
    kw = dict(elastic_max=3, elastic_threshold=4, seed=4)
    new = Simulator(configdict, SynergAI(), **kw).run(jobs)
    old = LegacySimulator(configdict, SynergAI(), **kw).run(jobs)
    assert _key(new) == _key(old)


def test_event_heap_matches_seed_on_synth_fleet(configdict):
    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "mmpp", n_jobs=300, fleet=fleet,
                    seed=5)
    failures = synth_failures(fleet, jobs[-1].arrival, mtbf_s=600.0,
                              mttr_s=60.0, seed=5)
    for P in (SynergAI, RoundRobin):
        new = Simulator(configdict, P(), fleet=fleet, failures=failures,
                        seed=5).run(jobs)
        old = LegacySimulator(configdict, P(), fleet=fleet,
                              failures=failures, seed=5).run(jobs)
        assert _key(new) == _key(old), P.name


# ----------------------------------------------------------------------------
# determinism


def test_same_seed_same_results(configdict):
    fleet = synth_fleet(2, 2, 2)
    jobs = scenario(configdict, "multi-tenant", n_jobs=400,
                    fleet=fleet, seed=7)
    a = Simulator(configdict, SynergAI(), fleet=fleet, seed=7).run(jobs)
    b = Simulator(configdict, SynergAI(), fleet=fleet, seed=7).run(jobs)
    assert _key(a) == _key(b)


def test_different_seed_different_noise(configdict):
    jobs = make_experiment(configdict, "DL", "FL", seed=1)
    a = Simulator(configdict, SynergAI(), seed=1).run(jobs)
    b = Simulator(configdict, SynergAI(), seed=2).run(jobs)
    assert _key(a) != _key(b)   # exec noise differs -> schedules differ
    assert sorted(r.job.id for r in a) == sorted(r.job.id for r in b)


# ----------------------------------------------------------------------------
# conservation invariants at fleet scale


def test_fleet_scale_conservation(configdict):
    """Every job completes exactly once; no worker is double-booked."""
    fleet = synth_fleet(4, 6, 6)
    jobs = scenario(configdict, "mmpp", n_jobs=2000, fleet=fleet,
                    utilization=0.8, seed=1)
    res = Simulator(configdict, SynergAI(), fleet=fleet, seed=1).run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    by_worker = {}
    for r in res:
        assert r.start >= r.job.arrival - 1e-9
        assert np.isclose(r.e2e, r.end - r.job.arrival)
        assert r.exec_s > 0 and r.excess >= 0
        by_worker.setdefault(r.worker, []).append((r.start, r.end))
    for w, spans in by_worker.items():
        spans.sort()
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert s2 >= e1 - 1e-6, f"double-booked {w}"


def test_fleet_failures_requeue_and_complete(configdict):
    fleet = synth_fleet(2, 4, 4)
    jobs = scenario(configdict, "flash", n_jobs=600, fleet=fleet,
                    seed=3)
    failures = synth_failures(fleet, jobs[-1].arrival, mtbf_s=400.0,
                              mttr_s=80.0, seed=3)
    assert failures, "trace should contain failures"
    res = Simulator(configdict, SynergAI(), fleet=fleet, failures=failures,
                    seed=3).run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    # every surviving record either completed before its worker's failure
    # or started after the recovery — anything else was killed and re-run
    for r in res:
        for f in failures:
            if f.worker == r.worker:
                assert (r.end <= f.at + 1e-6
                        or r.start >= f.at + f.duration - 1e-6), (r, f)


@pytest.mark.slow
def test_10k_by_64_pool_mmpp_all_policies(configdict):
    """Acceptance: the 10k-job, 64-pool MMPP scenario runs end-to-end under
    SynergAI and all baselines without the livelock guard tripping."""
    from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                      MostRecentlyUsed)
    fleet = synth_fleet(8, 28, 28)
    assert len(fleet) == 64
    jobs = scenario(configdict, "mmpp", n_jobs=10_000, fleet=fleet,
                    utilization=0.8, seed=0)
    viol = {}
    for P in [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
              MostRecentlyUsed, BestEffort, SloMael, SynergAI]:
        res = Simulator(configdict, P(), fleet=fleet, seed=0).run(jobs)
        assert len(res) == 10_000, P.name
        viol[P.name] = sum(r.violated for r in res)
    assert viol["SynergAI"] <= min(viol.values())
