"""Device-resident score cache: differential invalidation + fused-tick
parity + transfer accounting (``repro.core.devicecache``).

The acceptance anchors of the device-residency PR:

* a differential harness drives random interleavings of arrivals,
  placements, failures, elastic clones and profile refreshes through
  ``ScoreCache`` and ``DeviceScoreCache`` simultaneously and asserts
  row-for-row equality after every step — the device mirror inherits
  every invalidation rule the host cache established, with exactly one
  sanctioned divergence (a pure ``fail_gen`` bump *masks* on the device
  path instead of flushing, because failure state never enters the
  rows);
* ``SynergAI(score_fn=make_pallas_score_fn(device_cache=True))`` is
  bit-for-bit the cached numpy scheduler in interpret mode — the PR 2/4
  golden digests reproduce in both serving modes, flat and
  ``RegionView``-sliced hierarchical;
* steady-state host->device traffic is O(churn * W), not O(J * W).
"""

import dataclasses
import hashlib

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.devicecache import DeviceScoreCache
from repro.core.estimator import (new_profile_id, profile_overlay,
                                  profile_gen)
from repro.core.hierarchy import HierarchicalSynergAI
from repro.core.pallas_scoring import make_pallas_score_fn
from repro.core.scheduler import SynergAI
from repro.core.scorecache import ScoreCache
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import scenario, synth_failures

from test_streaming_qos import PR2_GOLDEN, STREAM_GOLDEN
from test_trace_replay import REPLAY_GOLDEN_DIGEST

_APPROX = 1e-9


def _device_fn():
    return make_pallas_score_fn(device_cache=True)


def _result_key(results):
    return [(r.job.id, r.worker, r.config, r.start, r.end, r.waiting,
             r.exec_s, r.e2e, r.violated, r.excess, r.ttft, r.tpot)
            for r in results]


# ---------------------------------------------------------------------------
# differential invalidation harness: host cache vs device mirror through
# random interleavings of every invalidation-relevant event


def _assert_mirrors_equal(hc, dc, cd, queue, cluster):
    """Sync both caches on the same state; every view must agree exactly
    (host mirrors are the same f64 computation) and every device row
    must be the f32 cast of its host row."""
    hs = hc.sync(cd, queue, cluster)
    ds = dc.sync(cd, queue, cluster)
    np.testing.assert_array_equal(hc.t_matrix(hs), dc.t_matrix(ds))
    np.testing.assert_array_equal(hc.min_estimate(hs),
                                  dc.min_estimate(ds))
    np.testing.assert_array_equal(hc.t_remaining(hs, 0.0),
                                  dc.t_remaining(ds, 0.0))
    if len(queue):
        W = dc._W
        pool = np.asarray(dc._dt)
        np.testing.assert_array_equal(
            pool[ds, :W], dc._t[ds].astype(np.float32))
        if dc._have_phase:
            pre, dec = dc.phase_matrices(ds)
            np.testing.assert_array_equal(
                np.asarray(dc._dpre)[ds, :W], pre.astype(np.float32))
            np.testing.assert_array_equal(
                np.asarray(dc._ddec)[ds, :W], dec.astype(np.float32))
        # padded device columns stay inf (self-masking in the kernel)
        assert np.isinf(pool[ds, W:]).all()


def _drive(configdict, ops, seed=13):
    """Apply an op sequence to one live cluster while a plain ScoreCache
    and a DeviceScoreCache track the same queue."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    sim = Simulator(cd, SynergAI(), fleet=fleet)
    cluster = sim.cluster
    pid = new_profile_id()
    hc = ScoreCache(profile=pid)
    dc = DeviceScoreCache(profile=pid)
    pool = list(scenario(cd, "poisson", n_jobs=160, fleet=fleet,
                         seed=seed))
    queue = [pool.pop(0) for _ in range(12)]
    engines = sorted({j.engine for j in pool})
    names = list(cluster.arrays.names)
    now, clones = 0.0, 0
    _assert_mirrors_equal(hc, dc, cd, queue, cluster)
    for step, op in enumerate(ops):
        now += 1.0
        if op == "arrive":
            queue.extend(pool.pop(0) for _ in range(min(3, len(pool))))
        elif op == "place":
            if queue:
                queue.pop(step % len(queue))
        elif op == "fail":
            cluster.workers[names[step % len(names)]].failed_until = \
                now + 5.0
        elif op == "clone":
            clones += 1
            base = cluster.workers["cloud-pod"].pool
            clone = dataclasses.replace(
                base, name=f"cloud-pod__clone{clones}")
            cluster.workers[clone.name] = cluster._make_worker(clone)
            names = list(cluster.arrays.names)
        elif op == "profile":
            profile_overlay(cd, pid).apply(
                {engines[step % len(engines)]:
                 {names[0]: 0.5 + 0.1 * (step % 4)}})
        _assert_mirrors_equal(hc, dc, cd, queue, cluster)
    # sanctioned divergence only: the device path converts pure fail_gen
    # flushes into masks, so it never flushes more often than the host
    assert dc.flushes <= hc.flushes
    assert dc.col_extends == hc.col_extends
    assert dc.profile_reclaims == hc.profile_reclaims
    return hc, dc


_OPS = ("arrive", "place", "fail", "clone", "profile")


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_differential_interleavings_seeded(configdict, seed):
    rng = np.random.default_rng(seed)
    ops = [
        _OPS[i] for i in rng.integers(0, len(_OPS), size=24)]
    _drive(configdict, ops, seed=13 + seed)


@given(ops=st.lists(st.sampled_from(_OPS), min_size=1, max_size=24))
@settings(max_examples=25, deadline=None)
def test_differential_interleavings_property(ops):
    from repro.core.offline import characterize
    _drive(characterize(), ops)


def test_fail_gen_masks_instead_of_flushing(configdict):
    """A pure failure-generation bump keeps every device row resident:
    the host rule flushes conservatively, the mirror masks — failure
    state never enters the Eq. 2 rows, so the kept rows are exactly what
    a recompute would produce (asserted row-for-row by the harness)."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    cluster = Simulator(cd, SynergAI(), fleet=fleet).cluster
    jobs = list(scenario(cd, "poisson", n_jobs=40, fleet=fleet, seed=5))
    dc = DeviceScoreCache()
    dc.sync(cd, jobs, cluster)
    rows0, up0 = dict(dc._slot), dc.rows_uploaded
    cluster.workers["edge-large"].failed_until = 50.0
    hc = ScoreCache()
    hc.sync(cd, jobs, cluster)      # fresh host cache, post-failure rows
    _assert_mirrors_equal(hc, dc, cd, jobs, cluster)
    assert dc.fail_masks == 1
    assert dc.flushes == 0
    assert dc._slot == rows0               # every slot survived
    assert dc.rows_uploaded == up0         # zero re-upload


def test_elastic_clone_extends_device_columns(configdict):
    """Appending a pool widens the device pools in place: the old block
    moves device-to-device and only the new columns upload."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    cluster = Simulator(cd, SynergAI(), fleet=fleet).cluster
    jobs = list(scenario(cd, "poisson", n_jobs=30, fleet=fleet, seed=9))
    dc = DeviceScoreCache()
    slots = dc.sync(cd, jobs, cluster)
    bytes0 = dc.bytes_to_device
    base = cluster.workers["cloud-pod"].pool
    clone = dataclasses.replace(base, name="cloud-pod__clone1")
    cluster.workers[clone.name] = cluster._make_worker(clone)
    slots = dc.sync(cd, jobs, cluster)
    assert dc.col_extends == 1 and dc.flushes == 0
    W = dc._W
    np.testing.assert_array_equal(
        np.asarray(dc._dt)[slots, :W], dc._t[slots].astype(np.float32))
    # one new column for the live rows, not a row re-upload
    assert dc.bytes_to_device - bytes0 < len(jobs) * 16 * 4
    # retiring the clone is a non-append membership change: full flush,
    # device pools drop and rebuild on the next sync
    del cluster.workers[clone.name]
    slots = dc.sync(cd, jobs, cluster)
    assert dc.flushes == 1
    np.testing.assert_array_equal(
        np.asarray(dc._dt)[slots, :dc._W],
        dc._t[slots].astype(np.float32))


def test_profile_refresh_reships_only_touched_rows(configdict):
    """A profile overlay refresh reclaims exactly the refreshed engine's
    slots (the PR 7 rule); only those rows travel back to the device."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    cluster = Simulator(cd, SynergAI(), fleet=fleet).cluster
    jobs = list(scenario(cd, "poisson", n_jobs=60, fleet=fleet, seed=6))
    pid = new_profile_id()
    dc = DeviceScoreCache(profile=pid)
    dc.sync(cd, jobs, cluster)
    up0 = dc.rows_uploaded
    target = sorted({j.engine for j in jobs})[0]
    profile_overlay(cd, pid).apply({target: {fleet[0].name: 0.5}})
    slots = dc.sync(cd, jobs, cluster)
    touched = sum(j.engine == target for j in jobs)
    assert dc.profile_reclaims == touched
    assert dc.rows_uploaded - up0 == touched
    np.testing.assert_array_equal(
        np.asarray(dc._dt)[slots, :dc._W],
        dc._t[slots].astype(np.float32))


# ---------------------------------------------------------------------------
# drop-in scheduling parity: device path == cached numpy path, bit-for-bit


@pytest.mark.parametrize("serving,streaming,disaggregate",
                         [("job", None, False),
                          ("batched", None, False),
                          ("batched", (2.0, 2.5), False),
                          ("batched", (2.0, 2.5), True)])
def test_device_drop_in_matches_numpy(configdict, serving, streaming,
                                      disaggregate):
    cd = configdict
    fleet = synth_fleet(1, 2, 2, disaggregate=disaggregate)
    jobs = scenario(cd, "mmpp", n_jobs=60, fleet=fleet, seed=7,
                    utilization=1.2, serving=serving,
                    streaming=streaming)
    run = lambda pol: _result_key(
        Simulator(cd, pol, fleet=fleet, seed=7, serving=serving)
        .run(jobs))
    assert run(SynergAI(score_fn=_device_fn())) == run(SynergAI())


@pytest.mark.parametrize("serving", ["job", "batched"])
def test_device_drop_in_under_failures_elastic_energy(configdict,
                                                      serving):
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, "mmpp", n_jobs=120, fleet=fleet, seed=3,
                    utilization=1.2, serving=serving)
    span = jobs[-1].arrival
    kw = dict(fleet=fleet, seed=3, serving=serving,
              failures=synth_failures(fleet, span, mtbf_s=span / 2,
                                      mttr_s=span / 6, seed=5),
              elastic_max=3, elastic_threshold=4)
    run = lambda pol: _result_key(Simulator(cd, pol, **kw).run(jobs))
    assert run(SynergAI(score_fn=_device_fn(), energy_weight=0.5)) == \
        run(SynergAI(energy_weight=0.5))


def test_pr2_golden_reproduced_device(configdict):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, SynergAI(score_fn=_device_fn()),
                     fleet=fleet, seed=7, serving="batched").run(jobs)}
    for jid, worker, start, end, exec_s, violated in PR2_GOLDEN:
        r = res[jid]
        assert r.worker == worker
        assert r.start == pytest.approx(start, rel=_APPROX)
        assert r.end == pytest.approx(end, rel=_APPROX)
        assert r.exec_s == pytest.approx(exec_s, rel=_APPROX)
        assert r.violated == violated


def test_stream_golden_reproduced_device(configdict):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "poisson", n_jobs=12, fleet=fleet,
                    seed=11, utilization=1.0, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, SynergAI(score_fn=_device_fn()),
                     fleet=fleet, seed=11, serving="batched").run(jobs)}
    for jid, ttft, tpot in STREAM_GOLDEN:
        assert res[jid].ttft == pytest.approx(ttft, rel=_APPROX), jid
        assert res[jid].tpot == pytest.approx(tpot, rel=_APPROX), jid


def test_replay_golden_digest_device_flat_and_hier(configdict,
                                                   tmp_path):
    from repro.core.workload import save_trace, replay
    jobs = scenario(configdict, "mmpp", n_jobs=40,
                    fleet=synth_fleet(1, 2, 2), seed=7, utilization=1.2)
    path = tmp_path / "golden.jsonl"
    save_trace(path, jobs)

    def digest(pol, fleet):
        res = Simulator(configdict, pol, fleet=fleet,
                        seed=7).run(replay(str(path)))
        canon = "\n".join(
            f"{r.job.id},{r.worker},{r.config},{r.start!r},{r.end!r},"
            f"{r.ttft!r},{r.tpot!r},{int(r.violated)}"
            for r in sorted(res, key=lambda r: r.job.id))
        return hashlib.sha256(canon.encode()).hexdigest()

    assert digest(SynergAI(score_fn=_device_fn()),
                  synth_fleet(1, 2, 2)) == REPLAY_GOLDEN_DIGEST
    assert digest(HierarchicalSynergAI(score_fn=_device_fn()),
                  synth_fleet(1, 2, 2, regions=1)) == \
        REPLAY_GOLDEN_DIGEST


@pytest.mark.parametrize("regions", [2, 3])
def test_hierarchical_region_sliced_device(configdict, regions):
    """Each region core carries its own DeviceScoreCache over the
    RegionView slice; the schedule matches the numpy hierarchy."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2, regions=regions)
    jobs = scenario(cd, "mmpp", n_jobs=80, fleet=fleet, seed=5,
                    utilization=1.1, serving="batched")
    pol = HierarchicalSynergAI(score_fn=_device_fn())
    got = _result_key(Simulator(cd, pol, fleet=fleet, seed=5,
                                serving="batched").run(jobs))
    want = _result_key(Simulator(cd, HierarchicalSynergAI(),
                                 fleet=fleet, seed=5,
                                 serving="batched").run(jobs))
    assert got == want
    assert pol._subs
    for sub in pol._subs.values():
        assert isinstance(sub.cache, DeviceScoreCache)
        assert sub.cache.rows_uploaded > 0


# ---------------------------------------------------------------------------
# transfer accounting: O(churn * W) per steady tick, not O(J * W)


def test_steady_tick_transfer_is_o_churn_w(configdict):
    cd = configdict
    fleet = synth_fleet(2, 4, 4)
    cluster = Simulator(cd, SynergAI(), fleet=fleet).cluster
    jobs = list(scenario(cd, "poisson", n_jobs=512, fleet=fleet,
                         seed=21))
    pol = SynergAI(score_fn=_device_fn())
    queue = list(jobs[:480])
    spare = list(jobs[480:])
    pol.schedule(0.0, queue, cluster)    # cold tick: every row uploads
    dc = pol.cache
    assert dc.rows_uploaded == len(queue)
    full_matrix = len(queue) * dc._d_Wp * 4    # one [J, W] f32 re-upload
    # steady ticks: no arrivals -> zero matrix rows travel, only the
    # O(J + W) per-tick vectors
    b0, u0 = dc.bytes_to_device, dc.rows_uploaded
    for i in range(5):
        pol.schedule(1.0 + i, queue, cluster)
    assert dc.rows_uploaded == u0
    per_tick = (dc.bytes_to_device - b0) / 5
    assert per_tick < 0.25 * full_matrix
    # churn tick: exactly the arrivals' rows ship
    churn = 16
    queue.extend(spare[:churn])
    b1, u1 = dc.bytes_to_device, dc.rows_uploaded
    pol.schedule(10.0, queue, cluster)
    assert dc.rows_uploaded - u1 == churn
    assert dc.bytes_to_device - b1 < per_tick + 4 * churn * dc._d_Wp * 8
    assert dc.flushes == 0


def test_device_counters_over_full_run(configdict):
    """End-to-end: a 120-job run uploads each row once and never
    flushes; per-tick traffic stays far below a full-matrix ship."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, "mmpp", n_jobs=120, fleet=fleet, seed=3,
                    utilization=1.2)
    pol = SynergAI(score_fn=_device_fn())
    Simulator(cd, pol, fleet=fleet, seed=3).run(jobs)
    dc = pol.cache
    assert dc.flushes == 0
    assert dc.rows_uploaded == 120
    assert dc.ticks >= 100
    full_matrix_per_tick = 120 * dc._d_Wp * 4
    assert dc.bytes_to_device / dc.ticks < 0.5 * full_matrix_per_tick
