"""Serving engine integration: batched generation through the public API."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.serving import sampling
from repro.serving.engine import InferenceEngine


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-1.6b", "hymba-1.5b"])
def test_engine_generate_deterministic(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_len=48, sampler=sampling.greedy)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    out1 = eng.generate({"tokens": toks}, n_tokens=6)
    eng2 = InferenceEngine(model, params, max_len=48,
                           sampler=sampling.greedy)
    out2 = eng2.generate({"tokens": toks}, n_tokens=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert eng.stats.decoded_tokens == 12
    assert eng.stats.prefill_tokens == 32


def test_engine_generate_matches_stepwise_prefill():
    """Token t+1 from generate() equals argmax of a fresh prefill over the
    prompt + generated prefix (greedy consistency)."""
    cfg = reduced(get_config("h2o-danube-1.8b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_len=64)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)
    out = eng.generate({"tokens": toks}, n_tokens=4)
    seq = jnp.concatenate([toks, out[:, :3]], axis=1)
    logits, _ = model.prefill(params, {"tokens": seq})
    expect = jnp.argmax(logits, axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 3]), np.asarray(expect))


def test_sampling_top_k_within_support():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 100))
    for _ in range(3):
        key, sub = jax.random.split(key)
        tok = sampling.top_k(logits, sub, k=5)
        top5 = jnp.argsort(logits, axis=-1)[:, -5:]
        for b in range(4):
            assert int(tok[b]) in np.asarray(top5[b])
