"""Dry-run integration: one real cell lowered+compiled on the production
mesh in a subprocess (the 512-device override must stay process-local),
plus unit tests of the sharding rule system on a small mesh."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_dryrun_single_cell_compiles(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "h2o-danube-1.8b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.load(open(tmp_path / "h2o-danube-1.8b__decode_32k__single.json"))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["jaxpr_flops_global"] > 0
    assert rec["memory"]["temp_bytes"] is not None


def test_sharding_rules_divisibility_fallback():
    """GQA kv heads that don't divide the model axis must replicate, not
    fail; batch=1 must not shard over data."""
    os.environ.setdefault("XLA_FLAGS", "")
    import jax
    import jax.numpy as jnp

    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_mesh

    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run via subprocess dryrun instead)")
    mesh = make_mesh((1, 2), ("data", "model"))
    params = {"groups": [{"attn": {
        "wq": jnp.zeros((4, 64, 8, 16)),   # heads=8 divisible by 2
        "wk": jnp.zeros((4, 64, 3, 16)),   # kv=3 NOT divisible
    }}]}
    specs = sh.param_pspecs(params, mesh)
    wq = specs["groups"][0]["attn"]["wq"]
    wk = specs["groups"][0]["attn"]["wk"]
    assert "model" in tuple(wq)
    assert "model" not in tuple(wk)


def test_cache_pspecs_prefers_largest_divisible_dim():
    import jax.numpy as jnp

    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_mesh
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")
    mesh = make_mesh((1, 2), ("data", "model"))
    cache = [{"k": jnp.zeros((4, 2, 64, 3, 16))}]   # [n, B, S, K, hd]
    specs = sh.cache_pspecs(cache, mesh)
    spec = tuple(specs[0]["k"])
    assert "model" in spec  # S=64 sharded
    assert spec.index("model") == 2
