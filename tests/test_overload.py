"""Overload control & failure-domain hardening (docs/robustness.md):
retry budgets with exponential backoff, client abandonment, deadline-
aware load shedding + admission backpressure, WAN partition injection,
flapping failure traces, outage parking (the infinite-requeue fix),
elastic scale-down hysteresis, the terminal-outcome taxonomy with
goodput, trace round-trip of the new job fields, and the bench_overload
smoke schema.  Everything defaults off: the first test pins the
controller-free schedule bit-for-bit against the feature-bearing build.
"""

import dataclasses

import numpy as np
import pytest
from conftest import given, settings, st
from test_trace_replay import _result_key

from repro.core.hierarchy import HierarchicalSynergAI
from repro.core.job import Job, Request
from repro.core.metrics import OUTCOMES, outcome_of, summarize
from repro.core.overload import OverloadController
from repro.core.scheduler import SynergAI
from repro.core.simulator import (FailureEvent, JobResult,
                                  LinkFailureEvent, RetryEvent, Simulator)
from repro.core.workers import synth_fleet
from repro.core.workload import (load_trace, regional_scenario, save_trace,
                                 scenario, synth_failures)

ENGINE = "gemma-2b/bf16"


# ---------------------------------------------------------------------------
# defaults-off equivalence


def test_inert_controller_is_bitforbit(configdict):
    """A controller that never sheds (shed_doomed=False, no cap) leaves
    the schedule bit-for-bit identical to no controller at all."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=60, fleet=fleet, seed=7,
                    utilization=1.2)
    plain = Simulator(configdict, SynergAI(), fleet=fleet,
                      seed=7).run(list(jobs))
    jobs2 = scenario(configdict, "mmpp", n_jobs=60, fleet=fleet, seed=7,
                     utilization=1.2)
    ctrl = OverloadController(shed_doomed=False)
    wired = Simulator(configdict, SynergAI(overload=ctrl), fleet=fleet,
                      seed=7).run(list(jobs2))
    assert _result_key(plain) == _result_key(wired)
    assert all(r.outcome == "" for r in wired)
    assert ctrl.shed_doom_total == 0 and ctrl.shed_backpressure_total == 0


def test_retry_knobs_off_are_bitforbit(configdict):
    """retry_budget=None + no patience reproduces the historical failure
    requeue stream exactly (same RNG draw order, same results)."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=60, fleet=fleet, seed=3)
    span = jobs[-1].arrival
    fails = synth_failures(fleet, span, mtbf_s=span / 2, mttr_s=span / 8,
                           seed=5)
    a = Simulator(configdict, SynergAI(), fleet=fleet, failures=fails,
                  seed=3).run(list(jobs))
    jobs2 = scenario(configdict, "mmpp", n_jobs=60, fleet=fleet, seed=3)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, failures=fails,
                    seed=3, elastic_cooldown_s=0.0)
    b = sim.run(list(jobs2))
    assert _result_key(a) == _result_key(b)
    assert sim.retry_events == [] and all(r.outcome == "" for r in b)


# ---------------------------------------------------------------------------
# retry budgets + exponential backoff


def test_backoff_doubles_and_budget_exhausts(configdict):
    """Each budget-consuming retry waits retry_base_s * 2^attempt (exact
    with jitter off); exhaustion is terminal outcome='failed'."""
    fleet = synth_fleet(1, 1, 1)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, retry_budget=3,
                    retry_base_s=2.0, retry_jitter=0.0)
    sim._results = []
    job = Job(0, ENGINE, 500, 60.0, 0.0)
    q = []
    sim._requeue_failed(job, 100.0, q)
    sim._requeue_failed(job, 110.0, q)
    sim._requeue_failed(job, 120.0, q)
    assert sim.retry_events == [RetryEvent(0, 102.0, 1),
                                RetryEvent(0, 114.0, 2),
                                RetryEvent(0, 128.0, 3)]
    assert not q and job.id in sim._parked
    # fourth kill: budget (3) exhausted -> terminal failure
    sim._requeue_failed(job, 130.0, q)
    assert len(sim._results) == 1
    r = sim._results[0]
    assert r.outcome == "failed" and r.end == 130.0 and r.worker == ""
    assert outcome_of(r) == "failed"


def test_backoff_jitter_bounded_by_knob(configdict):
    fleet = synth_fleet(1, 1, 1)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, retry_budget=5,
                    retry_base_s=2.0, retry_jitter=0.5)
    sim._results = []
    for i in range(4):
        sim._requeue_failed(Job(i, ENGINE, 500, 60.0, 0.0), 0.0, [])
    for ev in sim.retry_events:         # all attempt 1: delay = 2 * u
        assert 2.0 <= ev.at <= 3.0

    # per-job budget overrides the simulator-wide budget
    strict = Job(9, ENGINE, 500, 60.0, 0.0, retry_budget=0)
    sim._requeue_failed(strict, 50.0, [])
    assert sim._results and sim._results[-1].outcome == "failed"


def test_killed_job_retries_through_flap_or_fails(configdict):
    """End-to-end: a retry budget under flapping failures yields only
    terminal outcomes — completed/violated after surviving retries, or
    'failed' past the budget; nothing is lost or duplicated."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=80, fleet=fleet, seed=3)
    span = jobs[-1].arrival
    fails = synth_failures(fleet, span, mtbf_s=span / 4, mttr_s=span / 6,
                           seed=7, flap=3)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, failures=fails,
                    seed=3, retry_budget=2, retry_base_s=1.0)
    res = sim.run(jobs)
    assert len(res) == 80
    assert sorted(r.job.id for r in res) == list(range(80))
    assert {outcome_of(r) for r in res} <= set(OUTCOMES)
    assert sim.retry_events        # the flap actually exercised retries
    assert all(ev.attempt <= 2 for ev in sim.retry_events
               if ev.attempt > 0)


# ---------------------------------------------------------------------------
# outage parking (the infinite-requeue hot loop)


def test_full_outage_parks_instead_of_hot_looping(configdict):
    """During a full-fleet outage, queued jobs park on the backoff heap:
    the loop stops burning a tick per second of outage.  The tick count
    is pinned well below the outage length; the no-budget run (the
    historical hot loop) scans through it."""
    fleet = synth_fleet(1, 1, 1)
    outage = [FailureEvent(w.name, 5.0, 2_000.0) for w in fleet]
    jobs = [Job(i, ENGINE, 500, 1e6, float(i)) for i in range(4)]

    hot = Simulator(configdict, SynergAI(), fleet=fleet, failures=outage,
                    seed=0)
    res_hot = hot.run([dataclasses.replace(j) for j in jobs])
    parked = Simulator(configdict, SynergAI(), fleet=fleet,
                       failures=outage, seed=0, retry_budget=8)
    res_parked = parked.run([dataclasses.replace(j) for j in jobs])

    assert len(res_hot) == len(res_parked) == 4
    assert all(r.outcome == "" for r in res_parked)
    assert hot.loop_iters > 1_000          # one scan per tick of outage
    assert parked.loop_iters < 100         # O(1) wakes per parked job
    # the park targeted the outage end, not a backoff-sized nap
    assert any(ev.at >= 2_000.0 for ev in parked.retry_events)


# ---------------------------------------------------------------------------
# client abandonment


def test_queued_job_abandons_at_patience(configdict):
    fleet = synth_fleet(1, 0, 0)
    long_j = Job(0, ENGINE, 20_000, 1e6, 0.0)
    waiter = Job(1, ENGINE, 500, 1e6, 1.0, patience=3.5)
    pol = SynergAI()
    res = Simulator(configdict, pol, fleet=fleet,
                    seed=0).run([long_j, waiter])
    by = {r.job.id: r for r in res}
    assert by[0].outcome == "" and by[0].worker == "cloud-pod"
    r = by[1]
    assert r.outcome == "abandoned" and outcome_of(r) == "abandoned"
    assert r.worker == "" and not r.violated
    assert r.end == pytest.approx(1.0 + 3.5)
    assert r.waiting == pytest.approx(3.5) and r.e2e == r.waiting
    # no stale score-cache row survives the abandonment
    assert pol.cache is None or 1 not in pol.cache._slot


def test_batched_member_abandons_only_before_first_token(configdict):
    """A batched member whose client hangs up mid-prefill leaves the
    batch and counts zero tokens; one already streaming is committed
    and completes.  Token totals cover exactly the served members."""
    fleet = synth_fleet(1, 0, 0)
    stream = Job(0, ENGINE, 500, 1e6, 0.0, request=Request(200, 50_000))
    # huge prompt: still prefilling when patience expires at t=2.0
    mid_prefill = Job(1, ENGINE, 500, 1e6, 0.5, patience=1.5,
                      request=Request(4_000_000, 100))
    sim = Simulator(configdict, SynergAI(), fleet=fleet,
                    serving="batched", seed=0)
    res = sim.run([stream, mid_prefill])
    by = {r.job.id: r for r in res}
    assert by[0].outcome == "" and not by[0].violated
    assert by[1].outcome == "abandoned"
    assert by[1].end == pytest.approx(0.5 + 1.5)
    ws = sim.cluster.workers["cloud-pod"]
    assert ws.abandoned == 1
    # exact token conservation: only the finished member's tokens count
    assert ws.prefill_tokens == 200 and ws.decoded_tokens == 50_000


def test_committed_batched_member_never_abandons(configdict):
    """Patience expiring after the first token no longer abandons — the
    client is already streaming."""
    fleet = synth_fleet(1, 0, 0)
    job = Job(0, ENGINE, 500, 1e6, 0.0, patience=5.0,
              request=Request(100, 500_000))  # tiny prefill, long decode
    sim = Simulator(configdict, SynergAI(), fleet=fleet,
                    serving="batched", seed=0)
    res = sim.run([job])
    assert res[0].outcome == "" and res[0].end > 5.0
    assert sim.cluster.workers["cloud-pod"].abandoned == 0


def test_scenario_patience_stamps_jobs(configdict):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "poisson", n_jobs=20, fleet=fleet,
                    seed=0, patience=2.0)
    assert all(j.patience == pytest.approx(2.0 * j.t_qos) for j in jobs)
    plain = scenario(configdict, "poisson", n_jobs=20, fleet=fleet,
                     seed=0)
    assert all(j.patience is None for j in plain)
    # patience doesn't perturb the sampled trace itself
    assert [(j.arrival, j.engine, j.t_qos) for j in jobs] == \
           [(j.arrival, j.engine, j.t_qos) for j in plain]


# ---------------------------------------------------------------------------
# deadline-aware shedding + admission backpressure


def test_certainly_doomed_job_is_shed(configdict):
    fleet = synth_fleet(1, 1, 1)
    doomed = Job(0, ENGINE, 500, 1e-6, 0.0)    # cannot meet QoS anywhere
    served = Simulator(configdict, SynergAI(), fleet=fleet,
                       seed=0).run([dataclasses.replace(doomed)])
    assert served[0].outcome == "" and served[0].violated
    pol = SynergAI(overload=OverloadController())
    shed = Simulator(configdict, pol,
                     fleet=fleet, seed=0).run([dataclasses.replace(doomed)])
    assert shed[0].outcome == "shed" and not shed[0].violated
    assert shed[0].worker == "" and outcome_of(shed[0]) == "shed"
    # the scored row was reclaimed eagerly on the terminal exit
    assert pol.cache is None or (pol.cache.releases >= 1
                                 and 0 not in pol.cache._slot)


def test_shed_fires_even_with_no_open_slot(configdict):
    """The no-availability early return still consults the controller:
    a doomed job sheds while every pool is busy instead of aging."""
    fleet = synth_fleet(1, 0, 0)
    long_j = Job(0, ENGINE, 20_000, 1e6, 0.0)
    doomed = Job(1, ENGINE, 500, 1e-6, 1.0)
    res = Simulator(configdict, SynergAI(overload=OverloadController()),
                    fleet=fleet, seed=0).run([long_j, doomed])
    by = {r.job.id: r for r in res}
    assert by[1].outcome == "shed"
    assert by[1].end < by[0].end       # shed while the pool was busy


def test_queue_cap_bounds_depth(configdict):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "flash", n_jobs=250, fleet=fleet,
                    utilization=2.5, seed=3)
    ctrl = OverloadController(queue_cap=10)
    sim = Simulator(configdict, SynergAI(overload=ctrl), fleet=fleet,
                    seed=1)
    res = sim.run(jobs)
    assert len(res) == 250
    assert ctrl.shed_backpressure_total > 0
    depths = np.asarray(sim.queue_depths)
    # depth is sampled post-drain each tick; the cap holds up to the
    # arrivals that landed after the tick's consult
    assert float(np.percentile(depths, 99)) <= 4 * 10
    s = summarize(res)
    assert s["shed"] == sum(1 for r in res if r.outcome == "shed") > 0
    assert s["jobs"] == 250


def test_controller_counts_doom_vs_backpressure():
    ctrl = OverloadController(queue_cap=2)
    queue = [Job(i, ENGINE, 500, 60.0, 0.0) for i in range(5)]
    doomed = np.array([True, False, False, False, False])
    urgency = np.array([0.0, 3.0, 1.0, 2.0, 4.0])
    shed = ctrl.consult(0.0, queue, doomed, urgency)
    # doom shed: job 0; cap keeps the 2 most schedulable of the rest
    # (urgency order 2, 3) and sheds jobs 1 and 4
    assert shed.tolist() == [True, True, False, False, True]
    assert ctrl.shed_doom_total == 1
    assert ctrl.shed_backpressure_total == 2
    assert {j.id for j in ctrl.drain()} == {0, 1, 4}
    assert ctrl.drain() == []
    assert ctrl.consult(0.0, [], np.zeros(0, bool), np.zeros(0)) is None


# ---------------------------------------------------------------------------
# WAN partition injection


def test_cluster_link_down_window(configdict):
    fleet = synth_fleet(1, 1, 1, regions=2)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=0)
    cl = sim.cluster
    cl.link_outages = [LinkFailureEvent("r0", "r1", 10.0, 5.0)]
    cl._part_memo = (None, frozenset())
    assert not cl.link_down("r0", "r1", 9.0)
    assert cl.link_down("r0", "r1", 10.0)
    assert cl.link_down("r1", "r0", 14.9)      # symmetric
    assert not cl.link_down("r0", "r1", 15.0)  # half-open window
    assert not cl.link_down("r0", "r0", 12.0)  # same region: never
    assert cl.partitioned_pairs(12.0) == \
        frozenset({frozenset(("r0", "r1"))})


def test_partition_blocks_spillover(configdict):
    """A slot-starved region spills to a foreign idle pool — unless the
    WAN link to that region is partitioned."""
    def starved(policy, cl):
        jobs = [Job(i, ENGINE, 500, 120.0, 0.0) for i in range(4)]
        for j in jobs:
            policy.on_arrival(j, cl, 0.0)
            policy.router.home[j.id] = "r0"
        for ws in cl.workers.values():
            if ws.pool.region == "r0":
                ws.busy_until = 1_000.0
        return policy.schedule(1.0, jobs, cl)

    fleet = synth_fleet(2, 2, 2, regions=2)
    pol = HierarchicalSynergAI()
    sim = Simulator(configdict, pol, fleet=fleet, seed=0)
    out = starved(pol, sim.cluster)
    assert out and pol.spills == len(out)      # sanity: spill happens

    pol2 = HierarchicalSynergAI()
    sim2 = Simulator(configdict, pol2, fleet=fleet, seed=0)
    sim2.cluster.link_outages = [LinkFailureEvent("r0", "r1", 0.0, 100.0)]
    sim2.cluster._part_memo = (None, frozenset())
    out2 = starved(pol2, sim2.cluster)
    assert out2 == [] and pol2.spills == 0     # partition severs relief


def test_partition_end_to_end_with_retries(configdict):
    """A full mesh partition during a disaggregated multi-region run:
    cross-region KV pulls are refused at admission, the decode leg
    re-prefills under the retry budget, and every job still reaches
    exactly one terminal outcome."""
    fleet = synth_fleet(3, 6, 9, regions=3, disaggregate=True)
    jobs = regional_scenario(configdict, "mmpp", n_jobs=150, fleet=fleet,
                             seed=5, serving="batched")
    span = jobs[-1].arrival
    links = [LinkFailureEvent(a, b, 0.0, 2 * span)
             for a, b in (("r0", "r1"), ("r0", "r2"), ("r1", "r2"))]
    sim = Simulator(configdict, HierarchicalSynergAI(), fleet=fleet,
                    serving="batched", link_failures=links, seed=2,
                    retry_budget=2)
    res = sim.run(jobs)
    assert len(res) == 150
    assert sorted(r.job.id for r in res) == list(range(150))
    assert {outcome_of(r) for r in res} <= set(OUTCOMES)


# ---------------------------------------------------------------------------
# flapping failure traces


def test_synth_failures_flap_splits_pulses(configdict):
    fleet = synth_fleet(1, 2, 2)
    solid = synth_failures(fleet, 500.0, mtbf_s=100.0, mttr_s=40.0,
                           seed=3)
    flapped = synth_failures(fleet, 500.0, mtbf_s=100.0, mttr_s=40.0,
                             seed=3, flap=4)
    assert len(flapped) == 4 * len(solid)
    by_worker = {}
    for e in flapped:
        by_worker.setdefault(e.worker, []).append(e)
    for e in solid:
        pulses = [p for p in by_worker[e.worker]
                  if e.at - 1e-9 <= p.at < e.at + e.duration]
        assert len(pulses) == 4
        step = e.duration / 4
        for i, p in enumerate(sorted(pulses, key=lambda p: p.at)):
            assert p.at == pytest.approx(e.at + i * step)
            assert p.duration == pytest.approx(0.5 * step)
    # flap=None / flap=1 are the seed-identical historical trace
    assert synth_failures(fleet, 500.0, mtbf_s=100.0, mttr_s=40.0,
                          seed=3, flap=1) == solid


# ---------------------------------------------------------------------------
# elastic scale-down hysteresis


def test_elastic_cooldown_damps_thrash(configdict):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "flash", n_jobs=300, fleet=fleet,
                    utilization=1.5, seed=3)
    counts = {}
    for cool in (0.0, 1e9):
        sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=1,
                        elastic_max=4, elastic_threshold=4,
                        provision_s=5.0, elastic_cooldown_s=cool)
        res = sim.run(list(jobs))
        assert len(res) == 300
        counts[cool] = (sim.elastic_clones_total,
                        sim.elastic_retires_total)
    clones0, retires0 = counts[0.0]
    clones1, retires1 = counts[1e9]
    assert clones0 > 0                       # the spike provisions
    # an effectively-infinite quiet window never retires a clone, so
    # nothing is ever re-provisioned after the first ramp
    assert retires1 == 0 and clones1 <= clones0
    assert retires0 >= retires1


def test_region_elastic_clones_extend_home_region_only(configdict):
    """An elastic clone lands in its base pool's region: after growth,
    each RegionView still holds exactly its own region's columns."""
    fleet = synth_fleet(2, 2, 2, regions=2)
    jobs = regional_scenario(configdict, "flash", n_jobs=250, fleet=fleet,
                             utilization=1.8, seed=3)
    pol = HierarchicalSynergAI()
    # infinite cooldown keeps every clone alive to the end of the run,
    # so the final fleet still carries the provisioned columns
    sim = Simulator(configdict, pol, fleet=fleet, seed=1, elastic_max=4,
                    elastic_threshold=4, provision_s=5.0,
                    elastic_cooldown_s=1e9)
    res = sim.run(jobs)
    assert len(res) == 250
    assert sim.elastic_clones_total > 0
    assert len(sim.cluster.workers) > len(fleet)
    pol._ensure(sim.cluster)                 # fold in the final fleet
    for region, view in pol._views.items():
        for name in view.arrays.names:
            assert sim.cluster.workers[name].pool.region == region


# ---------------------------------------------------------------------------
# chaos soak: conservation under everything at once


def _chaos_run(configdict, seed, serving):
    fleet = synth_fleet(2, 4, 6, regions=3)
    jobs = regional_scenario(configdict, "mmpp", n_jobs=120, fleet=fleet,
                             utilization=1.4, seed=seed, serving=serving,
                             patience=6.0)
    span = jobs[-1].arrival
    fails = synth_failures(fleet, span, mtbf_s=span / 2, mttr_s=span / 8,
                           seed=seed, regions=True, flap=2)
    links = [LinkFailureEvent("r0", "r1", 0.2 * span, 0.4 * span),
             LinkFailureEvent("r1", "r2", 0.5 * span, 0.3 * span)]
    ctrl = OverloadController(queue_cap=48)
    pol = HierarchicalSynergAI(overload=ctrl)
    sim = Simulator(configdict, pol, fleet=fleet, serving=serving,
                    failures=fails, link_failures=links, seed=seed,
                    retry_budget=2, retry_base_s=1.0)
    return sim, pol, sim.run(jobs), jobs


def _assert_conserved(sim, pol, res, jobs, serving):
    # exactly one terminal outcome per job, nothing lost or duplicated
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    assert {outcome_of(r) for r in res} <= set(OUTCOMES)
    # non-served results never bill service
    for r in res:
        if r.outcome and r.prefill_worker is None:
            assert r.worker == "" and r.exec_s == 0.0
    if serving == "batched":
        # token conservation: every worker token maps to exactly one
        # served job (kills and abandons contribute nothing)
        served = [r for r in res if not r.outcome]
        want_p = sum(r.job.request.prompt_tokens for r in served)
        want_d = sum(r.job.request.decode_tokens for r in served)
        have_p = sum(w.prefill_tokens for w in sim.cluster.workers.values())
        have_d = sum(w.decoded_tokens for w in sim.cluster.workers.values())
        assert (have_p, have_d) == (want_p, want_d)
    # the score caches dropped every terminal job's row
    for r in res:
        if r.outcome:
            for sub in pol._subs.values():
                assert sub.cache is None or r.job.id not in sub.cache._slot


@pytest.mark.parametrize("serving", ["job", "batched"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_soak_conserves_everything(configdict, seed, serving):
    sim, pol, res, jobs = _chaos_run(configdict, seed, serving)
    _assert_conserved(sim, pol, res, jobs, serving)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_chaos_soak_property(seed):
    sim, pol, res, jobs = _chaos_run(_chaos_cd(), seed, "job")
    _assert_conserved(sim, pol, res, jobs, "job")


_CD_CACHE = {}


def _chaos_cd():
    if "cd" not in _CD_CACHE:
        from repro.core.offline import characterize
        _CD_CACHE["cd"] = characterize()
    return _CD_CACHE["cd"]


# ---------------------------------------------------------------------------
# metrics: taxonomy + goodput


def test_summarize_outcomes_and_goodput():
    j = [Job(i, ENGINE, 500, 10.0, 0.0) for i in range(4)]
    ok = JobResult(j[0], "w", "c", 0.0, 5.0, 0.0, 5.0, 5.0, False, 0.0,
                   0.0, 0.0)
    late = JobResult(j[1], "w", "c", 0.0, 20.0, 0.0, 20.0, 20.0, True,
                     10.0, 0.0, 0.0)
    shed = JobResult(j[2], "", "", 2.0, 2.0, 2.0, 0.0, 2.0, False, 0.0,
                     0.0, 0.0, outcome="shed")
    gone = JobResult(j[3], "", "", 3.0, 3.0, 3.0, 0.0, 3.0, False, 0.0,
                     0.0, 0.0, outcome="abandoned")
    s = summarize([ok, late, shed, gone])
    assert (s["completed"], s["violated"], s["shed"],
            s["abandoned"], s["failed"]) == (1, 1, 1, 1, 0)
    assert s["violations"] == 1 and s["jobs"] == 4
    # latency stats cover the served results only
    assert s["e2e_max_s"] == 20.0 and s["e2e_avg_s"] == 12.5
    # goodput: 1 within-QoS completion over the 20 s span
    assert s["goodput_jps"] == pytest.approx(1 / 20.0)
    assert [outcome_of(r) for r in (ok, late, shed, gone)] == \
        ["completed", "violated", "shed", "abandoned"]


# ---------------------------------------------------------------------------
# trace round-trip of the new job fields


def test_trace_roundtrip_patience_and_retry_budget(configdict, tmp_path):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "poisson", n_jobs=15, fleet=fleet,
                    seed=2, patience=1.5)
    jobs[0].retry_budget = 4
    jobs[1] = dataclasses.replace(jobs[1], patience=None)
    path = tmp_path / "trace.jsonl"
    save_trace(path, jobs)
    back = load_trace(path)
    assert [(j.id, j.patience, j.retry_budget) for j in back] == \
        [(j.id, j.patience, j.retry_budget) for j in jobs]


# ---------------------------------------------------------------------------
# bench smoke (the tier-1 CI leg's schema)


def test_bench_overload_smoke(configdict):
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import bench_overload
    blob = bench_overload(configdict, smoke=True, emit=lambda *a: None)
    assert blob["bench"] == "bench_overload" and blob["schema"] == 1
    variants = {c["variant"] for c in blob["configs"]}
    assert variants == {"overload-uncontrolled", "overload-controlled"}
    for c in blob["configs"]:
        assert {"goodput_jps", "queue_depth_p99", "J", "W", "serving",
                "regions"} <= set(c)
        assert sum(c[o] for o in OUTCOMES) == c["J"]
    assert "overload_headline" not in blob     # smoke never gates
