"""Pallas ``scheduler_score`` vs the numpy ``estimate_matrix`` oracle at
fleet scale (J~2048, W=256), covering the padding edges (J not divisible by
``bj``, all-infeasible rows, doomed jobs) — and the drop-in guarantee:
``SynergAI(score_fn=pallas)`` produces identical assignments.

The fused v2 kernel (``scheduler_score_v2``) is additionally checked
against the numpy batched + streaming + disaggregated scoring block
(depth penalty, phase slicing, TTFT/TPOT gates — interpret mode, padding
edges included), and ``SynergAI(score_fn=make_pallas_score_fn(v2=True))``
must be a drop-in under ``serving="batched"``."""

import numpy as np
import pytest

from repro.core.estimator import estimate_matrix
from repro.core.job import Job
from repro.core.pallas_scoring import make_pallas_score_fn
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import (MMPPArrivals, ParetoSize, TenantSpec,
                                 make_workload, scenario)


def _fleet_queue(cd, n_jobs):
    """A messy fleet-scale queue: bursty multi-tenant mix, heavy-tail
    sizes, a slice of doomed jobs (impossible QoS) and a slice of
    all-infeasible rows (engine unknown to the ConfigDict)."""
    tenants = [
        TenantSpec("mix", MMPPArrivals((0.5, 3.0), (120.0, 60.0)),
                   n_jobs - 64, sizes=ParetoSize()),
        # doomed: QoS far below anything any worker can deliver
        TenantSpec("doomed", MMPPArrivals((0.5, 3.0), (120.0, 60.0)), 32,
                   qos_scale=1e-3),
    ]
    jobs = make_workload(cd, tenants, seed=13)
    # all-infeasible rows: an engine no worker has a profile for
    for i in range(32):
        jobs.append(Job(len(jobs), "unknown-engine/bf16", 1000, 60.0,
                        float(i)))
    return jobs


@pytest.mark.parametrize("J,bj", [(2048, 128), (2043, 128)])
def test_pallas_matches_numpy_oracle_at_fleet_scale(configdict, J, bj):
    fleet = synth_fleet(86, 85, 85)
    workers = [w.name for w in fleet]
    assert len(workers) == 256
    jobs = _fleet_queue(configdict, J)[:J]
    now = float(np.median([j.arrival for j in jobs]))  # t_rem straddles 0
    s_np = estimate_matrix(configdict, jobs, workers, now)
    s_pl = make_pallas_score_fn(bj=bj)(configdict, jobs, workers, now)
    assert (s_np.best_worker == s_pl.best_worker).all()
    assert (s_np.acceptable == s_pl.acceptable).all()
    assert (s_np.doomed == s_pl.doomed).all()
    feas = np.isfinite(s_np.t_estimated)
    assert (np.isfinite(s_pl.t_estimated) == feas).all()
    np.testing.assert_allclose(s_pl.t_estimated[feas],
                               s_np.t_estimated[feas], rtol=1e-5)
    np.testing.assert_allclose(s_pl.urgency[feas.any(1)],
                               s_np.urgency[feas.any(1)], rtol=1e-4,
                               atol=0.5)
    # the all-infeasible rows really exercised the -1 path
    assert (s_np.best_worker == -1).any()
    # and the doomed path
    assert s_np.doomed.any() and not s_np.doomed.all()


def test_synergai_identical_assignments_with_pallas_score_fn(configdict):
    """Byte-identical schedules: same worker, config and (noise-driven)
    timings job-for-job on a paper experiment and on a fleet scenario."""
    from repro.core.job import make_experiment

    def run(score_fn, jobs, **kw):
        sim = Simulator(configdict, SynergAI(score_fn=score_fn), **kw)
        return [(r.job.id, r.worker, r.config, r.start, r.end, r.violated)
                for r in sim.run(jobs)]

    jobs = make_experiment(configdict, "DH", "FH", seed=11)
    assert run(None, jobs, seed=11) \
        == run(make_pallas_score_fn(), jobs, seed=11)

    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "mmpp", n_jobs=120, fleet=fleet,
                    utilization=0.9, seed=5)
    assert run(None, jobs, fleet=fleet, seed=5) \
        == run(make_pallas_score_fn(), jobs, fleet=fleet, seed=5)


# ----------------------------------------------------------------------------
# fused v2 kernel: batched + streaming + disaggregated scoring


def _v2_inputs(configdict, J, seed):
    """A messy fused-scoring input set: fleet-scale matrices with
    infeasible columns and all-infeasible rows, mixed phases, live depth
    penalties, and streaming deadlines on a slice of the queue."""
    from repro.core.estimator import phase_split_matrices, score_matrices

    rng = np.random.default_rng(seed)
    fleet = synth_fleet(86, 85, 85)
    workers = [w.name for w in fleet]
    jobs = _fleet_queue(configdict, J)[:J]
    now = float(np.median([j.arrival for j in jobs]))
    qps, pre = score_matrices(configdict, jobs, workers)
    q = np.array([float(j.queries) for j in jobs])
    with np.errstate(divide="ignore", invalid="ignore"):
        t0 = np.where(qps > 0, pre + q[:, None] / qps, np.inf)
    pre_m, dec_m = phase_split_matrices(configdict, jobs, workers)
    t_rem = np.array([j.t_qos - (now - j.arrival) for j in jobs])
    pen = np.where(rng.random(len(workers)) < 0.5,
                   1.0 + 0.5 * rng.integers(1, 8, len(workers)), 1.0)
    phase = rng.integers(0, 3, J).astype(np.int8)
    has_ttft = rng.random(J) < 0.4
    has_tpot = rng.random(J) < 0.4
    ttft_qos = np.where(has_ttft, rng.uniform(0.5, 50.0, J), np.inf)
    tpot_qos = np.where(has_tpot, rng.uniform(1e-5, 1e-2, J), np.inf)
    dtok = rng.integers(100, 200_000, J).astype(np.float64)
    ttft_rem = ttft_qos - rng.uniform(0.0, 5.0, J)
    return (t0, pre_m, dec_m, t_rem, pen, phase, has_ttft, has_tpot,
            ttft_rem, tpot_qos, dtok)


def _v2_numpy_oracle(t0, pre_m, dec_m, t_rem, pen, phase, has_ttft,
                     has_tpot, ttft_rem, tpot_qos, dtok):
    """The exact numpy scoring block from ``SynergAI``: phase slicing,
    depth penalty, Eq. 3 + streaming gates, TTFT-tightened urgency."""
    t = np.where((phase == 1)[:, None], pre_m,
                 np.where((phase == 2)[:, None], dec_m, t0))
    t = t * pen[None, :]
    acceptable = t_rem[:, None] >= t
    ttft_est = pre_m * pen[None, :]
    tpot_est = dec_m * pen[None, :] / dtok[:, None]
    ok_ttft = ((~has_ttft | (phase == 2))[:, None]
               | (ttft_est <= ttft_rem[:, None]))
    ok_tpot = ((~has_tpot | (phase == 1))[:, None]
               | (tpot_est <= tpot_qos[:, None]))
    acceptable = acceptable & ok_ttft & ok_tpot
    urgency = t_rem - t0.min(axis=1)
    with np.errstate(invalid="ignore"):
        ttft_slack = ttft_rem - np.min(ttft_est, axis=1)
    urgency = np.where(has_ttft & (phase != 2),
                       np.minimum(urgency, ttft_slack), urgency)
    return t, acceptable, urgency, ~acceptable.any(axis=1)


@pytest.mark.parametrize("J,bj", [(1024, 128), (1021, 128)])
def test_v2_kernel_matches_numpy_oracle(configdict, J, bj):
    inputs = _v2_inputs(configdict, J, seed=17)
    t, acc, urg, doom = _v2_numpy_oracle(*inputs)
    fn = make_pallas_score_fn(bj=bj, v2=True)
    t2, acc2, urg2, doom2 = fn(*inputs)
    feas = np.isfinite(t)
    assert (np.isfinite(t2) == feas).all()
    np.testing.assert_allclose(t2[feas], t[feas], rtol=1e-5)
    # float32 scoring may flip entries whose estimate ties the deadline
    # to the last few bits; everything with real margin must agree
    t_rem = inputs[3]
    margin = np.abs(t - t_rem[:, None])
    tol = 1e-4 * np.maximum(np.abs(t), np.abs(t_rem)[:, None]) + 1e-6
    clear = feas & (margin > tol)
    assert (acc2 == acc)[clear].all()
    mism = (acc2 != acc) & ~clear
    assert mism.mean() < 0.01                      # ties are rare
    same_doom = (acc2.any(axis=1) == acc.any(axis=1))
    assert same_doom.mean() > 0.99
    assert (doom2 == ~acc2.any(axis=1)).all()      # self-consistent
    assert (doom2 == doom)[same_doom].all()
    row_ok = feas.any(axis=1)
    np.testing.assert_allclose(urg2[row_ok], urg[row_ok], rtol=1e-4,
                               atol=0.5)
    # the messy inputs really exercised the edges
    assert (~feas).any(axis=1).any() and (~feas).all(axis=1).any()
    assert doom.any() and not doom.all()
    assert (inputs[4] != 1.0).any()                # live depth penalties


def test_synergai_v2_drop_in(configdict):
    """``SynergAI(score_fn=make_pallas_score_fn(v2=True))`` is a drop-in:
    byte-identical schedules under serving='batched' with streaming
    deadlines and disaggregated pools — and in plain job mode."""
    def run(score_fn, jobs, **kw):
        sim = Simulator(configdict, SynergAI(score_fn=score_fn), **kw)
        return [(r.job.id, r.worker, r.config, r.start, r.end,
                 r.violated, r.ttft, r.tpot) for r in sim.run(jobs)]

    fleet = synth_fleet(2, 3, 3, disaggregate=True)
    jobs = scenario(configdict, "mmpp", n_jobs=120, fleet=fleet, seed=3,
                    utilization=1.0, serving="batched",
                    streaming=(2.0, 2.5))
    kw = dict(fleet=fleet, seed=3, serving="batched")
    assert run(None, jobs, **kw) \
        == run(make_pallas_score_fn(v2=True), jobs, **kw)

    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "mmpp", n_jobs=120, fleet=fleet,
                    utilization=0.9, seed=5)
    assert run(None, jobs, fleet=fleet, seed=5) \
        == run(make_pallas_score_fn(v2=True), jobs, fleet=fleet, seed=5)


# ----------------------------------------------------------------------------
# the float32 boundary-tie caveat, as an executable contract: a QoS
# budget that ties the estimate at the last float64 bit may flip between
# acceptable and doomed (the kernels score in f32), and that divergence
# is confined to the tie — everything with real margin agrees exactly


def _tie_inputs():
    """2 jobs x 2 workers; job 0's budget sits one f64 ulp *below* its
    worker-0 estimate (t = 0.25 + 100/2.0 = 50.25, exact in f32 and
    f64), so float64 rejects while the float32 cast rounds the budget
    back onto the estimate and accepts.  Job 1 has generous margins
    everywhere."""
    qps = np.array([[2.0, 1.0], [2.0, 4.0]])
    pre = np.array([[0.25, 0.5], [0.25, 0.5]])
    q = np.array([100.0, 100.0])
    t = pre + q[:, None] / qps           # [[50.25, 100.5], [50.25, 25.5]]
    t_rem = np.array([np.nextafter(50.25, 0.0), 60.0])
    return qps, pre, q, t, t_rem


def test_f32_boundary_tie_contract_v1():
    qps, pre, q, t, t_rem = _tie_inputs()
    # the f64 oracle: the tie cell misses by one ulp
    acc64 = t_rem[:, None] >= t
    assert not acc64[0, 0] and not acc64[0].any()    # doomed in f64
    assert acc64[1].all()
    # the f32 cast lands exactly on the estimate -> acceptable
    assert np.float32(t_rem[0]) == np.float32(t[0, 0]) == 50.25
    from repro.kernels.scheduler_score import scheduler_score
    est, best, urg, acc = scheduler_score(
        qps.astype(np.float32), pre.astype(np.float32),
        q.astype(np.float32), t_rem.astype(np.float32), bj=8,
        interpret=True)
    acc = np.asarray(acc).astype(bool)
    # divergence confined to the documented tie cell
    assert acc[0, 0] and not acc64[0, 0]
    diff = acc != acc64
    assert diff.sum() == 1 and diff[0, 0]
    # exact parity off the boundary: estimates are the same dyadic
    # rationals in both precisions here, margins are wide
    np.testing.assert_array_equal(np.asarray(est, np.float64), t)
    assert (acc[1] == acc64[1]).all()


def test_f32_boundary_tie_contract_v2():
    qps, pre, q, t, t_rem = _tie_inputs()
    acc64 = t_rem[:, None] >= t
    J, W = t.shape
    fn = make_pallas_score_fn(bj=8, v2=True)
    t2, acc, urg, doom = fn(
        t, t, t, t_rem, np.ones(W), np.zeros(J, np.int8),
        np.zeros(J, bool), np.zeros(J, bool), np.full(J, np.inf),
        np.full(J, np.inf), np.ones(J))
    diff = acc != acc64
    assert diff.sum() == 1 and diff[0, 0]
    assert doom[0] != (~acc64[0].any())       # the flip un-dooms job 0
    assert not doom[1] and (acc[1] == acc64[1]).all()
    np.testing.assert_array_equal(t2, t)


def test_f32_off_boundary_exact_parity():
    """One ulp of *f32* margin is already enough: nudge the budget a
    float32 step off the estimate in either direction and both
    precisions agree everywhere."""
    from repro.kernels.scheduler_score import scheduler_score
    qps, pre, q, t, _ = _tie_inputs()
    for rem0 in (np.float64(np.nextafter(np.float32(50.25),
                                         np.float32(0.0))),
                 np.float64(np.nextafter(np.float32(50.25),
                                         np.float32(100.0)))):
        t_rem = np.array([rem0, 60.0])
        acc64 = t_rem[:, None] >= t
        _, _, _, acc = scheduler_score(
            qps.astype(np.float32), pre.astype(np.float32),
            q.astype(np.float32), t_rem.astype(np.float32), bj=8,
            interpret=True)
        np.testing.assert_array_equal(
            np.asarray(acc).astype(bool), acc64)


# ----------------------------------------------------------------------------
# zero-job ticks: every scoring backend shares ScoreResult.empty


def test_zero_job_score_result_shared_shape(configdict):
    from repro.core.estimator import ScoreResult
    workers = [w.name for w in synth_fleet(1, 2, 2)]
    empty = ScoreResult.empty(workers)
    assert empty.workers == workers
    assert empty.t_estimated.shape == (0, len(workers))
    assert empty.acceptable.shape == (0, len(workers))
    for arr in (empty.t_remaining, empty.best_worker, empty.urgency,
                empty.doomed):
        assert arr.shape == (0,)
    # the numpy estimator and the pallas v1 backend return the same
    # shaped empty (the hand-built variant used to drift)
    for fn in (estimate_matrix, make_pallas_score_fn()):
        got = fn(configdict, [], workers, now=0.0)
        assert got.workers == workers
        assert got.t_estimated.shape == (0, len(workers))
        assert got.best_worker.shape == (0,)


@pytest.mark.parametrize("variant", ["numpy", "uncached", "pallas",
                                     "pallas-v2", "pallas-resident"])
def test_zero_job_tick_all_variants(configdict, variant):
    pol = {
        "numpy": lambda: SynergAI(),
        "uncached": lambda: SynergAI(incremental=False),
        "pallas": lambda: SynergAI(score_fn=make_pallas_score_fn()),
        "pallas-v2": lambda: SynergAI(
            score_fn=make_pallas_score_fn(v2=True)),
        "pallas-resident": lambda: SynergAI(
            score_fn=make_pallas_score_fn(device_cache=True)),
    }[variant]()
    fleet = synth_fleet(1, 2, 2)
    cluster = Simulator(configdict, pol, fleet=fleet).cluster
    assert pol.schedule(0.0, [], cluster) == []
    # and with a queue that empties: the next tick stays well-formed
    jobs = scenario(configdict, "poisson", n_jobs=4, fleet=fleet,
                    seed=2)
    out = pol.schedule(0.0, list(jobs), cluster)
    assert out                      # something placed on idle workers
    assert pol.schedule(1.0, [], cluster) == []
