"""Pallas ``scheduler_score`` vs the numpy ``estimate_matrix`` oracle at
fleet scale (J~2048, W=256), covering the padding edges (J not divisible by
``bj``, all-infeasible rows, doomed jobs) — and the drop-in guarantee:
``SynergAI(score_fn=pallas)`` produces identical assignments."""

import numpy as np
import pytest

from repro.core.estimator import estimate_matrix
from repro.core.job import Job
from repro.core.pallas_scoring import make_pallas_score_fn
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import (MMPPArrivals, ParetoSize, TenantSpec,
                                 make_workload, scenario)


def _fleet_queue(cd, n_jobs):
    """A messy fleet-scale queue: bursty multi-tenant mix, heavy-tail
    sizes, a slice of doomed jobs (impossible QoS) and a slice of
    all-infeasible rows (engine unknown to the ConfigDict)."""
    tenants = [
        TenantSpec("mix", MMPPArrivals((0.5, 3.0), (120.0, 60.0)),
                   n_jobs - 64, sizes=ParetoSize()),
        # doomed: QoS far below anything any worker can deliver
        TenantSpec("doomed", MMPPArrivals((0.5, 3.0), (120.0, 60.0)), 32,
                   qos_scale=1e-3),
    ]
    jobs = make_workload(cd, tenants, seed=13)
    # all-infeasible rows: an engine no worker has a profile for
    for i in range(32):
        jobs.append(Job(len(jobs), "unknown-engine/bf16", 1000, 60.0,
                        float(i)))
    return jobs


@pytest.mark.parametrize("J,bj", [(2048, 128), (2043, 128)])
def test_pallas_matches_numpy_oracle_at_fleet_scale(configdict, J, bj):
    fleet = synth_fleet(86, 85, 85)
    workers = [w.name for w in fleet]
    assert len(workers) == 256
    jobs = _fleet_queue(configdict, J)[:J]
    now = float(np.median([j.arrival for j in jobs]))  # t_rem straddles 0
    s_np = estimate_matrix(configdict, jobs, workers, now)
    s_pl = make_pallas_score_fn(bj=bj)(configdict, jobs, workers, now)
    assert (s_np.best_worker == s_pl.best_worker).all()
    assert (s_np.acceptable == s_pl.acceptable).all()
    assert (s_np.doomed == s_pl.doomed).all()
    feas = np.isfinite(s_np.t_estimated)
    assert (np.isfinite(s_pl.t_estimated) == feas).all()
    np.testing.assert_allclose(s_pl.t_estimated[feas],
                               s_np.t_estimated[feas], rtol=1e-5)
    np.testing.assert_allclose(s_pl.urgency[feas.any(1)],
                               s_np.urgency[feas.any(1)], rtol=1e-4,
                               atol=0.5)
    # the all-infeasible rows really exercised the -1 path
    assert (s_np.best_worker == -1).any()
    # and the doomed path
    assert s_np.doomed.any() and not s_np.doomed.all()


def test_synergai_identical_assignments_with_pallas_score_fn(configdict):
    """Byte-identical schedules: same worker, config and (noise-driven)
    timings job-for-job on a paper experiment and on a fleet scenario."""
    from repro.core.job import make_experiment

    def run(score_fn, jobs, **kw):
        sim = Simulator(configdict, SynergAI(score_fn=score_fn), **kw)
        return [(r.job.id, r.worker, r.config, r.start, r.end, r.violated)
                for r in sim.run(jobs)]

    jobs = make_experiment(configdict, "DH", "FH", seed=11)
    assert run(None, jobs, seed=11) \
        == run(make_pallas_score_fn(), jobs, seed=11)

    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "mmpp", n_jobs=120, fleet=fleet,
                    utilization=0.9, seed=5)
    assert run(None, jobs, fleet=fleet, seed=5) \
        == run(make_pallas_score_fn(), jobs, fleet=fleet, seed=5)
