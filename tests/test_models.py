"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs,
plus the prefill+decode == full-forward consistency invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, get_config
from repro.models.registry import build_model
from repro.serving.kvcache import pad_cache

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, key, B, S, with_labels=True):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = jnp.roll(toks, -1, axis=1)
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.vision.n_vision_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            key, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = make_batch(cfg, key, B=2, S=16)
    loss = model.train_loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    # gradients exist and are finite for every leaf
    grads = jax.grad(model.train_loss)(params, batch)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.all(np.isfinite(np.asarray(g))), f"{arch}: NaN grad {path}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    B, S = 2, 16
    batch = make_batch(cfg, key, B, S, with_labels=False)
    logits, caches = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert len(jax.tree.leaves(caches)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init_params(key)
    B, S = 2, 12
    batch_full = make_batch(cfg, key, B, S + 1, with_labels=False)
    if cfg.family == "audio":  # same source for both runs
        batch_full["audio_embeds"] = batch_full["audio_embeds"][:, :S]
    logits_full, _ = model.prefill(params, batch_full)

    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :S]
    _, caches = model.prefill(params, batch_pre)
    ctx_len = S if cfg.family == "audio" else None
    caches = pad_cache(caches, model.init_cache(B, S + 4, ctx_len))
    logits_step, _ = model.decode(
        params, caches,
        {"token": batch_full["tokens"][:, S:S + 1], "pos": jnp.int32(S)})
    rel = (np.max(np.abs(logits_full - logits_step))
           / (np.max(np.abs(logits_full)) + 1e-9))
    assert rel < 2e-3, f"{arch}: prefill+decode diverges from full, rel={rel}"


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "hymba-1.5b"])
def test_sliding_window_ring_decode(arch):
    """Decoding past the window must keep matching the full forward."""
    cfg = reduced(get_config(arch))
    assert cfg.sliding_window is not None
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init_params(key)
    B = 1
    W = cfg.sliding_window
    S = 2 * W  # prompt spans two windows; ring must have wrapped
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    logits_full, _ = model.prefill(params, {"tokens": toks})
    _, caches = model.prefill(params, {"tokens": toks[:, :S]})
    caches = pad_cache(caches, model.init_cache(B, S + 4))
    logits_step, _ = model.decode(params, caches,
                                  {"token": toks[:, S:S + 1],
                                   "pos": jnp.int32(S)})
    rel = (np.max(np.abs(logits_full - logits_step))
           / (np.max(np.abs(logits_full)) + 1e-9))
    assert rel < 2e-3, f"{arch}: ring cache broke at wrap, rel={rel}"


def test_param_count_sane():
    # analytic parameter counts should be within 35% of actual init sizes
    # (analytic count skips small norm/bias tensors)
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        approx = cfg.param_count
        assert approx > 1e8, arch
    # exact check on one reduced model
    cfg = reduced(get_config("qwen3-4b"))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    assert actual > 0


def test_flash_attention_matches_naive():
    from repro.models import common
    key = jax.random.PRNGKey(0)
    B, S, H, K, hd = 2, 128, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd))
    for window in [None, 32]:
        ref = common.naive_attention(q, k, v, causal=True, window=window)
        out = common.chunked_flash_attention(q, k, v, causal=True,
                                             window=window, chunk=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
