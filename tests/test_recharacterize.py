"""Online re-characterization: drift detection + estimator refresh.

The drift-recovery test tier the PR's acceptance anchors name:

- **Quiet detector** — with an ``OnlineRecharacterizer`` attached, every
  non-drift schedule is bit-for-bit what it is without one: deterministic
  scenario combos across both serving modes and all three policy shapes
  (flat ``SynergAI``, ``HierarchicalSynergAI``, ``SloMael``), plus the
  PR2/replay golden digests reproduced with the detector *enabled*.
- **Recovery** — under ``synth_degradations`` (unmodeled pool slowdowns)
  the online loop cuts QoS violations strictly below the stale-profile
  run and lands within a pinned factor of the true-factor oracle.
- **Mechanics** — ``DegradationEvent`` slowdown composition and exact
  restore, the ``service_s / service_pred_s`` observable, minimal
  profile-generation cache flush (exactly the refreshed engines' rows),
  and cached == uncached through refresh/failure/elastic interleavings.
- Hypothesis properties behind the conftest shim with seeded fallbacks.
"""

import functools
import hashlib
import math

import numpy as np
import pytest
from conftest import given, settings, st
from test_streaming_qos import PR2_GOLDEN
from test_trace_replay import REPLAY_GOLDEN_DIGEST

from repro.core.engines import engine_catalogue
from repro.core.estimator import profile_gen, profile_overlay
from repro.core.hierarchy import HierarchicalSynergAI
from repro.core.offline import characterize
from repro.core.recharacterize import (OnlineRecharacterizer, _MixWindow,
                                       _ResidWindow)
from repro.core.scheduler import SynergAI
from repro.core.scorecache import ScoreCache
from repro.core.simulator import DegradationEvent, Simulator
from repro.core.slo_mael import SloMael
from repro.core.workers import synth_fleet
from repro.core.workload import (replay, save_trace, scenario,
                                 synth_degradations, synth_failures)


@functools.lru_cache(maxsize=None)
def _cd():
    return characterize()


def _result_key(results):
    return [(r.job.id, r.worker, r.config, r.start, r.end, r.waiting,
             r.exec_s, r.e2e, r.violated, r.excess, r.ttft, r.tpot)
            for r in results]


def _violations(results):
    return sum(1 for r in results if r.violated)


# ----------------------------------------------------------------------------
# quiet detector: enabled on non-drift traffic == no recharacterizer,
# bit-for-bit, across serving modes and policy shapes

def _check_quiet(kind, serving, make_policy, seed=3, n_jobs=260,
                 utilization=1.2, regions=None):
    cd = _cd()
    fleet = synth_fleet(1, 2, 2, regions=regions)
    jobs = scenario(cd, kind, n_jobs=n_jobs, fleet=fleet, seed=seed,
                    utilization=utilization, serving=serving)
    kw = dict(fleet=fleet, seed=seed, serving=serving)
    base = _result_key(Simulator(cd, make_policy(None), **kw).run(jobs))
    rc = OnlineRecharacterizer()
    withrc = _result_key(Simulator(cd, make_policy(rc), **kw).run(jobs))
    assert withrc == base
    assert rc.refreshes == 0, rc.last_reason
    return rc


@pytest.mark.parametrize("kind,serving,policy", [
    ("mmpp", "job", "synergai"),
    ("mmpp", "batched", "synergai"),
    ("flash", "job", "hier"),
    ("multi-tenant", "batched", "hier"),
    ("poisson", "job", "slomael"),
    ("diurnal", "batched", "slomael"),
])
def test_quiet_detector_bit_for_bit(kind, serving, policy):
    make = {
        "synergai": lambda rc: SynergAI(recharacterizer=rc),
        "hier": lambda rc: HierarchicalSynergAI(recharacterizer=rc),
        "slomael": lambda rc: SloMael(recharacterizer=rc),
    }[policy]
    _check_quiet(kind, serving, make,
                 regions=2 if policy == "hier" else None)


def test_detect_false_is_inert():
    rc = _check_quiet("mmpp", "job",
                      lambda rc: SynergAI(
                          recharacterizer=rc or
                          OnlineRecharacterizer(detect=False)))
    assert rc.refreshes == 0


def test_golden_digest_replayed_mmpp_with_detector_enabled(configdict,
                                                           tmp_path):
    """The PR4 replay golden digest, reproduced with the online loop
    *enabled*: 40 jobs never fill a detector window, and even the live
    observation hooks must not perturb one bit of the schedule."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2)
    path = tmp_path / "golden.jsonl"
    save_trace(path, jobs)
    rc = OnlineRecharacterizer()
    res = Simulator(configdict, SynergAI(recharacterizer=rc), fleet=fleet,
                    seed=7).run(replay(str(path)))
    canon = "\n".join(
        f"{r.job.id},{r.worker},{r.config},{r.start!r},{r.end!r},"
        f"{r.ttft!r},{r.tpot!r},{int(r.violated)}"
        for r in sorted(res, key=lambda r: r.job.id))
    assert hashlib.sha256(canon.encode()).hexdigest() == \
        REPLAY_GOLDEN_DIGEST
    assert rc.refreshes == 0


@pytest.mark.parametrize("policy", ["flat", "hier"])
def test_pr2_golden_with_detector_enabled(configdict, policy):
    """The PR2 batched golden values survive an enabled detector, flat
    and through the hierarchical wrapper."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2, serving="batched")
    rc = OnlineRecharacterizer()
    pol = (SynergAI(recharacterizer=rc) if policy == "flat"
           else HierarchicalSynergAI(recharacterizer=rc))
    res = {r.job.id: r for r in
           Simulator(configdict, pol, fleet=fleet, seed=7,
                     serving="batched").run(jobs)}
    for jid, worker, start, end, exec_s, violated in PR2_GOLDEN:
        r = res[jid]
        assert r.worker == worker
        assert r.start == pytest.approx(start, rel=1e-9)
        assert r.end == pytest.approx(end, rel=1e-9)
        assert r.exec_s == pytest.approx(exec_s, rel=1e-9)
        assert r.violated == violated
    assert rc.refreshes == 0


# ----------------------------------------------------------------------------
# recovery: stale profile vs the online loop vs the oracle

def _drift_setup(cd, n_jobs=2500, factor=5.0):
    fleet = synth_fleet(2, 5, 5, regions=3)
    jobs = scenario(cd, "drift", n_jobs=n_jobs, fleet=fleet,
                    utilization=0.6, seed=0)
    degs = synth_degradations(fleet, jobs[-1].arrival, factor=factor,
                              fraction=0.35, prefix="edge", seed=0)
    return fleet, jobs, degs


def test_drift_recovery_online_beats_stale(configdict):
    """An unmodeled 5x slowdown on a third of the edge tier: the online
    loop must at least halve the stale profile's violations and land
    within a pinned factor of the true-factor oracle."""
    cd = configdict
    fleet, jobs, degs = _drift_setup(cd)
    truth = {d.worker: d.factor for d in degs}

    def run(rc):
        return Simulator(cd, SynergAI(recharacterizer=rc),
                         fleet=list(fleet), degradations=degs,
                         seed=0).run(list(jobs))

    stale = _violations(run(None))
    rc = OnlineRecharacterizer()
    online = _violations(run(rc))
    oracle_rc = OnlineRecharacterizer(detect=False)
    from repro.core.simulator import Cluster
    oracle_rc.seed(Cluster(cd, list(fleet)), worker_factors=truth)
    oracle = _violations(run(oracle_rc))

    assert rc.refreshes >= 1
    assert online < stale                      # strictly better
    assert online <= stale / 2                 # at least halved
    assert oracle <= online                    # oracle is the floor
    assert online <= 8 * max(1, oracle)        # pinned factor of oracle
    # the refresh installed beliefs in the slow direction on degraded
    # pools (scale < 1 means "believed slower than the profile")
    ov = profile_overlay(cd, rc.profile)
    names = tuple(w.name for w in fleet)
    slowed = [w for w in truth if w in names]
    assert slowed
    believed = np.ones(len(names))
    for e in ov.scale:
        believed = np.minimum(believed, ov.factors(e, names))
    for w in slowed:
        assert believed[names.index(w)] < 1.0, w


def test_drift_recovery_batched_serving(configdict):
    """The residual observable is batch-contention-free, so the loop
    also recovers under batched serving (looser bar: strictly fewer
    violations than the stale profile)."""
    cd = configdict
    fleet, jobs, degs = _drift_setup(cd, n_jobs=1200)
    jobs = scenario(cd, "drift", n_jobs=1200, fleet=fleet,
                    utilization=0.6, seed=0, serving="batched")
    degs = synth_degradations(fleet, jobs[-1].arrival, factor=5.0,
                              fraction=0.35, prefix="edge", seed=0)

    def run(rc):
        return Simulator(cd, SynergAI(recharacterizer=rc),
                         fleet=list(fleet), degradations=degs, seed=0,
                         serving="batched").run(list(jobs))

    stale = _violations(run(None))
    rc = OnlineRecharacterizer()
    online = _violations(run(rc))
    assert rc.refreshes >= 1
    assert online < stale


def test_seed_oracle_installs_inverse_factors(configdict):
    from repro.core.simulator import Cluster
    fleet = synth_fleet(1, 2, 2)
    cluster = Cluster(configdict, fleet)
    rc = OnlineRecharacterizer(detect=False)
    rc.seed(cluster, worker_factors={fleet[0].name: 4.0},
            engine_factors={"gemma-2b/bf16": 2.0})
    assert rc.refreshes == 1 and rc.last_reason == "seed"
    ov = profile_overlay(configdict, rc.profile)
    names = tuple(w.name for w in fleet)
    f = ov.factors("gemma-2b/bf16", names)
    assert f[0] == pytest.approx(1.0 / 8.0)    # worker 4x * engine 2x
    assert f[1] == pytest.approx(1.0 / 2.0)    # engine factor alone
    g = ov.factors("qwen3-4b/bf16", names)
    assert g[0] == pytest.approx(1.0 / 4.0)
    assert g[1] == pytest.approx(1.0)


# ----------------------------------------------------------------------------
# DegradationEvent mechanics

def test_degradation_scales_solo_service_exactly(configdict):
    """With exec noise off, a whole-run degradation makes every job's
    solo service seconds exactly ``factor *`` the profile model's own
    prediction — the clean form of the drift observable."""
    fleet = synth_fleet(1, 0, 0)
    jobs = scenario(configdict, "poisson", n_jobs=20, fleet=fleet,
                    seed=2, utilization=0.5)
    span = jobs[-1].arrival + 1e6
    deg = [DegradationEvent(fleet[0].name, 0.0, span, factor=3.0)]
    healthy = Simulator(configdict, SynergAI(), fleet=fleet, seed=2,
                        exec_noise=0.0).run(list(jobs))
    for r in healthy:
        assert r.service_s == pytest.approx(r.service_pred_s, rel=1e-12)
    slow = Simulator(configdict, SynergAI(), fleet=fleet, seed=2,
                     exec_noise=0.0, degradations=deg).run(list(jobs))
    for r in slow:
        assert r.service_s == pytest.approx(3.0 * r.service_pred_s,
                                            rel=1e-12)


class _SlowdownProbe(SynergAI):
    name = "SlowdownProbe"

    def __init__(self, worker, **kw):
        super().__init__(**kw)
        self.worker = worker
        self.seen = []

    def schedule(self, now, queue, cluster):
        ws = cluster.workers.get(self.worker)
        if ws is not None:
            self.seen.append((now, ws.slowdown))
        return super().schedule(now, queue, cluster)


def test_overlapping_degradations_compose_and_restore(configdict):
    """Two overlapping windows compose multiplicatively and expire to an
    exact 1.0 (no float residue)."""
    fleet = synth_fleet(1, 1, 1)
    w = fleet[0].name
    jobs = scenario(configdict, "poisson", n_jobs=120, fleet=fleet,
                    seed=4, utilization=0.8)
    span = jobs[-1].arrival
    degs = [DegradationEvent(w, span * 0.2, span * 0.4, factor=2.0),
            DegradationEvent(w, span * 0.4, span * 0.1, factor=3.0)]
    probe = _SlowdownProbe(w)
    Simulator(configdict, probe, fleet=fleet, seed=4,
              degradations=degs).run(jobs)
    levels = {s for _, s in probe.seen}
    assert 6.0 in levels                       # overlap: 2 * 3
    assert 2.0 in levels                       # first window alone
    final = [s for t, s in probe.seen if t > span * 0.7]
    assert final and all(s == 1.0 for s in final)   # exact restore


def test_synth_degradations_validation_and_shape():
    fleet = synth_fleet(2, 2, 2)
    with pytest.raises(ValueError):
        synth_degradations(fleet, 100.0, factor=0.0)
    with pytest.raises(ValueError):
        synth_degradations(fleet, 100.0, fraction=0.0)
    with pytest.raises(ValueError):
        synth_degradations(fleet, 100.0, prefix="nope")
    degs = synth_degradations(fleet, 900.0, factor=3.0, fraction=1.0,
                              prefix="edge", seed=1)
    assert degs and all(d.worker.startswith("edge") for d in degs)
    assert all(d.at >= 300.0 for d in degs)    # anchor window first
    assert all(2.4 <= d.factor <= 3.6 for d in degs)
    assert degs == sorted(degs, key=lambda d: d.at)


def test_service_residual_observable_is_noise_only(configdict):
    """log(service_s / service_pred_s) on a healthy fleet is exactly
    the exec-noise distribution (mean -sigma^2/2, sigma=0.2) in *both*
    serving modes — the property that keeps the detector quiet under
    batching, load swings and transfers."""
    fleet = synth_fleet(1, 2, 2)
    for serving in ("job", "batched"):
        jobs = scenario(configdict, "mmpp", n_jobs=300, fleet=fleet,
                        seed=5, utilization=1.2, serving=serving)
        res = Simulator(configdict, SynergAI(), fleet=fleet, seed=5,
                        serving=serving).run(jobs)
        lr = np.array([math.log(r.service_s / r.service_pred_s)
                       for r in res
                       if not math.isnan(r.service_s)
                       and (r.prefill_worker is None
                            or r.prefill_worker == r.worker)])
        assert len(lr) >= 250
        assert abs(lr.mean() + 0.02) < 0.05, serving
        assert abs(lr.std() - 0.2) < 0.06, serving


# ----------------------------------------------------------------------------
# profile generation: minimal flush

def test_profile_gen_flushes_exactly_refreshed_engines(configdict):
    """An overlay refresh reclaims exactly the refreshed engines' cached
    rows; every other job's slot survives untouched."""
    from repro.core.simulator import Cluster
    fleet = synth_fleet(1, 2, 2)
    sim = Simulator(configdict, SynergAI(), fleet=fleet)
    cluster = sim.cluster
    jobs = scenario(configdict, "poisson", n_jobs=60, fleet=fleet,
                    seed=6)
    engines = {j.engine for j in jobs}
    assert len(engines) >= 2
    rc = OnlineRecharacterizer()
    cache = ScoreCache(profile=rc.profile)
    cache.sync(configdict, jobs, cluster)
    slots_before = dict(cache._slot)
    gen0 = profile_gen(configdict, rc.profile)
    target = sorted(engines)[0]
    profile_overlay(configdict, rc.profile).apply(
        {target: {fleet[0].name: 0.5}})
    assert profile_gen(configdict, rc.profile) == gen0 + 1
    cache.sync(configdict, jobs, cluster)
    touched = [j for j in jobs if j.engine == target]
    assert cache.profile_reclaims == len(touched)
    for j in jobs:
        if j.engine != target:
            assert cache._slot[j.id] == slots_before[j.id]


def test_pristine_profile_gen_is_pinned_zero(configdict):
    assert profile_gen(configdict, 0) == 0
    rc = OnlineRecharacterizer()
    assert profile_gen(configdict, rc.profile) == 0   # never refreshed
    assert rc.profile != 0


# ----------------------------------------------------------------------------
# cached == uncached through refresh / failure / elastic interleavings

def _check_cached_equals_uncached_with_rc(seed, kind, utilization,
                                          serving, failures=False,
                                          elastic=0, factor=4.0):
    cd = _cd()
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, kind, n_jobs=400, fleet=fleet, seed=seed,
                    utilization=utilization, serving=serving)
    span = jobs[-1].arrival
    kw = dict(fleet=fleet, seed=seed, serving=serving,
              degradations=synth_degradations(fleet, span, factor=factor,
                                              fraction=0.5, seed=seed))
    if failures:
        kw["failures"] = synth_failures(fleet, span, mtbf_s=span / 2,
                                        mttr_s=60.0, seed=seed)
    if elastic:
        kw.update(elastic_max=elastic, elastic_threshold=4)
    rc_a, rc_b = OnlineRecharacterizer(), OnlineRecharacterizer()
    a = _result_key(Simulator(cd, SynergAI(recharacterizer=rc_a),
                              **kw).run(list(jobs)))
    b = _result_key(Simulator(
        cd, SynergAI(recharacterizer=rc_b, incremental=False),
        **kw).run(list(jobs)))
    assert a == b
    assert rc_a.refreshes == rc_b.refreshes
    return rc_a


def test_cached_equals_uncached_through_refresh():
    rc = _check_cached_equals_uncached_with_rc(0, "mmpp", 0.7, "job")
    assert rc.refreshes >= 1        # the interleaving actually refreshed


def test_cached_equals_uncached_refresh_failures_elastic():
    _check_cached_equals_uncached_with_rc(1, "mmpp", 0.9, "job",
                                          failures=True)
    _check_cached_equals_uncached_with_rc(2, "flash", 1.1, "job",
                                          elastic=2)
    _check_cached_equals_uncached_with_rc(3, "poisson", 0.8, "batched")


# ----------------------------------------------------------------------------
# hypothesis properties (conftest shim: skip cleanly without hypothesis)

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["poisson", "mmpp", "multi-tenant"]),
       serving=st.sampled_from(["job", "batched"]))
def test_no_drift_never_triggers_property(seed, kind, serving):
    """Stationary traffic on a healthy fleet never triggers a refresh,
    and the enabled detector leaves the schedule bit-for-bit."""
    _check_quiet(kind, serving,
                 lambda rc: SynergAI(recharacterizer=rc), seed=seed,
                 n_jobs=220, utilization=1.0)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000),
       utilization=st.floats(0.6, 1.2),
       factor=st.floats(2.5, 6.0))
def test_cached_equals_uncached_with_refresh_property(seed, utilization,
                                                      factor):
    """Incremental and uncached SynergAI stay identical through any
    drift + refresh interleaving hypothesis finds."""
    _check_cached_equals_uncached_with_rc(seed, "mmpp", utilization,
                                          "job", factor=factor)


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_mix_window_anchored_tv_property(data):
    """The mix window never fires while the mix matches its anchor and
    always fires after ``confirm`` windows of a disjoint mix."""
    engines = sorted(engine_catalogue())[:4]
    window, confirm = 32, 2
    mw = _MixWindow(window, 0.3, confirm)
    steady = data.draw(st.lists(st.sampled_from(engines[:2]),
                                min_size=window, max_size=window))
    for _ in range(3):                      # anchor + 2 matching windows
        assert not any(mw.add(e) for e in steady)
    fired = []
    for _ in range(confirm + 1):            # disjoint mix: TV = 1.0
        for e in data.draw(st.lists(st.sampled_from(engines[2:]),
                                    min_size=window, max_size=window)):
            fired.append(mw.add(e))
    assert any(fired)
    mw.reset()
    assert mw.anchor is None and mw.streak == 0


def test_resid_window_fires_on_shift_not_on_noise():
    """Seeded fallback for the detector internals: stationary lognormal
    noise never fires; a sustained 3x one-worker shift does."""
    rng = np.random.default_rng(0)
    rw = _ResidWindow(window=64, threshold=0.35)
    workers = [f"w{i}" for i in range(4)]
    fired = False
    for i in range(64 * 4):
        fired = fired or rw.add("e0", workers[i % 4],
                                float(rng.normal(-0.02, 0.2)))
    assert not fired
    for i in range(64):
        w = workers[i % 4]
        shift = math.log(3.0) if w == "w0" else 0.0
        if rw.add("e0", w, float(rng.normal(-0.02 + shift, 0.2))):
            fired = True
            break
    assert fired


def test_refit_gates_noise_to_zero_updates(configdict):
    """A trigger with no real physics deviation re-fits to zero updates
    (the schedule-preserving rule for mix-triggered refreshes)."""
    from repro.core.simulator import Cluster
    rng = np.random.default_rng(1)
    fleet = synth_fleet(1, 1, 1)
    cluster = Cluster(configdict, fleet)
    rc = OnlineRecharacterizer()
    names = [w.name for w in fleet]
    for i in range(rc.window):              # anchor window: pure noise
        rc._resid.add("gemma-2b/bf16", names[i % len(names)],
                      float(rng.normal(-0.02, 0.2)))
    for i in range(rc.window):              # second window: still noise
        rc._resid.add("gemma-2b/bf16", names[i % len(names)],
                      float(rng.normal(-0.02, 0.2)))
    assert rc._refit(cluster) == {}
    rc.refresh(cluster, now=123.0)
    assert rc.refreshes == 0 and rc.triggered_at == []
