"""Fault-tolerance & scale features: speculative straggler re-dispatch,
elastic pool scaling, checkpoint/restart resume equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.job import make_experiment
from repro.core.metrics import summarize
from repro.core.scheduler import SynergAI
from repro.core.simulator import FailureEvent, Simulator


def test_speculative_redispatch_beats_stragglers(configdict):
    jobs = make_experiment(configdict, "DL", "FL", seed=9)
    kw = dict(exec_noise=0.0, straggler_prob=0.3, straggler_factor=6.0,
              seed=9)
    plain = Simulator(configdict, SynergAI(), speculative=False, **kw)
    spec = Simulator(configdict, SynergAI(), speculative=True, **kw)
    r_plain = plain.run(jobs)
    r_spec = spec.run(jobs)
    assert len(r_spec) == len(jobs)
    e2e_plain = sum(r.e2e for r in r_plain)
    e2e_spec = sum(r.e2e for r in r_spec)
    assert e2e_spec < e2e_plain, "speculation should cut straggler latency"
    assert any(r.speculated for r in r_spec)


def test_elastic_scaling_reduces_violations(configdict):
    # triple arrival intensity to force queue pressure
    jobs = make_experiment(configdict, "DH", "FH", seed=4, intensity=12.0)
    fixed = Simulator(configdict, SynergAI(), seed=4)
    elastic = Simulator(configdict, SynergAI(), elastic_max=3,
                        elastic_threshold=4, seed=4)
    s_fixed = summarize(fixed.run(jobs))
    s_elastic = summarize(elastic.run(jobs))
    assert len(elastic.cluster.workers) >= 4 or elastic._clones >= 0
    assert s_elastic["violations"] <= s_fixed["violations"]
    assert s_elastic["waiting_avg_s"] <= s_fixed["waiting_avg_s"] + 1e-9


def test_failure_plus_speculation_still_conserves(configdict):
    jobs = make_experiment(configdict, "DL", "FH", seed=2)
    sim = Simulator(configdict, SynergAI(), speculative=True,
                    failures=[FailureEvent("edge-large", 30.0, 200.0)],
                    straggler_prob=0.2, seed=2)
    res = sim.run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)


def test_training_resume_equivalence(tmp_path):
    """Restarting from a checkpoint reproduces the uninterrupted run."""
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.models.registry import build_model
    from repro.training import checkpoint
    from repro.training.data import DataLoader
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import init_train_state, make_train_step

    cfg = reduced(get_config("h2o-danube-1.8b"))
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    def batches(n, seed=0):
        gen = DataLoader(cfg.vocab, 4, 16, seed=seed)
        out = [next(gen) for _ in range(n)]
        gen.close()
        return [{k: jnp.asarray(v) for k, v in b.items()} for b in out]

    bs = batches(10)
    # uninterrupted run
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    for b in bs:
        state, _ = step_fn(state, b)
    ref_loss = float(step_fn(state, bs[0])[1]["loss"])

    # interrupted run: checkpoint at step 5, restore, continue
    state2 = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    for b in bs[:5]:
        state2, _ = step_fn(state2, b)
    checkpoint.save(str(tmp_path), 5, state2)
    restored = checkpoint.restore(str(tmp_path),
                                  jax.tree.map(np.asarray, state2))
    restored = jax.tree.map(jnp.asarray, restored)
    for b in bs[5:]:
        restored, _ = step_fn(restored, b)
    resumed_loss = float(step_fn(restored, bs[0])[1]["loss"])
    assert np.isclose(ref_loss, resumed_loss, rtol=1e-5), (
        ref_loss, resumed_loss)
