import os
import sys

# tests run on the single real CPU device (the 512-device override is only
# ever set inside launch/dryrun.py, per the dry-run contract)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def configdict():
    from repro.core.offline import characterize
    return characterize()


# ---------------------------------------------------------------------------
# optional-dependency shim: hypothesis property tests skip cleanly when the
# library isn't installed, while every other test still collects and runs.
# Test modules use ``from conftest import given, settings, st``.

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    class _MissingStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _MissingStrategies()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = f.__name__
            return stub
        return deco
