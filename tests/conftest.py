import os
import sys

# tests run on the single real CPU device (the 512-device override is only
# ever set inside launch/dryrun.py, per the dry-run contract)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture(scope="session")
def configdict():
    from repro.core.offline import characterize
    return characterize()
