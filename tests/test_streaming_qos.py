"""Streaming QoS (TTFT/TPOT) + prefill/decode-disaggregated pools.

Covers the PR's acceptance anchors: the batched simulator with no
streaming deadlines and no disaggregation reproduces the pre-streaming
(PR 2) results bit-for-bit (golden digest), per-request TTFT/TPOT values
are pinned on a tiny seed-pinned scenario so event-heap refactors cannot
silently shift streaming numbers, failures mid-prefill re-dispatch
without double-counting decode tokens, and ``bench_streaming``'s
disaggregated fleet beats the aggregated one on TTFT violations under
the mmpp overload preset."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core.baselines import RoundRobin
from repro.core.engines import default_engines
from repro.core.job import Job, Request, streaming_threshold
from repro.core.metrics import summarize, summarize_by_tenant
from repro.core.scheduler import SynergAI
from repro.core.serving_bridge import (batch_profile, batch_stats,
                                       kv_transfer_s, solo_service)
from repro.core.simulator import FailureEvent, Simulator
from repro.core.slo_mael import SloMael
from repro.core.workers import synth_fleet
from repro.core.workload import (PoissonArrivals, TenantSpec,
                                 attach_requests, make_workload, scenario)

ENGINE = "gemma-2b/bf16"


# ----------------------------------------------------------------------------
# golden digests: PR 2 reproduction + pinned streaming numbers

# Captured from the pre-streaming serving bridge (PR 2 code) on
# scenario(mmpp, n_jobs=40, synth_fleet(1, 2, 2), seed=7, utilization=1.2,
# serving="batched") under SynergAI, seed=7: (id, worker, start, end,
# exec_s, violated).  The streaming/disaggregation machinery must leave
# every one of these bit-level intact when no deadlines are set and
# disaggregation is off.
PR2_GOLDEN = [
    (0, 'cloud-pod', 11.300764261041577, 17.570153136205573,
     6.269388875163997, False),
    (3, 'edge-large__2', 29.711197567719314, 33.96497085060364,
     4.2537732828843255, False),
    (14, 'edge-large', 162.11386962619943, 233.37023248539643,
     71.256362859197, False),
    (22, 'cloud-pod', 192.66509001339668, 197.2729017708007,
     4.607811757404022, False),
    (31, 'edge-small', 193.8175910790733, 200.5086758612955,
     6.691084782222191, False),
    (39, 'cloud-pod', 209.81748451162554, 215.80883106828557,
     5.991346556660032, False),
]

# Per-request (ttft, tpot) on scenario(poisson, n_jobs=12,
# synth_fleet(1, 1, 1), seed=11, utilization=1.0, serving="batched")
# under SynergAI, seed=11.
STREAM_GOLDEN = [
    (0, 1.282354669002056, 3.542578364441039e-05),
    (1, 2.9592339144720947, 0.00015592907759862386),
    (2, 1.9254544833942653, 3.1262542695471705e-05),
    (3, 1.8797090695006702, 0.0001095340832446187),
    (4, 4.208632470252402, 3.810724351115139e-05),
    (5, 1.2696845805506527, 2.972844108969736e-05),
    (6, 1.7388397034083773, 7.368236800886053e-05),
    (7, 1.6181216483347818, 2.4197844486390866e-05),
    (8, 2.2665180078563125, 0.00012469447267886904),
    (9, 1.288530958038244, 2.795324589033711e-05),
    (10, 8.94623761649482, 7.762049841819115e-05),
    (11, 9.957773879416635, 0.00017934001456500084),
]


def test_pr2_batched_results_reproduced_bitforbit(configdict):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, SynergAI(), fleet=fleet, seed=7,
                     serving="batched").run(jobs)}
    assert len(res) == 40
    for jid, worker, start, end, exec_s, violated in PR2_GOLDEN:
        r = res[jid]
        assert r.worker == worker
        assert r.start == pytest.approx(start, rel=1e-9)
        assert r.end == pytest.approx(end, rel=1e-9)
        assert r.exec_s == pytest.approx(exec_s, rel=1e-9)
        assert r.violated == violated
        # no deadlines -> streaming flags are inert
        assert not r.ttft_violated and not r.tpot_violated
        assert r.prefill_worker is None


def test_golden_ttft_tpot_values(configdict):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "poisson", n_jobs=12, fleet=fleet,
                    seed=11, utilization=1.0, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, SynergAI(), fleet=fleet, seed=11,
                     serving="batched").run(jobs)}
    for jid, ttft, tpot in STREAM_GOLDEN:
        assert res[jid].ttft == pytest.approx(ttft, rel=1e-9), jid
        assert res[jid].tpot == pytest.approx(tpot, rel=1e-9), jid


# ----------------------------------------------------------------------------
# metrics: both serving modes, invariants, summarize

@pytest.mark.parametrize("serving", ["job", "batched"])
def test_ttft_bounded_by_latency_both_modes(configdict, serving):
    fleet = synth_fleet(2, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=200, fleet=fleet, seed=5,
                    utilization=1.1, serving=serving)
    res = Simulator(configdict, SynergAI(), fleet=fleet, seed=5,
                    serving=serving).run(jobs)
    assert len(res) == len(jobs)
    for r in res:
        assert 0.0 < r.ttft <= r.e2e + 1e-9
        assert math.isnan(r.tpot) or r.tpot >= 0.0
    s = summarize(res)
    assert s["ttft_violations"] == 0 and s["tpot_violations"] == 0
    assert 0.0 < s["ttft_avg_s"] <= s["ttft_p99_s"]
    assert 0.0 < s["tpot_avg_s"]


def test_job_mode_ttft_is_prefill_share(configdict):
    # solo job, no noise: TTFT is exactly the profiled prefill prefix
    job = Job(0, ENGINE, 1000, 1e6, 0.0)
    sim = Simulator(configdict, SynergAI(), exec_noise=0.0)
    r = sim.run([job])[0]
    ent = configdict.optimal(ENGINE, r.worker)
    spec = default_engines()[ENGINE]
    pool = [w for w in sim.cluster.workers.values()
            if w.pool.name == r.worker][0].pool
    prof = batch_profile(ent, spec, pool)
    _, prefill = solo_service(ent, prof, None, 1000)
    assert r.ttft == pytest.approx(prefill, rel=1e-9)
    assert r.tpot == pytest.approx((r.exec_s - prefill)
                                   / (1000 * spec.decode_len), rel=1e-9)


def test_streaming_threshold_shape(configdict):
    ttft50, tpot50 = streaming_threshold(configdict, ENGINE, 1000, 50.0)
    ttft25, tpot25 = streaming_threshold(configdict, ENGINE, 1000, 25.0)
    assert 0 < ttft25 <= ttft50     # tighter percentile, tighter budget
    assert 0 < tpot25 <= tpot50
    from repro.core.job import qos_threshold
    assert ttft50 < qos_threshold(configdict, ENGINE, 1000, 50.0)


# ----------------------------------------------------------------------------
# deadlines: attachment + scheduler gating

def test_tenant_scales_attach_deadlines(configdict):
    chat = TenantSpec("chat", PoissonArrivals(0.5), 30, engines=(ENGINE,),
                      qos_percentile=25.0, ttft_scale=2.0, tpot_scale=3.0)
    batch = TenantSpec("batch", PoissonArrivals(0.2), 20,
                       engines=(ENGINE,))
    jobs = make_workload(configdict, [chat, batch], seed=0)
    attach_requests(jobs, seed=0, cd=configdict, tenants=[chat, batch])
    ttft_t, tpot_t = streaming_threshold(configdict, ENGINE, 1000, 25.0)
    for j in jobs:
        if j.tenant == "chat":
            assert j.request.ttft_qos == pytest.approx(2.0 * ttft_t)
            assert j.request.tpot_qos == pytest.approx(3.0 * tpot_t)
        else:
            assert j.request.ttft_qos is None
            assert j.request.tpot_qos is None


def test_attach_requests_streaming_needs_cd(configdict):
    chat = TenantSpec("chat", PoissonArrivals(0.5), 5, engines=(ENGINE,),
                      ttft_scale=2.0)
    jobs = make_workload(configdict, [chat], seed=0)
    with pytest.raises(ValueError):
        attach_requests(jobs, seed=0, tenants=[chat])


def test_scenario_streaming_knob(configdict):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "multi-tenant", n_jobs=60, fleet=fleet,
                    seed=2, serving="batched", streaming=(1.5, 2.0))
    assert all(j.request.ttft_qos > 0 and j.request.tpot_qos > 0
               for j in jobs)
    assert all(j.tenant for j in jobs)
    with pytest.raises(ValueError):     # deadlines need token requests
        scenario(configdict, "mmpp", n_jobs=10, serving="job",
                 streaming=(1.5, 2.0))


def test_deadline_violations_flagged_and_gated(configdict):
    spec = default_engines()[ENGINE]
    req_tight = Request(1000 * spec.prefill_len, 1000 * spec.decode_len,
                        ttft_qos=1e-6, tpot_qos=1e-12)   # unmeetable
    req_loose = Request(1000 * spec.prefill_len, 1000 * spec.decode_len,
                        ttft_qos=1e6, tpot_qos=1e3)
    for req, expect in ((req_tight, True), (req_loose, False)):
        job = Job(0, ENGINE, 1000, 1e6, 0.0, request=req)
        res = Simulator(configdict, SynergAI(), exec_noise=0.0,
                        serving="batched").run([job])
        r = res[0]
        assert r.ttft_violated == expect
        assert r.tpot_violated == expect
        assert r.violated == expect     # e2e budget itself is huge


def test_slo_mael_respects_streaming_deadlines(configdict):
    # two workers; the TTFT deadline sits between their default-config
    # prefill prefixes -> SLO-MAEL must plan onto the only pool that
    # meets it (without the deadline it is free to pick either)
    from repro.core.serving_bridge import prefill_prefix
    fleet = synth_fleet(1, 1, 0)
    spec = default_engines()[ENGINE]
    prefills = {w.name: prefill_prefix(
        configdict.default_entry(ENGINE, w.name), 1000) for w in fleet}
    assert len(set(prefills.values())) == 2
    ttft_qos = float(np.mean(list(prefills.values())))
    req = Request(1000 * spec.prefill_len, 1000 * spec.decode_len,
                  ttft_qos=ttft_qos)
    job = Job(0, ENGINE, 1000, 1e6, 0.0, request=req)
    sim = Simulator(configdict, SloMael(), fleet=fleet, exec_noise=0.0,
                    serving="batched")
    r = sim.run([job])[0]
    assert r.worker == min(prefills, key=prefills.get)
    assert prefills[r.worker] <= req.ttft_qos


# ----------------------------------------------------------------------------
# disaggregated pools

def test_synth_fleet_roles():
    fleet = synth_fleet(2, 5, 5, disaggregate=True)
    roles = {w.name: w.role for w in fleet}
    assert set(roles.values()) == {"prefill", "decode"}
    by_arch = {}
    for w in fleet:
        by_arch.setdefault(w.name.split("__")[0], []).append(w.role)
    for arch, rs in by_arch.items():    # both phases inside each archetype
        assert "prefill" in rs and "decode" in rs, arch
    # singleton archetypes keep role "both" (no engine loses a phase)
    assert all(w.role == "both"
               for w in synth_fleet(1, 1, 1, disaggregate=True))
    # plain fleets are untouched
    assert all(w.role == "both" for w in synth_fleet(2, 5, 5))


def test_disaggregated_requires_batched(configdict):
    fleet = synth_fleet(2, 2, 2, disaggregate=True)
    with pytest.raises(ValueError):
        Simulator(configdict, SynergAI(), fleet=fleet)   # job mode


@pytest.mark.parametrize("policy_cls", [SynergAI, SloMael, RoundRobin])
def test_disaggregated_phases_and_conservation(configdict, policy_cls):
    fleet = synth_fleet(2, 3, 3, disaggregate=True)
    jobs = scenario(configdict, "mmpp", n_jobs=120, fleet=fleet, seed=3,
                    utilization=1.0, serving="batched",
                    streaming=(2.0, 2.5))
    sim = Simulator(configdict, policy_cls(), fleet=fleet, seed=3,
                    serving="batched")
    res = sim.run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    for r in res:
        assert r.prefill_worker is not None          # two-phase lifecycle
        assert sim.cluster.workers[r.prefill_worker].pool.role in (
            "prefill", "both")
        assert sim.cluster.workers[r.worker].pool.role in ("decode",
                                                           "both")
        assert 0 < r.ttft <= r.e2e + 1e-9
        assert math.isfinite(r.tpot) and r.tpot > 0
    st = batch_stats(sim.cluster)
    # exact token conservation across the phase split
    assert (sum(v["prefill_tokens"] for v in st.values())
            == sum(j.request.prompt_tokens for j in jobs))
    assert (sum(v["decoded_tokens"] for v in st.values())
            == sum(j.request.decode_tokens for j in jobs))
    # role purity: prefill pools never decode and vice versa
    for name, v in st.items():
        role = sim.cluster.workers[name].pool.role
        if role == "prefill":
            assert v["decoded_tokens"] == 0
        if role == "decode":
            assert v["prefill_tokens"] == 0


def test_kv_transfer_delays_decode(configdict):
    """A lone disaggregated job's end-to-end time is exactly prefill +
    transfer + decode: no queueing, no batching, no noise."""
    spec = default_engines()[ENGINE]
    fleet = synth_fleet(2, 0, 0, disaggregate=True)
    assert [w.role for w in fleet] == ["prefill", "decode"]
    job = Job(0, ENGINE, 800, 1e6, 0.0,
              request=Request(800 * spec.prefill_len,
                              800 * spec.decode_len))
    sim = Simulator(configdict, SynergAI(), fleet=fleet, exec_noise=0.0,
                    serving="batched")
    r = sim.run([job])[0]
    ent = configdict.optimal(ENGINE, "cloud-pod")
    prof = batch_profile(ent, spec, fleet[0])
    work, prefill = solo_service(ent, prof, job.request, 800)
    assert r.ttft == pytest.approx(prefill, rel=1e-9)
    assert r.prefill_worker == "cloud-pod" and r.worker == "cloud-pod__2"
    assert r.e2e == pytest.approx(prefill + kv_transfer_s(prof)
                                  + (work - prefill), rel=1e-9)
    assert r.tpot == pytest.approx((r.e2e - r.ttft)
                                   / job.request.decode_tokens, rel=1e-9)


def test_failure_mid_prefill_no_double_count(configdict):
    """A worker failure mid-prefill re-queues the job; its tokens are
    counted exactly once, wherever the retry lands (the synth_failures /
    elastic interaction gap from the issue)."""
    spec = default_engines()[ENGINE]
    pool = synth_fleet(1, 0, 0)
    req = Request(500 * spec.prefill_len, 500 * spec.decode_len)
    job = Job(0, ENGINE, 500, 1e6, 0.0, request=req)
    ent = configdict.optimal(ENGINE, pool[0].name)
    prof = batch_profile(ent, spec, pool[0])
    _, prefill = solo_service(ent, prof, req, 500)
    fail = FailureEvent(pool[0].name, 0.5 * prefill, 10.0)  # mid-prefill
    sim = Simulator(configdict, SynergAI(), fleet=pool, exec_noise=0.0,
                    serving="batched", failures=[fail])
    r = sim.run([job])[0]
    ws = sim.cluster.workers[pool[0].name]
    assert r.end > fail.at + fail.duration       # served after recovery
    assert ws.prefill_tokens == req.prompt_tokens     # once, not twice
    assert ws.decoded_tokens == req.decode_tokens
    assert ws.admitted == 2                      # but it was admitted twice


def test_disagg_failure_mid_prefill_restarts_once_counted(configdict):
    """Disaggregated variant: prefill-pool failure mid-prefill restarts
    the prefill phase; decode tokens land exactly once on a decode
    pool."""
    spec = default_engines()[ENGINE]
    fleet = synth_fleet(2, 0, 0, disaggregate=True)
    req = Request(500 * spec.prefill_len, 500 * spec.decode_len)
    job = Job(0, ENGINE, 500, 1e6, 0.0, request=req)
    ent = configdict.optimal(ENGINE, "cloud-pod")
    prof = batch_profile(ent, spec, fleet[0])
    _, prefill = solo_service(ent, prof, req, 500)
    fail = FailureEvent("cloud-pod", 0.5 * prefill, 5.0)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, exec_noise=0.0,
                    serving="batched", failures=[fail])
    r = sim.run([job])[0]
    pre_ws = sim.cluster.workers["cloud-pod"]
    dec_ws = sim.cluster.workers["cloud-pod__2"]
    assert pre_ws.prefill_tokens == req.prompt_tokens
    assert pre_ws.decoded_tokens == 0
    assert dec_ws.decoded_tokens == req.decode_tokens
    assert dec_ws.prefill_tokens == 0
    assert r.ttft >= fail.at + fail.duration     # prefill restarted


def test_pull_staging_same_pool_zero_transfer(configdict):
    """Pull-style KV staging (ROADMAP open item): a disaggregated
    handoff whose decode leg lands back on the same ``role="both"`` pool
    must not pay the DISAGG_XFER link — the cache never moves."""
    from repro.core.workers import default_fleet
    spec = default_engines()[ENGINE]
    cloud, _, small = default_fleet()
    fleet = [cloud, dataclasses.replace(small, role="prefill")]
    job = Job(0, ENGINE, 800, 1e6, 0.0,
              request=Request(800 * spec.prefill_len,
                              800 * spec.decode_len))
    sim = Simulator(configdict, SynergAI(), fleet=fleet, exec_noise=0.0,
                    serving="batched")
    r = sim.run([job])[0]
    assert r.prefill_worker == "cloud-pod" and r.worker == "cloud-pod"
    ent = configdict.optimal(ENGINE, "cloud-pod")
    prof = batch_profile(ent, spec, cloud)
    work, prefill = solo_service(ent, prof, job.request, 800)
    assert r.ttft == pytest.approx(prefill, rel=1e-9)
    assert r.e2e == pytest.approx(work, rel=1e-9)     # no transfer paid
    assert kv_transfer_s(prof) > 0                    # it would have cost


def test_pull_staging_cross_pool_pays_at_admission(configdict):
    """A cache parked on a ``role="both"`` pool whose decode leg moves to
    a *different* pool still pays the link — charged at decode admission,
    so the end-to-end time is exactly prefill + transfer + decode."""
    from repro.core.workers import default_fleet
    spec = default_engines()[ENGINE]
    cloud, large, _ = default_fleet()
    fleet = [dataclasses.replace(large, role="both"),
             dataclasses.replace(cloud, role="decode")]
    job = Job(0, ENGINE, 800, 1e6, 0.0,
              request=Request(800 * spec.prefill_len,
                              800 * spec.decode_len))
    sim = Simulator(configdict, SynergAI(), fleet=fleet, exec_noise=0.0,
                    serving="batched")
    r = sim.run([job])[0]
    assert r.prefill_worker == "edge-large"     # the only prefill pool
    assert r.worker == "cloud-pod"              # faster decode wins
    ent_l = configdict.optimal(ENGINE, "edge-large")
    ent_c = configdict.optimal(ENGINE, "cloud-pod")
    prof_l = batch_profile(ent_l, spec, large)
    prof_c = batch_profile(ent_c, spec, cloud)
    _, prefill_l = solo_service(ent_l, prof_l, job.request, 800)
    work_c, prefill_c = solo_service(ent_c, prof_c, job.request, 800)
    assert r.ttft == pytest.approx(prefill_l, rel=1e-9)
    assert r.e2e == pytest.approx(prefill_l + kv_transfer_s(prof_l)
                                  + (work_c - prefill_c), rel=1e-9)


def test_pull_staging_parked_kv_lost_on_prefill_pool_failure(configdict):
    """A ``role="both"`` pool that dies while a handed-off cache is still
    parked on it (decode leg queued, not yet admitted) loses the cache:
    the job re-prefills after recovery.  A scripted policy parks the
    decode leg across the failure window to pin the sequence."""
    from repro.core.simulator import Assignment, Policy

    spec = default_engines()[ENGINE]
    fleet = synth_fleet(2, 0, 0, disaggregate=True)
    fleet = [dataclasses.replace(fleet[0], role="both"), fleet[1]]
    req = Request(500 * spec.prefill_len, 500 * spec.decode_len)
    job = Job(0, ENGINE, 500, 1e6, 0.0, request=req)
    ent = configdict.optimal(ENGINE, "cloud-pod")
    prof = batch_profile(ent, spec, fleet[0])
    work, prefill = solo_service(ent, prof, req, 500)
    fail = FailureEvent("cloud-pod", 1.5 * prefill, 5.0)   # cache parked
    recover = fail.at + fail.duration

    class Scripted(Policy):
        name = "scripted"
        use_default_config = False

        def schedule(self, now, queue, cluster):
            out = []
            for j in queue:
                if cluster.phase_of(j) == "decode" and now < recover:
                    continue        # hold the decode leg: keep it parked
                for w in cluster.workers:
                    if (cluster.admit_ok(j, w, now)
                            and cluster.feasible(j.engine, w, False)):
                        out.append(Assignment(
                            j, w, configdict.optimal(j.engine, w)))
                        break
            return out

    sim = Simulator(configdict, Scripted(), fleet=fleet, exec_noise=0.0,
                    serving="batched", failures=[fail])
    r = sim.run([job])[0]
    ws = sim.cluster.workers["cloud-pod"]
    # the parked cache died with the pool: prefill ran twice, the second
    # one after recovery, and the decode leg (same pool) paid no link
    assert r.ttft == pytest.approx(recover + prefill, rel=1e-9)
    assert r.prefill_worker == "cloud-pod" and r.worker == "cloud-pod"
    assert r.e2e == pytest.approx(recover + work, rel=1e-9)
    assert ws.prefill_tokens == 2 * req.prompt_tokens   # honest double work
    assert ws.decoded_tokens == req.decode_tokens
    assert sim.cluster.workers["cloud-pod__2"].admitted == 0


def test_summarize_by_tenant_groups(configdict):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "multi-tenant", n_jobs=90, fleet=fleet,
                    seed=4, serving="batched")
    res = Simulator(configdict, SynergAI(), fleet=fleet, seed=4,
                    serving="batched").run(jobs)
    per = summarize_by_tenant(res)
    assert set(per) == {j.tenant for j in jobs}
    assert sum(s["jobs"] for s in per.values()) == len(res)


# ----------------------------------------------------------------------------
# the acceptance bench: disaggregation cuts TTFT violations

def _bench_streaming():
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import bench_streaming
    return bench_streaming


def test_bench_streaming_disagg_beats_agg_on_ttft(configdict):
    bench_streaming = _bench_streaming()
    out = bench_streaming(configdict, emit=lambda *_: None)
    agg = out[("aggregated", "SynergAI")]
    dis = out[("disaggregated", "SynergAI")]
    assert agg["ttft_violations"] > 0          # overload actually bites
    assert dis["ttft_violations"] < agg["ttft_violations"]


@pytest.mark.slow
def test_bench_streaming_slow_acceptance(configdict):
    bench_streaming = _bench_streaming()
    out = bench_streaming(configdict, n_jobs=4000, pools=(3, 8, 8),
                          emit=lambda *_: None)
    agg = out[("aggregated", "SynergAI")]
    dis = out[("disaggregated", "SynergAI")]
    assert dis["ttft_violations"] < agg["ttft_violations"]
    assert dis["ttft_p99_s"] < agg["ttft_p99_s"]
