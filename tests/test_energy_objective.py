"""Energy/carbon-aware orchestration objective + fleet-correct accounting.

Covers the PR's acceptance anchors: ``energy_weight=0`` leaves every
golden digest bit-for-bit unchanged (flat and hierarchical, both serving
modes, deterministic runs + a hypothesis property behind the conftest
shim), the incremental score cache stays bit-identical to the uncached
path through the new energy rows, every busy second is billed exactly
once across speculative handoff and disaggregated WAN-transfer legs
(idle-floor re-rating), ``offload_fraction``/``normalized_edge_energy``
resolve replicated and disjoint fleets correctly, ``CarbonTrace`` moves
the cleanest region over the trace, ``power_capped_fleet`` throttles
instead of failing, and the ``bench_energy`` smoke leg runs."""

import dataclasses
import functools
import math
import os
import sys

import numpy as np
import pytest
from conftest import given, settings, st
from test_streaming_qos import PR2_GOLDEN, STREAM_GOLDEN
from test_trace_replay import _result_key

from repro.core.constants import CHIP_TDP_W, IDLE_POWER_FRACTION
from repro.core.energy import normalized_edge_energy, offload_fraction
from repro.core.estimator import energy_matrix
from repro.core.hierarchy import HierarchicalSynergAI
from repro.core.job import Job
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.scorecache import ScoreCache
from repro.core.simulator import Assignment, Cluster, Policy, Simulator
from repro.core.workers import (default_fleet, power_capped_fleet,
                                synth_fleet)
from repro.core.workload import CarbonTrace, scenario

ENGINE = "gemma-2b/bf16"


@functools.lru_cache(maxsize=None)
def _cd():
    # session-style cache that doesn't tangle pytest fixtures with @given
    return characterize()


# ----------------------------------------------------------------------------
# energy_weight=0 is bit-for-bit inert: golden digests unchanged


@pytest.mark.parametrize("mk", [
    lambda: SynergAI(energy_weight=0.0),
    lambda: HierarchicalSynergAI(energy_weight=0.0),
], ids=["flat", "hier"])
def test_zero_weight_reproduces_pr2_batched_golden(configdict, mk):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, mk(), fleet=fleet, seed=7,
                     serving="batched").run(jobs)}
    assert len(res) == 40
    for jid, worker, start, end, exec_s, violated in PR2_GOLDEN:
        r = res[jid]
        assert r.worker == worker
        assert r.start == pytest.approx(start, rel=1e-9)
        assert r.end == pytest.approx(end, rel=1e-9)
        assert r.exec_s == pytest.approx(exec_s, rel=1e-9)
        assert r.violated == violated


@pytest.mark.parametrize("mk", [
    lambda: SynergAI(energy_weight=0.0),
    lambda: HierarchicalSynergAI(energy_weight=0.0),
], ids=["flat", "hier"])
def test_zero_weight_reproduces_streaming_golden(configdict, mk):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "poisson", n_jobs=12, fleet=fleet,
                    seed=11, utilization=1.0, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, mk(), fleet=fleet, seed=11,
                     serving="batched").run(jobs)}
    for jid, ttft, tpot in STREAM_GOLDEN:
        assert res[jid].ttft == pytest.approx(ttft, rel=1e-9), jid
        assert res[jid].tpot == pytest.approx(tpot, rel=1e-9), jid


def _check_zero_weight_inert(seed, kind, utilization, serving):
    """A zero weight (with or without an attached CarbonTrace) must take
    the exact legacy code path: the full JobResult stream is bit-level
    identical to the default policy, flat and hierarchical."""
    cd = _cd()
    regions = 3 if seed % 2 else 0
    fleet = synth_fleet(1, 2, 2, regions=regions)
    jobs = scenario(cd, kind, n_jobs=80, fleet=fleet, seed=seed,
                    utilization=utilization, serving=serving)
    trace = CarbonTrace.synth(sorted({w.region for w in fleet}))

    def run(pol):
        return _result_key(Simulator(cd, pol, fleet=fleet, seed=seed,
                                     serving=serving).run(list(jobs)))

    ref = run(SynergAI())
    assert run(SynergAI(energy_weight=0.0)) == ref
    assert run(SynergAI(energy_weight=0.0, carbon=trace)) == ref
    href = run(HierarchicalSynergAI())
    assert run(HierarchicalSynergAI(energy_weight=0.0,
                                    carbon=trace)) == href


@pytest.mark.parametrize("seed,kind,serving", [
    (1, "mmpp", "job"),
    (2, "poisson", "batched"),
    (3, "mmpp", "batched"),
])
def test_zero_weight_inert_seeded(seed, kind, serving):
    _check_zero_weight_inert(seed, kind, 1.2, serving)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       kind=st.sampled_from(["poisson", "mmpp"]),
       utilization=st.floats(0.5, 1.5),
       serving=st.sampled_from(["job", "batched"]))
def test_zero_weight_inert_property(seed, kind, utilization, serving):
    _check_zero_weight_inert(seed, kind, utilization, serving)


# ----------------------------------------------------------------------------
# incremental == uncached through the new energy rows


@pytest.mark.parametrize("serving,failures,elastic", [
    ("job", False, 0),
    ("batched", False, 0),
    ("job", True, 2),
])
def test_energy_weight_cached_equals_uncached(serving, failures, elastic):
    cd = _cd()
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, "mmpp", n_jobs=120, fleet=fleet, seed=5,
                    utilization=1.2, serving=serving)
    kw = dict(fleet=fleet, seed=5, serving=serving)
    if failures:
        from repro.core.workload import synth_failures
        span = jobs[-1].arrival
        kw["failures"] = synth_failures(fleet, span, mtbf_s=span / 2,
                                        mttr_s=60.0, seed=5)
    if elastic:
        kw.update(elastic_max=elastic, elastic_threshold=4)
    trace = CarbonTrace.synth(["r0"])
    a = _result_key(Simulator(
        cd, SynergAI(energy_weight=0.05, carbon=trace),
        **kw).run(list(jobs)))
    b = _result_key(Simulator(
        cd, SynergAI(energy_weight=0.05, carbon=trace, incremental=False),
        **kw).run(list(jobs)))
    assert a == b


def test_energy_rows_match_estimator_through_extension_and_flush(
        configdict):
    """The cached energy rows equal a fresh ``estimator.energy_matrix``
    after first materialization, after an elastic column append, and
    after a failure flush — the same invalidation rules as every other
    cached row."""
    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    cluster = Simulator(cd, SynergAI(), fleet=fleet).cluster
    jobs = [Job(i, ENGINE, 300 + 10 * i, 60.0, float(i))
            for i in range(6)]
    cache = ScoreCache()
    slots = cache.sync(cd, jobs, cluster)
    cache.ensure_energy_rows(cd, jobs, slots, cluster)
    names = cluster.arrays.names
    ref = energy_matrix(cd, jobs, names)
    np.testing.assert_array_equal(cache.energy_matrix(slots), ref)
    assert np.all(np.isfinite(ref)) and np.all(ref > 0)
    # elastic clone append: columns extend in place, rows stay exact
    base = cluster.workers["cloud-pod"].pool
    clone = dataclasses.replace(base, name="cloud-pod__clone1")
    cluster.workers[clone.name] = cluster._make_worker(clone)
    slots2 = cache.sync(cd, jobs, cluster)
    assert cache.col_extends == 1 and cache.flushes == 0
    ref2 = energy_matrix(cd, jobs, cluster.arrays.names)
    np.testing.assert_array_equal(cache.energy_matrix(slots2), ref2)
    # a clone shares the archetype profile: identical joules column
    np.testing.assert_array_equal(
        cache.energy_row(slots2[0])[cluster.arrays.names.index(
            clone.name)],
        cache.energy_row(slots2[0])[cluster.arrays.names.index(
            "cloud-pod")])
    # failure flush drops the rows; the next ensure rebuilds them
    cluster.workers["edge-large"].failed_until = 50.0
    slots3 = cache.sync(cd, jobs, cluster)
    assert cache.flushes == 1
    cache.ensure_energy_rows(cd, jobs, slots3, cluster)
    np.testing.assert_array_equal(
        cache.energy_matrix(slots3),
        energy_matrix(cd, jobs, cluster.arrays.names))


def test_negative_energy_weight_raises():
    with pytest.raises(ValueError):
        SynergAI(energy_weight=-0.1)
    with pytest.raises(ValueError):
        HierarchicalSynergAI(energy_weight=-0.1)


# ----------------------------------------------------------------------------
# the objective steers: energy falls, QoS holds


def test_energy_steering_reduces_energy_not_qos(configdict):
    """With headroom, the weighted term moves work off the per-query
    energy hog (the cloud pod) among *acceptable* workers: active energy
    and offload drop, deadline misses don't rise (acceptability and doom
    stay purely time-derived)."""
    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "mmpp", n_jobs=250, fleet=fleet, seed=3,
                    utilization=0.6)
    runs = {}
    for name, ew in (("blind", 0.0), ("aware", 1e-2)):
        sim = Simulator(configdict, SynergAI(energy_weight=ew),
                        fleet=fleet, seed=3)
        res = sim.run(list(jobs))
        runs[name] = (
            sum(w.energy_j for w in sim.cluster.workers.values()),
            offload_fraction(res, sim.cluster),
            sum(r.violated for r in res))
    e_blind, off_blind, v_blind = runs["blind"]
    e_aware, off_aware, v_aware = runs["aware"]
    assert e_aware < e_blind
    assert off_aware < off_blind
    assert v_aware <= v_blind


def test_carbon_aware_hierarchy_cuts_carbon(configdict):
    """Carbon-weighted hierarchical routing (router aggregates scaled by
    per-region relative intensity) lowers post-hoc carbon vs the blind
    hierarchy on the same regional trace."""
    fleet = synth_fleet(2, 3, 3, regions=3)
    jobs = scenario(configdict, "mmpp", n_jobs=250, fleet=fleet, seed=3,
                    utilization=0.6)
    trace = CarbonTrace.synth(sorted({w.region for w in fleet}),
                              period_s=2.0 * jobs[-1].arrival)

    def carbon_g(res, cluster):
        return sum(
            configdict.optimal(r.job.engine, r.worker).power_w
            * r.exec_s / 3.6e6
            * trace.intensity(cluster.workers[r.worker].pool.region,
                              0.5 * (r.start + r.end))
            for r in res)

    out = {}
    for name, pol in (("blind", HierarchicalSynergAI()),
                      ("aware", HierarchicalSynergAI(energy_weight=1e-1,
                                                     carbon=trace))):
        sim = Simulator(configdict, pol, fleet=fleet, seed=3)
        res = sim.run(list(jobs))
        out[name] = carbon_g(res, sim.cluster)
    assert out["aware"] < out["blind"]


# ----------------------------------------------------------------------------
# CarbonTrace physics


def test_carbon_trace_units_and_motion():
    regions = ["r0", "r1", "r2"]
    trace = CarbonTrace.synth(regions, period_s=1000.0)
    # synth is deterministic and spreads the base means over [lo, hi]
    again = CarbonTrace.synth(regions, period_s=1000.0)
    assert trace.base == again.base and trace.phase_s == again.phase_s
    assert min(trace.base.values()) == 250.0
    assert max(trace.base.values()) == 700.0
    # relative is dimensionless around the across-region mean
    mean = trace.mean_intensity()
    assert mean == pytest.approx(sum(trace.base.values()) / 3)
    for t in (0.0, 250.0, 990.0):
        rel = trace.relative_for(regions, t)
        assert rel.shape == (3,)
        for i, r in enumerate(regions):
            assert rel[i] == pytest.approx(
                trace.intensity(r, t) / mean)
    # staggered phases move the cleanest region over one period
    cleanest = {trace.cleanest(regions, t)
                for t in np.linspace(0.0, 1000.0, 40)}
    assert len(cleanest) > 1
    # unknown regions read the flat default
    assert trace.intensity("nowhere", 123.0) == trace.default_g
    # relative_for memoizes per distinct region: repeated labels agree
    rep = trace.relative_for(["r0", "r0", "r1"], 42.0)
    assert rep[0] == rep[1] == pytest.approx(trace.relative("r0", 42.0))


# ----------------------------------------------------------------------------
# accounting bugfixes: offload resolution, normalization, conservation


def _mk_result(job, worker):
    from repro.core.simulator import JobResult
    return JobResult(job, worker, "cfg", 0.0, 1.0, 0.0, 1.0, 1.0, False,
                     0.0, 0.0, 0.0)


def test_offload_fraction_resolves_replicated_fleet(configdict):
    """The old ``r.worker == "cloud-pod"`` literal under-counted every
    cloud replica: at fleet scale only 1/n_cloud of offloaded jobs were
    seen.  Edge-vs-cloud now resolves through ``WorkerPool.is_edge``."""
    fleet = synth_fleet(3, 2, 2, regions=2)
    cluster = Cluster(configdict, fleet)
    job = Job(0, ENGINE, 100, 60.0, 0.0)
    results = [_mk_result(job, w) for w in
               ("cloud-pod", "cloud-pod__2", "cloud-pod__3",
                "edge-large", "edge-large__2", "edge-small",
                "edge-small__2")]
    assert offload_fraction(results, cluster) == pytest.approx(3 / 7)
    # elastic clones share the archetype's edge-ness via suffix strip
    results.append(_mk_result(job, "cloud-pod__clone9"))
    assert offload_fraction(results, cluster) == pytest.approx(4 / 8)
    # without a cluster: default_fleet archetypes, suffix-stripped;
    # unknown workers count as edge (conservative: not offloaded)
    assert offload_fraction(
        [_mk_result(job, "cloud-pod__7"),
         _mk_result(job, "edge-small"),
         _mk_result(job, "mystery-box")]) == pytest.approx(1 / 3)
    assert offload_fraction([]) == 0.0


def test_normalized_edge_energy_disjoint_fleets(configdict):
    """Disjoint per-policy fleets: a pool a policy never had is omitted
    from its row (not reported as 0.0), and an all-zero pool normalizes
    to 0.0 instead of dividing by the ``or 1.0`` fallback peak."""
    a = Cluster(configdict, synth_fleet(1, 1, 0))
    b = Cluster(configdict, synth_fleet(1, 0, 1))
    a.workers["edge-large"].energy_j = 500.0
    b.workers["edge-small"].energy_j = 0.0   # ran, burned nothing
    norm = normalized_edge_energy({"A": a, "B": b})
    assert norm["A"] == {"edge-large": 1.0}          # its own peak
    assert "edge-small" not in norm["A"]             # never existed there
    assert norm["B"] == {"edge-small": 0.0}          # zero peak -> 0.0
    assert "edge-large" not in norm["B"]
    # cloud pools never appear in the edge-energy report
    assert "cloud-pod" not in norm["A"]


class _XferPolicy(Policy):
    """Places every job on the sole worker with a fixed WAN prefix."""

    name = "xfer-test"
    use_default_config = False

    def __init__(self, xfer_s):
        self.xfer_s = xfer_s

    def schedule(self, now, queue, cluster):
        out = []
        for job in list(queue):
            for w, ws in cluster.workers.items():
                if ws.idle(now):
                    ent = cluster.cd.optimal(job.engine, w)
                    out.append(Assignment(job, w, ent,
                                          xfer_s=self.xfer_s))
                    break
            break   # one at a time keeps the worker genuinely idle
        return out


def test_job_mode_xfer_billed_at_idle_floor(configdict):
    """The WAN-transfer prefix of a cross-region placement bills at the
    pool's static floor, not full compute draw — the chips wait on the
    wire."""
    fleet = [default_fleet()[0]]                     # cloud-pod only
    jobs = [Job(i, ENGINE, 200, 600.0, 40.0 * i) for i in range(4)]
    sim = Simulator(configdict, _XferPolicy(2.0), fleet=fleet,
                    exec_noise=0.0)
    res = sim.run(jobs)
    assert len(res) == 4
    ent = configdict.optimal(ENGINE, "cloud-pod")
    w = sim.cluster.workers["cloud-pod"]
    assert ent.idle_power_w < ent.power_w
    expect = sum(ent.power_w * (r.exec_s - 2.0) + ent.idle_power_w * 2.0
                 for r in res)
    assert w.energy_j == pytest.approx(expect, rel=1e-12)
    # billed strictly less than the naive full-draw accounting
    assert w.energy_j < ent.power_w * w.busy_s - 1e-9


def test_speculative_handoff_conserves_energy(configdict):
    """Speculative re-dispatch refunds the cancelled tail on the original
    worker: with a single-engine workload every worker's joules equal its
    entry draw times its (refund-adjusted) busy seconds — no second is
    billed twice across the handoff."""
    fleet = synth_fleet(1, 1, 1)
    jobs = [Job(i, ENGINE, 400, 600.0, 2.0 * i) for i in range(40)]
    sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=4,
                    speculative=True, straggler_prob=0.5,
                    straggler_factor=6.0)
    res = sim.run(jobs)
    assert any(r.speculated for r in res)            # path exercised
    for name, w in sim.cluster.workers.items():
        p = configdict.optimal(ENGINE, name).power_w
        assert w.energy_j == pytest.approx(p * w.busy_s, rel=1e-9), name


def test_batched_xfer_debt_conservation(configdict):
    """Disaggregated serving: KV-handoff transfer seconds folded into the
    batch re-rate at the idle floor as the batch drains them —
    ``energy_j == power * busy_s - (power - idle) * xfer_idle_s`` per
    worker for a single-engine trace, with the debt fully paid."""
    fleet = synth_fleet(1, 3, 3, disaggregate=True)
    # overload so decode legs spill off the "both" cloud pod: parked KV
    # caches get *pulled* cross-pool, which is the charged handoff path
    # (push-style handoffs are a pure wire delay — neither pool is busy)
    jobs = scenario(configdict, "poisson", n_jobs=80, fleet=fleet,
                    seed=2, utilization=2.0, serving="batched")
    jobs = [dataclasses.replace(j, engine=ENGINE) for j in jobs]
    sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=2,
                    serving="batched")
    sim.run(jobs)
    paid = 0.0
    for name, w in sim.cluster.workers.items():
        ent = configdict.optimal(ENGINE, name)
        expect = (ent.power_w * w.busy_s
                  - (ent.power_w - ent.idle_power_w) * w.xfer_idle_s)
        assert w.energy_j == pytest.approx(expect, rel=1e-9), name
        assert w.xfer_debt_s == pytest.approx(0.0, abs=1e-9)
        paid += w.xfer_idle_s
    assert paid > 0.0                                # KV pulls happened


def test_idle_floor_physics_and_settle(configdict):
    """Static floor below full draw for every mode; end-of-run settle
    charges parked seconds to ``idle_energy_j`` (kept apart from the
    active Fig. 12 series), ``total_energy_j`` sums both."""
    for pool in default_fleet():
        for m in pool.modes:
            assert 0.0 < m.idle_power_w() <= m.power_w()
            assert m.idle_power_w() == pytest.approx(
                min(m.power_budget_w,
                    CHIP_TDP_W * IDLE_POWER_FRACTION * m.chips_online),
                rel=1e-9)
        assert pool.idle_power_w == min(m.idle_power_w()
                                        for m in pool.modes)
    fleet = synth_fleet(1, 1, 1)
    jobs = [Job(i, ENGINE, 200, 600.0, 5.0 * i) for i in range(10)]
    sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=1)
    res = sim.run(jobs)
    span = max(r.end for r in res)
    for w in sim.cluster.workers.values():
        assert w.idle_energy_j == pytest.approx(
            w.pool.idle_power_w * max(0.0, span - w.busy_s), rel=1e-9)
        assert w.total_energy_j == w.energy_j + w.idle_energy_j
    # race-to-idle is visible: the fleet burns joules even while parked
    assert sum(w.idle_energy_j for w in sim.cluster.workers.values()) > 0


# ----------------------------------------------------------------------------
# energy-capped scenarios


def test_power_capped_fleet_throttles_instead_of_failing(configdict):
    fleet = default_fleet()
    full_draws = {p.name: sorted(m.power_w() for m in p.modes)
                  for p in fleet}
    cap = full_draws["edge-large"][0] + 1.0   # only the lowest mode fits
    capped = power_capped_fleet(fleet, cap)
    by_name = {p.name: p for p in capped}
    # cloud untouched (edge_only), edge pools keep only fitting modes
    assert by_name["cloud-pod"].modes == tuple(fleet[0].modes)
    for name in ("edge-large", "edge-small"):
        assert all(m.power_w() <= cap for m in by_name[name].modes)
        assert len(by_name[name].modes) >= 1
    # a cap below every mode brown-outs to the clamped floor mode
    tiny = power_capped_fleet(fleet, 1.0)
    for p in tiny:
        if not p.is_edge:
            continue
        assert len(p.modes) == 1
        assert p.modes[0].power_budget_w == 1.0
        assert p.modes[0].power_w() <= 1.0
    # the capped fleet re-characterizes feasibly end-to-end
    cd2 = characterize(fleet=capped)
    jobs = [Job(i, ENGINE, 100, 600.0, 10.0 * i) for i in range(6)]
    res = Simulator(cd2, SynergAI(), fleet=capped, seed=0).run(jobs)
    assert len(res) == 6
    for r in res:
        if _is_edge(r.worker):
            assert cd2.optimal(r.job.engine, r.worker).power_w <= cap


def _is_edge(worker):
    pools = {w.name: w for w in default_fleet()}
    pool = pools.get(worker) or pools.get(worker.split("__")[0])
    return pool.is_edge


# ----------------------------------------------------------------------------
# bench smoke


def test_bench_energy_smoke(configdict):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import bench_energy
    blob = bench_energy(configdict, n_jobs=120, smoke=True,
                        emit=lambda *a: None)
    assert blob["bench"] == "bench_energy" and blob["schema"] == 1
    variants = {c["variant"] for c in blob["configs"]}
    assert variants == {"energy-flat-blind", "energy-flat-energy",
                        "energy-flat-carbon", "energy-hier-blind",
                        "energy-hier-carbon"}
    for c in blob["configs"]:
        assert c["total_energy_mj"] > 0 and c["carbon_kg"] > 0
        assert 0.0 <= c["offload"] <= 1.0
        assert c["idle_energy_mj"] >= 0.0
        assert math.isfinite(c["edge_energy_mj"])
    aware = {c["variant"]: c for c in blob["configs"]}
    assert "energy_reduction_vs_blind" in aware["energy-flat-energy"]
    assert "carbon_reduction_vs_blind" in aware["energy-flat-carbon"]
    assert "carbon_reduction_vs_blind" in aware["energy-hier-carbon"]
    # the smoke leg never emits the nightly headline (noise at that size)
    assert "energy_headline" not in blob
