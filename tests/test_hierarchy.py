"""Hierarchical region-sharded scheduling (``repro.core.hierarchy``):
flat equivalence pinned against the PR 2 / PR 4 goldens, region-invariant
properties (every arrival routed to exactly one region, region-local
scoring never reads another region's pools, cross-region transfer cost
charged iff the placement left the routed region, a correlated outage
drains the region aggregate within one tick), the regional workload
calibrator, and the flat-vs-hierarchical bench smoke leg."""

import dataclasses
import functools
import hashlib

import numpy as np
import pytest
from conftest import given, settings, st
from test_streaming_qos import PR2_GOLDEN, STREAM_GOLDEN
from test_trace_replay import REPLAY_GOLDEN_DIGEST, _result_key

from repro.core.hierarchy import HierarchicalSynergAI
from repro.core.job import Job
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import region_groups, synth_fleet
from repro.core.workload import (region_rates, regional_scenario, replay,
                                 save_trace, scenario)


@functools.lru_cache(maxsize=None)
def _cd():
    # session-style cache that doesn't tangle pytest fixtures with @given
    return characterize()


class _Recording(HierarchicalSynergAI):
    """Snapshots each placed job's routed home *before* the tick pops it,
    so tests can check transfer charging against the routing decision."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.record = []        # (job id, home region, pool region, xfer)

    def schedule(self, now, queue, cluster):
        homes = dict(self.router.home) if self.router else {}
        out = super().schedule(now, queue, cluster)
        for a in out:
            self.record.append((a.job.id, homes.get(a.job.id),
                                cluster.workers[a.worker].pool.region,
                                a.xfer_s))
        return out


# ----------------------------------------------------------------------------
# flat equivalence: regions=1 (and untagged) is bit-for-bit flat SynergAI


@pytest.mark.parametrize("serving", ["job", "batched"])
@pytest.mark.parametrize("regions", [0, 1])
def test_flat_equivalence_full_stream(configdict, serving, regions):
    """An untagged or single-region fleet makes the hierarchical policy
    delegate wholesale to flat SynergAI — the full JobResult stream is
    bit-level identical in both serving modes."""
    fleet = synth_fleet(1, 2, 2, regions=regions)
    jobs = scenario(configdict, "mmpp", n_jobs=120, fleet=fleet, seed=4,
                    utilization=1.2, serving=serving)
    flat = Simulator(configdict, SynergAI(), fleet=fleet, seed=4,
                     serving=serving).run(jobs)
    hier = Simulator(configdict, HierarchicalSynergAI(), fleet=fleet,
                     seed=4, serving=serving).run(jobs)
    assert _result_key(flat) == _result_key(hier)


def test_regions1_reproduces_replay_golden_digest(configdict, tmp_path):
    """The PR 4 golden digest (replayed MMPP schedule under flat
    SynergAI, job mode) is reproduced bit-for-bit by the hierarchical
    policy on the regions=1 fleet."""
    jobs = scenario(configdict, "mmpp", n_jobs=40,
                    fleet=synth_fleet(1, 2, 2), seed=7, utilization=1.2)
    path = tmp_path / "golden.jsonl"
    save_trace(path, jobs)
    res = Simulator(configdict, HierarchicalSynergAI(),
                    fleet=synth_fleet(1, 2, 2, regions=1),
                    seed=7).run(replay(str(path)))
    canon = "\n".join(
        f"{r.job.id},{r.worker},{r.config},{r.start!r},{r.end!r},"
        f"{r.ttft!r},{r.tpot!r},{int(r.violated)}"
        for r in sorted(res, key=lambda r: r.job.id))
    assert hashlib.sha256(canon.encode()).hexdigest() == \
        REPLAY_GOLDEN_DIGEST


def test_regions1_reproduces_pr2_batched_golden(configdict):
    """The PR 2 batched golden rows survive the hierarchy unchanged."""
    fleet = synth_fleet(1, 2, 2, regions=1)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, HierarchicalSynergAI(), fleet=fleet,
                     seed=7, serving="batched").run(jobs)}
    assert len(res) == 40
    for jid, worker, start, end, exec_s, violated in PR2_GOLDEN:
        r = res[jid]
        assert r.worker == worker
        assert r.start == pytest.approx(start, rel=1e-9)
        assert r.end == pytest.approx(end, rel=1e-9)
        assert r.exec_s == pytest.approx(exec_s, rel=1e-9)
        assert r.violated == violated


def test_regions1_reproduces_streaming_golden(configdict):
    fleet = synth_fleet(1, 1, 1, regions=1)
    jobs = scenario(configdict, "poisson", n_jobs=12, fleet=fleet,
                    seed=11, utilization=1.0, serving="batched")
    res = {r.job.id: r for r in
           Simulator(configdict, HierarchicalSynergAI(), fleet=fleet,
                     seed=11, serving="batched").run(jobs)}
    for jid, ttft, tpot in STREAM_GOLDEN:
        assert res[jid].ttft == pytest.approx(ttft, rel=1e-9), jid
        assert res[jid].tpot == pytest.approx(tpot, rel=1e-9), jid


# ----------------------------------------------------------------------------
# region invariants


def test_every_arrival_routed_to_exactly_one_region(configdict):
    fleet = synth_fleet(2, 3, 3, regions=3)
    pol = HierarchicalSynergAI()
    sim = Simulator(configdict, pol, fleet=fleet, seed=0)
    jobs = [Job(i, "gemma-2b/bf16", 500, 60.0, float(i)) for i in range(12)]
    for j in jobs:
        pol.on_arrival(j, sim.cluster, j.arrival)
    regions = set(pol.router.regions)
    assert regions == {"r0", "r1", "r2"}
    for j in jobs:
        assert pol.router.home[j.id] in regions
    # re-announcing an arrival must not re-route it
    homes = dict(pol.router.home)
    for j in jobs:
        pol.on_arrival(j, sim.cluster, j.arrival)
    assert pol.router.home == homes


def test_region_view_masks_equal_global_slices(configdict):
    """Every RegionView vector view equals the cluster-wide view sliced
    to the region's columns, bit-for-bit — region-local scoring sees
    exactly what flat scoring would see for those pools."""
    fleet = synth_fleet(2, 4, 4, disaggregate=True, regions=3)
    pol = HierarchicalSynergAI()
    sim = Simulator(configdict, pol, fleet=fleet, seed=0,
                    serving="batched")
    cl = sim.cluster
    pol.on_arrival(Job(0, "gemma-2b/bf16", 500, 60.0, 0.0), cl, 0.0)
    # make the masks non-trivial
    cl.workers["edge-large"].busy_until = 10.0
    cl.workers["edge-small__2"].failed_until = 10.0
    for now in (0.0, 5.0):
        g_avail = cl.avail_array(now)
        g_wait = cl.busy_wait_array(now)
        g_pen = cl.depth_penalty_array(now)
        for v in pol._views.values():
            idx = v._idx
            np.testing.assert_array_equal(v.avail_array(now),
                                          g_avail[idx])
            np.testing.assert_array_equal(v.busy_wait_array(now),
                                          g_wait[idx])
            np.testing.assert_array_equal(v.depth_penalty_array(now),
                                          g_pen[idx])
            for ph in ("full", "prefill", "decode"):
                np.testing.assert_array_equal(
                    v.admit_engine_mask("gemma-2b/bf16", now, ph),
                    cl.admit_engine_mask("gemma-2b/bf16", now, ph)[idx])


def test_region_local_scoring_never_reads_other_regions(configdict):
    """With spillover off, every sub-scheduler's score cache holds only
    its own region's pools and every placement stays in the routed
    region with no transfer charge."""
    fleet = synth_fleet(2, 3, 3, regions=2)
    groups = region_groups(fleet)
    pol = _Recording(spill=False)
    jobs = scenario(configdict, "mmpp", n_jobs=100, fleet=fleet, seed=6,
                    utilization=1.2)
    res = Simulator(configdict, pol, fleet=fleet, seed=6).run(jobs)
    assert len(res) == 100
    for r, sub in pol._subs.items():
        assert set(sub.cache._names) <= {w.name for w in groups[r]}
    assert pol.record and pol.spills == 0
    for jid, home, pool_region, xfer in pol.record:
        assert pool_region == home
        assert xfer == 0.0


def test_spill_charges_xfer_iff_cross_region(configdict):
    """A slot-starved region spills to a foreign idle pool with the
    REGION_XFER input transfer charged; home placements never pay it."""
    fleet = synth_fleet(2, 2, 2, regions=2)
    pol = _Recording()
    sim = Simulator(configdict, pol, fleet=fleet, seed=0)
    cl = sim.cluster
    jobs = [Job(i, "gemma-2b/bf16", 500, 120.0, 0.0) for i in range(4)]
    for j in jobs:
        pol.on_arrival(j, cl, 0.0)
        pol.router.home[j.id] = "r0"     # pin every home to r0 ...
    for name, ws in cl.workers.items():  # ... and starve r0 of slots
        if ws.pool.region == "r0":
            ws.busy_until = 1_000.0
    out = pol.schedule(1.0, jobs, cl)
    assert out and pol.spills == len(out)
    for jid, home, pool_region, xfer in pol.record:
        assert home == "r0" and pool_region == "r1"
        assert xfer > 0.0                # cross-region ⇒ charged
    # now the inverse: an open home slot means no spill, no charge
    pol2 = _Recording()
    jobs2 = [Job(10 + i, "gemma-2b/bf16", 500, 120.0, 0.0)
             for i in range(2)]
    sim2 = Simulator(configdict, pol2, fleet=fleet, seed=0)
    for j in jobs2:
        pol2.on_arrival(j, sim2.cluster, 0.0)
    pol2.schedule(0.0, jobs2, sim2.cluster)
    for jid, home, pool_region, xfer in pol2.record:
        assert (pool_region != home) == (xfer > 0.0)


def test_outage_drains_region_aggregate_within_one_tick(configdict):
    fleet = synth_fleet(2, 2, 2, regions=2)
    pol = HierarchicalSynergAI()
    sim = Simulator(configdict, pol, fleet=fleet, seed=0)
    cl = sim.cluster
    j0 = Job(0, "gemma-2b/bf16", 500, 60.0, 0.0)
    pol.on_arrival(j0, cl, 0.0)
    assert float(pol.router.healthy.min()) == 1.0
    for name, ws in cl.workers.items():
        if ws.pool.region == "r0":       # correlated regional outage
            ws.failed_until = 500.0
    pol.schedule(1.0, [j0], cl)          # the next tick refreshes
    assert pol.router.healthy[pol.router._ri["r0"]] == 0.0
    assert pol.router.healthy[pol.router._ri["r1"]] == 1.0
    # new arrivals and failure requeues route around the downed region
    j1 = Job(1, "gemma-2b/bf16", 500, 60.0, 1.0)
    pol.on_arrival(j1, cl, 1.0)
    assert pol.router.home[j1.id] == "r1"
    pol.on_requeue(j0, cl, 1.0)
    assert j0.id not in pol.router.home
    pol.on_arrival(j0, cl, 1.0)
    assert pol.router.home[j0.id] == "r1"


def test_disaggregated_multi_region_completes_with_kv_handoff(configdict):
    """Prefill/decode pools scattered across regions: every job still
    completes (phase-aware routing + spillover), and cross-region decode
    legs are charged at admission rather than via Assignment.xfer_s."""
    fleet = synth_fleet(2, 4, 4, disaggregate=True, regions=2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=3,
                    utilization=1.1, serving="batched")
    pol = HierarchicalSynergAI()
    res = Simulator(configdict, pol, fleet=fleet, seed=3,
                    serving="batched").run(jobs)
    assert len(res) == 40
    assert all(r.end >= r.start for r in res)


# ----------------------------------------------------------------------------
# property tier (hypothesis behind the conftest shim + seeded fallbacks)


def _check_hier_invariants(seed, k, serving, utilization):
    cd = _cd()
    fleet = synth_fleet(2, 3, 3, regions=k)
    jobs = scenario(cd, "mmpp", n_jobs=80, fleet=fleet, seed=seed,
                    utilization=utilization, serving=serving)
    pol = _Recording()
    res = Simulator(cd, pol, fleet=fleet, seed=seed,
                    serving=serving).run(jobs)
    assert len(res) == len(jobs)         # nothing starves
    regions = set(pol.router.regions)
    for jid, home, pool_region, xfer in pol.record:
        assert home in regions           # routed to exactly one region
        # transfer charged iff the placement left the routed region
        assert (pool_region != home) == (xfer > 0.0)


@pytest.mark.parametrize("seed,k,serving,utilization", [
    (1, 2, "job", 1.3),
    (2, 3, "batched", 1.2),
    (3, 4, "job", 0.8),
])
def test_hier_invariants_seeded(seed, k, serving, utilization):
    _check_hier_invariants(seed, k, serving, utilization)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 5),
       serving=st.sampled_from(["job", "batched"]),
       utilization=st.floats(0.6, 1.4))
def test_hier_invariants_property(seed, k, serving, utilization):
    """Routing uniqueness, completion, and the cross-region transfer
    charge hold under random region counts, workloads and serving
    modes."""
    _check_hier_invariants(seed, k, serving, utilization)


# ----------------------------------------------------------------------------
# regional workload calibration


def test_region_rates_per_region_feasibility(configdict):
    fleet = synth_fleet(2, 3, 3, regions=3)
    rates = region_rates(configdict, fleet)
    assert set(rates) == {"r0", "r1", "r2"}
    assert all(v > 0 for v in rates.values())
    # untagged fleet: one "" group, matching the flat calibrator
    flat = region_rates(configdict, synth_fleet(1, 2, 2))
    assert list(flat) == [""] and flat[""] > 0


def test_regional_scenario_merges_and_reindexes(configdict):
    fleet = synth_fleet(2, 3, 3, regions=3)
    jobs = regional_scenario(configdict, "mmpp", n_jobs=300, fleet=fleet,
                             seed=2, utilization=0.9)
    assert len(jobs) == 300
    assert [j.id for j in jobs] == list(range(300))
    arrivals = [j.arrival for j in jobs]
    assert arrivals == sorted(arrivals)
    # single-region input falls through to the flat scenario generator
    flat_fleet = synth_fleet(1, 2, 2)
    a = regional_scenario(configdict, "mmpp", n_jobs=50, fleet=flat_fleet,
                          seed=2, utilization=0.9)
    b = scenario(configdict, "mmpp", n_jobs=50, fleet=flat_fleet,
                 seed=2, utilization=0.9)
    key = lambda js: [(j.id, j.arrival, j.engine, j.queries, j.t_qos)
                      for j in js]
    assert key(a) == key(b)


# ----------------------------------------------------------------------------
# region-aware elastic provisioning


def test_elastic_base_picks_hottest_region(configdict):
    """The pool elastic provisioning clones comes from the region with
    the highest busy/failed fraction, so the clone inherits the
    pressured region's tag instead of bulking up a cold one."""
    fleet = synth_fleet(1, 2, 2, regions=2)
    by_region = {}
    for w in fleet:
        by_region.setdefault(w.region, []).append(w.name)
    assert len(by_region) == 2
    hot, cold = sorted(by_region)
    sim = Simulator(configdict, SynergAI(), fleet=fleet)
    for name in by_region[hot]:
        sim.cluster.workers[name].busy_until = 100.0
    base = sim._elastic_base(now=10.0)
    assert base.region == hot
    # flip the pressure: the other region wins
    for name in by_region[hot]:
        sim.cluster.workers[name].busy_until = 0.0
    for name in by_region[cold]:
        sim.cluster.workers[name].failed_until = 100.0
    assert sim._elastic_base(now=10.0).region == cold


class _CloneRegionProbe(HierarchicalSynergAI):
    """Records every live clone's region tag at each scheduling tick
    (clones retire once pressure subsides, so post-run state is empty)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.seen = {}

    def schedule(self, now, queue, cluster):
        for name, ws in cluster.workers.items():
            if "__clone" in name:
                self.seen[name] = ws.pool.region
        return super().schedule(now, queue, cluster)


def test_elastic_clones_inherit_parent_region(configdict):
    """Every clone provisioned during a region-tagged overload run
    carries its base pool's region tag and joins that region's
    scheduling columns (regression: clones used to be untagged)."""
    fleet = synth_fleet(1, 2, 2, regions=2)
    regions = {w.region for w in fleet}
    base_region = {w.name: w.region for w in fleet}
    jobs = regional_scenario(configdict, "flash", n_jobs=250,
                             fleet=fleet, seed=3, utilization=2.5)
    pol = _CloneRegionProbe()
    Simulator(configdict, pol, fleet=fleet, seed=3,
              elastic_max=3, elastic_threshold=4).run(jobs)
    assert pol.seen                         # the overload actually scaled
    for name, region in pol.seen.items():
        parent = name.rsplit("__clone", 1)[0]
        assert region == base_region[parent]
        assert region in regions


def test_elastic_base_untagged_matches_single_region(configdict):
    """Untagged fleets reduce to the historical global argmax."""
    tagged = synth_fleet(1, 2, 2, regions=1)
    plain = synth_fleet(1, 2, 2)
    a = Simulator(configdict, SynergAI(), fleet=tagged)
    b = Simulator(configdict, SynergAI(), fleet=plain)
    assert a._elastic_base(0.0).name == b._elastic_base(0.0).name


# ----------------------------------------------------------------------------
# bench smoke


def test_bench_regions_smoke(configdict):
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import bench_regions
    blob = bench_regions(configdict, smoke=True, emit=lambda *a: None)
    assert blob["bench"] == "bench_regions" and blob["schema"] == 1
    variants = {c["variant"] for c in blob["configs"]}
    assert variants == {"flat", "hier"}
    for c in blob["configs"]:
        assert c["mean_tick_ms"] > 0 and c["regions"] == 4
    # the smoke leg never emits the nightly headline (its ratio is noise)
    assert "regions_headline" not in blob
