"""Workload subsystem: arrival-process statistics (MMPP burstier than
Poisson at equal mean rate), heavy-tail sizes, QoS derivation, multi-tenant
merging, synthetic fleets and failure traces."""

import numpy as np
import pytest

from repro.core.job import make_experiment, qos_threshold
from repro.core.workers import default_fleet, synth_fleet
from repro.core.workload import (SCENARIOS, DiurnalArrivals,
                                 FlashCrowdArrivals, FixedSize,
                                 MMPPArrivals, ParetoSize, PoissonArrivals,
                                 TenantSpec, index_of_dispersion,
                                 make_workload, scenario, synth_failures)


# ----------------------------------------------------------------------------
# arrival processes


def test_poisson_mean_rate():
    rng = np.random.default_rng(0)
    times = PoissonArrivals(2.0).sample(rng, 20_000)
    assert np.isclose(len(times) / times[-1], 2.0, rtol=0.05)
    assert (np.diff(times) >= 0).all()


def test_mmpp_burstier_than_poisson_at_equal_mean_rate():
    """The tentpole's point: scheduler quality only differentiates under
    bursty arrivals — MMPP must have dispersion >> Poisson at the same
    time-averaged rate."""
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(2)
    r = 2.0
    mmpp = MMPPArrivals((0.25 * r, 1.75 * r), (120.0, 120.0))
    assert np.isclose(mmpp.mean_rate(), r)
    t_mmpp = mmpp.sample(rng1, 20_000)
    t_poi = PoissonArrivals(r).sample(rng2, 20_000)
    # realized rates agree ...
    assert np.isclose(len(t_mmpp) / t_mmpp[-1], len(t_poi) / t_poi[-1],
                      rtol=0.1)
    # ... but the burstiness does not
    d_mmpp = index_of_dispersion(t_mmpp, 60.0)
    d_poi = index_of_dispersion(t_poi, 60.0)
    assert d_poi < 2.0          # Poisson: dispersion ~ 1
    assert d_mmpp > 3.0 * d_poi


def test_diurnal_peak_vs_trough():
    proc = DiurnalArrivals(base_rate=2.0, amplitude=0.8, period_s=1000.0)
    times = proc.sample(np.random.default_rng(3), 30_000)
    phase = (times % 1000.0) / 1000.0
    peak = ((phase > 0.15) & (phase < 0.35)).sum()     # sin ~ +1
    trough = ((phase > 0.65) & (phase < 0.85)).sum()   # sin ~ -1
    assert peak > 3 * trough


def test_flash_crowd_spike_window():
    proc = FlashCrowdArrivals(base_rate=1.0, spike_at=500.0,
                              spike_duration=100.0, spike_factor=10.0)
    times = proc.sample(np.random.default_rng(4), 10_000)
    in_spike = ((times >= 500.0) & (times < 600.0)).sum()
    before = ((times >= 300.0) & (times < 400.0)).sum()
    assert in_spike > 5 * before


def test_pareto_sizes_heavy_tail():
    sizes = ParetoSize(alpha=1.5, q_min=200, q_max=20_000).sample(
        np.random.default_rng(5), 20_000)
    assert sizes.min() >= 200 and sizes.max() <= 20_000
    assert sizes.max() > 10 * np.median(sizes)
    assert FixedSize(1000).sample(np.random.default_rng(0), 5).tolist() \
        == [1000] * 5


# ----------------------------------------------------------------------------
# QoS derivation


def test_qos_threshold_monotone_in_queries(configdict):
    t1 = qos_threshold(configdict, "gemma-2b/bf16", 500, 50)
    t2 = qos_threshold(configdict, "gemma-2b/bf16", 2000, 50)
    assert t2 > t1


def test_qos_dh_tighter_than_dl(configdict):
    dl = qos_threshold(configdict, "qwen3-4b/bf16", 1000, 50)
    dh = qos_threshold(configdict, "qwen3-4b/bf16", 1000, 25)
    assert dh < dl


# ----------------------------------------------------------------------------
# workload assembly


def test_make_workload_merges_tenants_sorted_and_renumbered(configdict):
    tenants = [
        TenantSpec("a", PoissonArrivals(1.0), 50,
                   engines=("gemma-2b/bf16",)),
        TenantSpec("b", PoissonArrivals(2.0), 70,
                   engines=("qwen3-4b/bf16",), sizes=ParetoSize(),
                   qos_percentile=25.0, qos_scale=2.0),
    ]
    jobs = make_workload(configdict, tenants, seed=0)
    assert len(jobs) == 120
    assert [j.id for j in jobs] == list(range(120))
    assert all(a.arrival <= b.arrival for a, b in zip(jobs, jobs[1:]))
    assert {j.engine for j in jobs} == {"gemma-2b/bf16", "qwen3-4b/bf16"}


def test_workload_same_seed_deterministic(configdict):
    fleet = synth_fleet(2, 3, 3)
    a = scenario(configdict, "multi-tenant", n_jobs=300, fleet=fleet,
                 seed=9)
    b = scenario(configdict, "multi-tenant", n_jobs=300, fleet=fleet,
                 seed=9)
    assert [(j.engine, j.queries, j.t_qos, j.arrival) for j in a] \
        == [(j.engine, j.queries, j.t_qos, j.arrival) for j in b]


@pytest.mark.parametrize("kind", SCENARIOS)
def test_every_scenario_generates(configdict, kind):
    jobs = scenario(configdict, kind, n_jobs=200,
                    fleet=synth_fleet(2, 3, 3), seed=1)
    assert len(jobs) == 200
    assert all(j.t_qos > 0 and j.queries > 0 for j in jobs)


def test_unknown_scenario_raises(configdict):
    with pytest.raises(ValueError):
        scenario(configdict, "nope", n_jobs=10)


def test_make_experiment_still_paper_shaped(configdict):
    jobs = make_experiment(configdict, "DL", "FH", seed=1)
    assert len(jobs) == 24
    assert [j.id for j in jobs] == list(range(24))
    assert jobs[0].arrival == 0.0


# ----------------------------------------------------------------------------
# fleets + failures


def test_synth_fleet_shares_archetype_profiles(configdict):
    fleet = synth_fleet(2, 3, 4)
    assert len(fleet) == 9
    names = [w.name for w in fleet]
    assert len(set(names)) == 9
    base = {w.name for w in default_fleet()}
    for w in fleet:
        assert w.name.split("__")[0] in base
        # replicas resolve to the archetype's profile
        ent = configdict.optimal("gemma-2b/bf16", w.name)
        ref = configdict.optimal("gemma-2b/bf16", w.name.split("__")[0])
        assert ent is ref


def test_synth_failures_within_horizon_sorted():
    fleet = synth_fleet(1, 2, 2)
    evs = synth_failures(fleet, horizon_s=5000.0, mtbf_s=1000.0,
                         mttr_s=100.0, seed=0)
    assert evs
    assert all(0 <= e.at < 5000.0 and e.duration > 0 for e in evs)
    assert all(a.at <= b.at for a, b in zip(evs, evs[1:]))
    assert {e.worker for e in evs} <= {w.name for w in fleet}
