"""Workload subsystem: arrival-process statistics (MMPP burstier than
Poisson at equal mean rate), heavy-tail sizes, QoS derivation, multi-tenant
merging, synthetic fleets, failure traces — plus the trace-driven axes:
engine-popularity drift (``DriftedArrivals``) and correlated multi-region
failures, with hypothesis properties behind the conftest shim (seeded
fallbacks always run)."""

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.job import make_experiment, qos_threshold
from repro.core.workers import default_fleet, synth_fleet
from repro.core.workload import (EDGE_ENGINES, SCENARIOS, DiurnalArrivals,
                                 DriftedArrivals, FlashCrowdArrivals,
                                 FixedSize, MMPPArrivals, ParetoSize,
                                 PoissonArrivals, TenantSpec,
                                 index_of_dispersion, make_workload,
                                 scenario, synth_failures)


# ----------------------------------------------------------------------------
# arrival processes


def test_poisson_mean_rate():
    rng = np.random.default_rng(0)
    times = PoissonArrivals(2.0).sample(rng, 20_000)
    assert np.isclose(len(times) / times[-1], 2.0, rtol=0.05)
    assert (np.diff(times) >= 0).all()


def test_mmpp_burstier_than_poisson_at_equal_mean_rate():
    """The tentpole's point: scheduler quality only differentiates under
    bursty arrivals — MMPP must have dispersion >> Poisson at the same
    time-averaged rate."""
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(2)
    r = 2.0
    mmpp = MMPPArrivals((0.25 * r, 1.75 * r), (120.0, 120.0))
    assert np.isclose(mmpp.mean_rate(), r)
    t_mmpp = mmpp.sample(rng1, 20_000)
    t_poi = PoissonArrivals(r).sample(rng2, 20_000)
    # realized rates agree ...
    assert np.isclose(len(t_mmpp) / t_mmpp[-1], len(t_poi) / t_poi[-1],
                      rtol=0.1)
    # ... but the burstiness does not
    d_mmpp = index_of_dispersion(t_mmpp, 60.0)
    d_poi = index_of_dispersion(t_poi, 60.0)
    assert d_poi < 2.0          # Poisson: dispersion ~ 1
    assert d_mmpp > 3.0 * d_poi


def test_diurnal_peak_vs_trough():
    proc = DiurnalArrivals(base_rate=2.0, amplitude=0.8, period_s=1000.0)
    times = proc.sample(np.random.default_rng(3), 30_000)
    phase = (times % 1000.0) / 1000.0
    peak = ((phase > 0.15) & (phase < 0.35)).sum()     # sin ~ +1
    trough = ((phase > 0.65) & (phase < 0.85)).sum()   # sin ~ -1
    assert peak > 3 * trough


def test_flash_crowd_spike_window():
    proc = FlashCrowdArrivals(base_rate=1.0, spike_at=500.0,
                              spike_duration=100.0, spike_factor=10.0)
    times = proc.sample(np.random.default_rng(4), 10_000)
    in_spike = ((times >= 500.0) & (times < 600.0)).sum()
    before = ((times >= 300.0) & (times < 400.0)).sum()
    assert in_spike > 5 * before


def test_pareto_sizes_heavy_tail():
    sizes = ParetoSize(alpha=1.5, q_min=200, q_max=20_000).sample(
        np.random.default_rng(5), 20_000)
    assert sizes.min() >= 200 and sizes.max() <= 20_000
    assert sizes.max() > 10 * np.median(sizes)
    assert FixedSize(1000).sample(np.random.default_rng(0), 5).tolist() \
        == [1000] * 5


# ----------------------------------------------------------------------------
# QoS derivation


def test_qos_threshold_monotone_in_queries(configdict):
    t1 = qos_threshold(configdict, "gemma-2b/bf16", 500, 50)
    t2 = qos_threshold(configdict, "gemma-2b/bf16", 2000, 50)
    assert t2 > t1


def test_qos_dh_tighter_than_dl(configdict):
    dl = qos_threshold(configdict, "qwen3-4b/bf16", 1000, 50)
    dh = qos_threshold(configdict, "qwen3-4b/bf16", 1000, 25)
    assert dh < dl


# ----------------------------------------------------------------------------
# workload assembly


def test_make_workload_merges_tenants_sorted_and_renumbered(configdict):
    tenants = [
        TenantSpec("a", PoissonArrivals(1.0), 50,
                   engines=("gemma-2b/bf16",)),
        TenantSpec("b", PoissonArrivals(2.0), 70,
                   engines=("qwen3-4b/bf16",), sizes=ParetoSize(),
                   qos_percentile=25.0, qos_scale=2.0),
    ]
    jobs = make_workload(configdict, tenants, seed=0)
    assert len(jobs) == 120
    assert [j.id for j in jobs] == list(range(120))
    assert all(a.arrival <= b.arrival for a, b in zip(jobs, jobs[1:]))
    assert {j.engine for j in jobs} == {"gemma-2b/bf16", "qwen3-4b/bf16"}


def test_workload_same_seed_deterministic(configdict):
    fleet = synth_fleet(2, 3, 3)
    a = scenario(configdict, "multi-tenant", n_jobs=300, fleet=fleet,
                 seed=9)
    b = scenario(configdict, "multi-tenant", n_jobs=300, fleet=fleet,
                 seed=9)
    assert [(j.engine, j.queries, j.t_qos, j.arrival) for j in a] \
        == [(j.engine, j.queries, j.t_qos, j.arrival) for j in b]


@pytest.mark.parametrize("kind", SCENARIOS)
def test_every_scenario_generates(configdict, kind):
    jobs = scenario(configdict, kind, n_jobs=200,
                    fleet=synth_fleet(2, 3, 3), seed=1)
    assert len(jobs) == 200
    assert all(j.t_qos > 0 and j.queries > 0 for j in jobs)


def test_unknown_scenario_raises(configdict):
    with pytest.raises(ValueError):
        scenario(configdict, "nope", n_jobs=10)


def test_make_experiment_still_paper_shaped(configdict):
    jobs = make_experiment(configdict, "DL", "FH", seed=1)
    assert len(jobs) == 24
    assert [j.id for j in jobs] == list(range(24))
    assert jobs[0].arrival == 0.0


# ----------------------------------------------------------------------------
# engine-popularity drift


def _check_drift_weights_normalized(w0, w1, span, mode, n_windows):
    """Mixing weights re-normalize to 1 in every window, whatever the
    input scales; piecewise drift is constant within a window and hits
    the exact start/end mixes at the extremes."""
    d = DriftedArrivals(PoissonArrivals(1.0), w0, w1, span_s=span,
                        mode=mode, n_windows=n_windows)
    for t in np.linspace(-0.1 * span, 1.1 * span, 97):
        w = d.weights_at(float(t))
        assert w.shape == (len(w0),)
        assert (w >= 0).all()
        assert np.isclose(w.sum(), 1.0, atol=1e-12)
    w_start = np.asarray(w0, float) / np.sum(w0)
    w_end = np.asarray(w1, float) / np.sum(w1)
    assert np.allclose(d.weights_at(0.0), w_start)
    assert np.allclose(d.weights_at(span), w_end)
    if mode == "piecewise":
        width = span / n_windows
        for k in range(n_windows):      # constant inside each window
            lo, hi = k * width, (k + 1) * width
            a = d.weights_at(lo + 0.01 * width)
            b = d.weights_at(hi - 0.01 * width)
            assert np.allclose(a, b)


@settings(max_examples=25, deadline=None)
@given(w0=st.lists(st.floats(0.01, 50.0), min_size=2, max_size=8),
       seed=st.integers(0, 10_000),
       span=st.floats(10.0, 1e5),
       mode=st.sampled_from(["smooth", "piecewise"]),
       n_windows=st.integers(2, 12))
def test_prop_drift_weights_sum_to_1(w0, seed, span, mode, n_windows):
    rng = np.random.default_rng(seed)
    w1 = rng.uniform(0.01, 50.0, size=len(w0)).tolist()
    _check_drift_weights_normalized(w0, w1, span, mode, n_windows)


@pytest.mark.parametrize("mode,n_windows", [("smooth", 2),
                                            ("piecewise", 4),
                                            ("piecewise", 7)])
def test_drift_weights_sum_to_1_seeded(mode, n_windows):
    _check_drift_weights_normalized([3.0, 1.0, 0.25], [0.1, 5.0, 2.0],
                                    1000.0, mode, n_windows)


def test_drift_validation():
    base = PoissonArrivals(1.0)
    with pytest.raises(ValueError):
        DriftedArrivals(base, [1, 2], [1, 2], span_s=10.0, mode="nope")
    with pytest.raises(ValueError):
        DriftedArrivals(base, [1, 2], [1, 2, 3], span_s=10.0)
    with pytest.raises(ValueError):
        DriftedArrivals(base, [1, -2], [1, 2], span_s=10.0)
    with pytest.raises(ValueError):
        DriftedArrivals(base, [1, 2], [1, 2], span_s=0.0)
    with pytest.raises(ValueError):
        DriftedArrivals(base, [1, 2], [1, 2], span_s=5.0,
                        mode="piecewise", n_windows=1)


def test_drifted_tenant_rejects_static_weights(configdict):
    drift = DriftedArrivals(PoissonArrivals(1.0), [1.0, 1.0], [0.0, 1.0],
                            span_s=100.0)
    spec = TenantSpec("d", drift, 10,
                      engines=("gemma-2b/bf16", "qwen3-4b/bf16"),
                      engine_weights=(0.5, 0.5))
    with pytest.raises(ValueError):
        make_workload(configdict, [spec], seed=0)
    bad = TenantSpec("d", drift, 10, engines=("gemma-2b/bf16",))
    with pytest.raises(ValueError):        # weight/engine length mismatch
        make_workload(configdict, [bad], seed=0)


def test_drift_scenario_mix_goes_stale(configdict):
    """The drift preset's point: the engine mix early in the trace looks
    like the offline-calibrated capacity-proportional one (edge-heavy);
    late in the trace the heavyweights have taken the traffic share."""
    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "drift", n_jobs=1000, fleet=fleet, seed=3)
    assert len(jobs) == 1000
    edge_share = lambda js: np.mean([j.engine in EDGE_ENGINES
                                     for j in js])
    early, late = edge_share(jobs[:200]), edge_share(jobs[-200:])
    assert early > late + 0.1           # popularity flipped edge -> heavy
    assert {j.tenant for j in jobs} == {"drift"}
    # drift composes with the serving bridge like every other preset
    jobs_b = scenario(configdict, "drift", n_jobs=50, fleet=fleet, seed=3,
                      serving="batched")
    assert all(j.request is not None for j in jobs_b)


# ----------------------------------------------------------------------------
# fleets + failures


def test_synth_fleet_shares_archetype_profiles(configdict):
    fleet = synth_fleet(2, 3, 4)
    assert len(fleet) == 9
    names = [w.name for w in fleet]
    assert len(set(names)) == 9
    base = {w.name for w in default_fleet()}
    for w in fleet:
        assert w.name.split("__")[0] in base
        # replicas resolve to the archetype's profile
        ent = configdict.optimal("gemma-2b/bf16", w.name)
        ref = configdict.optimal("gemma-2b/bf16", w.name.split("__")[0])
        assert ent is ref


def test_synth_failures_within_horizon_sorted():
    fleet = synth_fleet(1, 2, 2)
    evs = synth_failures(fleet, horizon_s=5000.0, mtbf_s=1000.0,
                         mttr_s=100.0, seed=0)
    assert evs
    assert all(0 <= e.at < 5000.0 and e.duration > 0 for e in evs)
    assert all(a.at <= b.at for a, b in zip(evs, evs[1:]))
    assert {e.worker for e in evs} <= {w.name for w in fleet}


# ----------------------------------------------------------------------------
# correlated multi-region failures


def test_synth_fleet_region_tags():
    fleet = synth_fleet(2, 4, 3, regions=3)
    regions = {w.region for w in fleet}
    assert regions == {"r0", "r1", "r2"}
    # round-robin: every region holds a mix, sizes within one of each
    # other, and plain fleets stay untagged
    sizes = [sum(w.region == r for w in fleet) for r in sorted(regions)]
    assert max(sizes) - min(sizes) <= 1
    assert all(w.region == "" for w in synth_fleet(2, 4, 3))


def _check_correlated_failures(n_pools, n_regions, correlation, seed):
    """The correlated-failure invariants: every event's pool belongs to
    the event's region, a single outage downs the sampled fraction of
    the region simultaneously, and no pool's failure windows overlap."""
    fleet = synth_fleet(n_pools, n_pools, n_pools, regions=n_regions)
    horizon = 50_000.0
    evs = synth_failures(fleet, horizon, mtbf_s=5000.0, mttr_s=400.0,
                         seed=seed, regions=True, correlation=correlation)
    region_of = {w.name: w.region for w in fleet}
    region_size = {r: sum(1 for w in fleet if w.region == r)
                   for r in {w.region for w in fleet}}
    assert evs and all(0 <= e.at < horizon and e.duration > 0
                       for e in evs)
    # one outage = one (at, duration) shared by its downed pools, all in
    # one region, exactly the correlated fraction of it
    by_outage = {}
    for e in evs:
        by_outage.setdefault((e.at, e.duration), []).append(e.worker)
    for (at, dur), pools in by_outage.items():
        regs = {region_of[p] for p in pools}
        assert len(regs) == 1, "an outage crossed a region boundary"
        r = regs.pop()
        assert len(pools) == len(set(pools))
        assert len(pools) == max(1, round(correlation * region_size[r]))
    # per-pool windows never overlap
    by_pool = {}
    for e in evs:
        by_pool.setdefault(e.worker, []).append((e.at, e.at + e.duration))
    for spans in by_pool.values():
        spans.sort()
        assert all(a_end <= b_at for (_, a_end), (b_at, _)
                   in zip(spans, spans[1:]))


@settings(max_examples=20, deadline=None)
@given(n_pools=st.integers(1, 4), n_regions=st.integers(1, 5),
       correlation=st.floats(0.05, 1.0), seed=st.integers(0, 10_000))
def test_prop_correlated_failures(n_pools, n_regions, correlation, seed):
    _check_correlated_failures(n_pools, n_regions, correlation, seed)


@pytest.mark.parametrize("n_pools,n_regions,correlation,seed", [
    (3, 3, 0.6, 0), (2, 4, 1.0, 7), (4, 2, 0.25, 13)])
def test_correlated_failures_seeded(n_pools, n_regions, correlation, seed):
    _check_correlated_failures(n_pools, n_regions, correlation, seed)


def test_correlated_failures_region_specs():
    fleet = synth_fleet(1, 2, 2)              # untagged
    with pytest.raises(ValueError, match="no region tag"):
        synth_failures(fleet, 1000.0, 100.0, 10.0, regions=True)
    with pytest.raises(ValueError, match="correlation"):
        synth_failures(fleet, 1000.0, 100.0, 10.0, regions=2,
                       correlation=0.0)
    with pytest.raises(ValueError, match="unknown pool"):
        synth_failures(fleet, 1000.0, 100.0, 10.0,
                       regions={"a": ["nope"]})
    with pytest.raises(ValueError, match="more than one region"):
        synth_failures(fleet, 1000.0, 100.0, 10.0,
                       regions={"a": ["cloud-pod"], "b": ["cloud-pod"]})
    with pytest.raises(ValueError, match="no pools"):
        synth_failures(fleet, 1000.0, 100.0, 10.0, regions={"a": []})
    # regions=False means off, like synth_fleet's disaggregate=False
    assert (synth_failures(fleet, 5000.0, 1000.0, 100.0, regions=False)
            == synth_failures(fleet, 5000.0, 1000.0, 100.0))
    # int and explicit mappings work on untagged fleets
    evs = synth_failures(fleet, 20_000.0, 2000.0, 100.0, seed=1,
                         regions=2, correlation=1.0)
    assert evs
    evs = synth_failures(fleet, 20_000.0, 2000.0, 100.0, seed=1,
                         regions={"edge": ["edge-large", "edge-small"]},
                         correlation=1.0)
    assert {e.worker for e in evs} <= {"edge-large", "edge-small"}


def test_correlated_failures_drive_simulator(configdict):
    """A correlated-region outage mid-trace exercises the kill/re-queue
    path at fleet scale: every job still completes exactly once."""
    from repro.core.scheduler import SynergAI
    from repro.core.simulator import Simulator
    fleet = synth_fleet(2, 3, 3, regions=3)
    jobs = scenario(configdict, "mmpp", n_jobs=300, fleet=fleet, seed=2,
                    utilization=1.1)
    span = jobs[-1].arrival
    failures = synth_failures(fleet, span, mtbf_s=0.5 * span,
                              mttr_s=120.0, seed=2, regions=True,
                              correlation=0.75)
    assert len({(e.at, e.duration) for e in failures}) < len(failures)
    res = Simulator(configdict, SynergAI(), fleet=fleet,
                    failures=failures, seed=2).run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
