"""SynergAI core tests: Eq. 1-4 estimator, policies, simulator invariants,
and the paper's headline orderings — plus hypothesis property tests."""

import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or the skip shim

from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                  MostRecentlyUsed, RoundRobin,
                                  StrictRoundRobin)
from repro.core.engines import default_engines
from repro.core.estimator import candidate_order, estimate_matrix
from repro.core.job import Job, exec_time, make_experiment
from repro.core.metrics import summarize
from repro.core.offline import characterize, cold_start_config
from repro.core.perfmodel import ConfigPoint, config_space, estimate, profile_engine
from repro.core.scheduler import SynergAI
from repro.core.simulator import FailureEvent, Simulator
from repro.core.slo_mael import SloMael
from repro.core.workers import default_fleet

WORKERS = ["cloud-pod", "edge-large", "edge-small"]


# ----------------------------------------------------------------------------
# offline phase


def test_configdict_has_optimal_for_every_feasible_pair(configdict):
    engines = default_engines()
    n = 0
    for e in engines:
        for w in WORKERS:
            ent = configdict.optimal(e, w)
            if ent is not None:
                assert ent.qps > 0
                n += 1
    assert n >= 30  # most engine/worker pairs are feasible


def test_optimal_beats_default(configdict):
    """The offline phase must never pick a config worse than the default."""
    for e in default_engines():
        for w in WORKERS:
            opt = configdict.optimal(e, w)
            def_ = configdict.default_entry(e, w)
            if opt and def_:
                assert opt.qps >= def_.qps * 0.999, (e, w)


def test_deepseek_cloud_only(configdict):
    """The 236B MoE must be infeasible on edge slices (heterogeneity)."""
    assert configdict.optimal("deepseek-v2/int8", "cloud-pod") is not None
    assert configdict.optimal("deepseek-v2/int8", "edge-small") is None


def test_cold_start_heuristic():
    for pool in default_fleet():
        point = cold_start_config(pool)
        # highest frequency band selected (paper §4.2)
        best = max(m.effective_clock() for m in pool.modes)
        assert point.mode.effective_clock() >= 0.95 * best
        assert point.chips_per_replica <= pool.n_chips


def test_perfmodel_feasibility_monotone_in_chips():
    """More chips per replica never makes a feasible engine infeasible."""
    engines = default_engines()
    e = engines["qwen3-32b/bf16"]
    pool = default_fleet()[0]
    mode = pool.modes[0]
    feas = [estimate(e, pool, ConfigPoint(mode, r)).feasible
            for r in (1, 2, 4, 8, 16)]
    # once feasible, stays feasible
    first = feas.index(True) if True in feas else len(feas)
    assert all(feas[first:]), feas


# ----------------------------------------------------------------------------
# estimator (Eq. 1-4)


def _mkjob(jid, engine, q=1000, t_qos=500.0, arrival=0.0):
    return Job(jid, engine, q, t_qos, arrival)


def test_eq1_remaining_time(configdict):
    jobs = [_mkjob(0, "gemma-2b/bf16", t_qos=100.0, arrival=10.0)]
    s = estimate_matrix(configdict, jobs, WORKERS, now=30.0)
    assert np.isclose(s.t_remaining[0], 80.0)  # Eq. 1


def test_eq2_estimated_time(configdict):
    jobs = [_mkjob(0, "gemma-2b/bf16", q=2000)]
    s = estimate_matrix(configdict, jobs, WORKERS, now=0.0)
    ent = configdict.optimal("gemma-2b/bf16", "cloud-pod")
    expect = ent.preproc_s + 2000 / ent.qps
    assert np.isclose(s.t_estimated[0][WORKERS.index("cloud-pod")], expect)


def test_eq3_eq4_acceptable_and_argmin(configdict):
    jobs = [_mkjob(0, "qwen3-32b/bf16", q=1000, t_qos=200.0)]
    s = estimate_matrix(configdict, jobs, WORKERS, now=0.0)
    fin = np.isfinite(s.t_estimated[0])
    acc = s.acceptable[0] & fin
    if acc.any():
        best = s.best_worker[0]
        masked = np.where(acc, s.t_estimated[0], np.inf)
        assert best == masked.argmin()  # Eq. 4


def test_doomed_detection(configdict):
    jobs = [_mkjob(0, "qwen3-32b/bf16", q=5000, t_qos=1.0)]
    s = estimate_matrix(configdict, jobs, WORKERS, now=0.0)
    assert bool(s.doomed[0])
    # doomed jobs still get a candidate list (fastest completion first)
    cands = candidate_order(s, 0, np.zeros(len(WORKERS)))
    assert cands, "doomed job must still be schedulable"


@settings(max_examples=50, deadline=None)
@given(q1=st.integers(100, 5000), q2=st.integers(100, 5000),
       t_qos=st.floats(10.0, 5000.0), now=st.floats(0.0, 100.0))
def test_estimator_properties(configdict_, q1, q2, t_qos, now):
    cd = configdict_
    jobs = [_mkjob(0, "gemma-2b/bf16", q=q1, t_qos=t_qos),
            _mkjob(1, "gemma-2b/bf16", q=q2, t_qos=t_qos)]
    s = estimate_matrix(cd, jobs, WORKERS, now=now)
    # monotonicity: more queries -> more estimated time on every worker
    if q1 <= q2:
        assert np.all(s.t_estimated[0] <= s.t_estimated[1] + 1e-9)
    # acceptability shrinks as waiting grows (Eq. 1/3 coupling)
    s_later = estimate_matrix(cd, jobs, WORKERS, now=now + 50.0)
    assert np.all(s_later.acceptable <= s.acceptable)
    # urgency decreases exactly with elapsed time
    assert np.allclose(s.urgency - 50.0, s_later.urgency)


@pytest.fixture(scope="module")
def configdict_():
    return characterize()


# ----------------------------------------------------------------------------
# simulator invariants


POLICIES = [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
            MostRecentlyUsed, BestEffort, SloMael, SynergAI]


@pytest.mark.parametrize("policy_cls", POLICIES)
def test_simulator_conservation(configdict, policy_cls):
    """Every job executes exactly once; times are consistent."""
    jobs = make_experiment(configdict, "DL", "FH", seed=7)
    res = Simulator(configdict, policy_cls(), seed=7).run(jobs)
    assert len(res) == len(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    for r in res:
        assert r.start >= r.job.arrival - 1e-9
        assert np.isclose(r.e2e, r.end - r.job.arrival)
        assert np.isclose(r.waiting, r.start - r.job.arrival)
        assert r.exec_s > 0
        assert r.excess >= 0
        assert r.violated == (r.e2e > r.job.t_qos)


@pytest.mark.parametrize("policy_cls", POLICIES)
def test_no_worker_overlap(configdict, policy_cls):
    """Strict isolation: at most one job on a worker at any time."""
    jobs = make_experiment(configdict, "DH", "FH", seed=3)
    res = Simulator(configdict, policy_cls(), seed=3).run(jobs)
    by_worker = {}
    for r in res:
        by_worker.setdefault(r.worker, []).append((r.start, r.end))
    for w, spans in by_worker.items():
        spans.sort()
        for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
            assert s2 >= e1 - 1e-6, f"overlap on {w}"


def test_synergai_uses_optimal_configs(configdict):
    jobs = make_experiment(configdict, "DL", "FL", seed=1)
    res = Simulator(configdict, SynergAI(), seed=1).run(jobs)
    for r in res:
        ent = configdict.optimal(r.job.engine, r.worker)
        assert r.config == f"{ent.mode}/r{ent.chips_per_replica}"


def test_baselines_use_default_configs(configdict):
    jobs = make_experiment(configdict, "DL", "FL", seed=1)
    res = Simulator(configdict, RoundRobin(), seed=1).run(jobs)
    for r in res:
        ent = configdict.default_entry(r.job.engine, r.worker)
        assert r.config == f"{ent.mode}/r{ent.chips_per_replica}"


def test_headline_orderings(configdict):
    """Paper's core claims, aggregated over seeds: SynergAI has the fewest
    violations; SRR has the worst waiting time."""
    totals = {}
    waits = {}
    for P in [RoundRobin, StrictRoundRobin, SloMael, SynergAI]:
        v, w = 0, []
        for seed in (1, 2, 3):
            for d, f in [("DL", "FL"), ("DL", "FH"), ("DH", "FH")]:
                jobs = make_experiment(configdict, d, f, seed=seed)
                s = summarize(Simulator(configdict, P(), seed=seed).run(jobs))
                v += s["violations"]
                w.append(s["waiting_avg_s"])
        totals[P.name] = v
        waits[P.name] = np.mean(w)
    assert totals["SynergAI"] < totals["SLO-MAEL"]
    assert totals["SynergAI"] < totals["RR"]
    assert totals["SynergAI"] < totals["SRR"]
    assert waits["SRR"] == max(waits.values())


# ----------------------------------------------------------------------------
# fault tolerance / robustness


def test_worker_failure_requeues_and_completes(configdict):
    jobs = make_experiment(configdict, "DL", "FL", seed=5)
    failures = [FailureEvent("cloud-pod", at=50.0, duration=300.0)]
    res = Simulator(configdict, SynergAI(), failures=failures,
                    seed=5).run(jobs)
    assert len(res) == len(jobs)           # everything still completes
    for r in res:
        ws = [f for f in failures if f.worker == r.worker]
        for f in ws:  # nothing runs inside a failure window
            assert r.end <= f.at + 1e-6 or r.start >= f.at + f.duration - 1e-6


def test_straggler_injection_slows_jobs(configdict):
    jobs = make_experiment(configdict, "DL", "FL", seed=5)
    base = Simulator(configdict, SynergAI(), exec_noise=0.0, seed=5).run(jobs)
    slow = Simulator(configdict, SynergAI(), exec_noise=0.0,
                     straggler_prob=0.5, straggler_factor=4.0,
                     seed=5).run(jobs)
    assert (sum(r.exec_s for r in slow) >
            1.5 * sum(r.exec_s for r in base))
