"""Property-based serving-bridge invariants (hypothesis; guarded by the
conftest import shim so the suite collects and the seeded fallback tests
still run when hypothesis isn't installed).

Invariants:
  * batch formation never exceeds the max-batch / KV-cache budgets;
  * m(b) is monotone decreasing in b while b * m(b) is increasing;
  * TTFT <= end-to-end latency, TPOT >= 0;
  * forced ``max_batch=1`` equals job mode bit-for-bit under random
    workloads (the bridge's semantics anchor);
  * trace export/replay preserves arrival order, total token counts and
    the trace's burstiness (``index_of_dispersion``) exactly, for every
    scenario preset.

Each property lives in a plain ``_check_*`` helper: hypothesis drives it
over drawn inputs in CI, and a deterministic parametrized test drives it
over pinned seeds everywhere (so tier-1 keeps the coverage even without
hypothesis)."""

import functools

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.baselines import RoundRobin
from repro.core.constants import OperatingMode
from repro.core.engines import default_engines
from repro.core.job import Job
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.serving_bridge import (batch_multiplier, batch_profile,
                                       batch_throughput)
from repro.core.simulator import BatchedWorkerSim, Simulator
from repro.core.workers import WorkerPool, synth_fleet
from repro.core.workload import (index_of_dispersion, replay, save_trace,
                                 scenario)


@functools.lru_cache(maxsize=None)
def _cd():
    # session-style cache that doesn't tangle pytest fixtures with @given
    return characterize()


def _result_key(results):
    return [(r.job.id, r.worker, r.config, r.start, r.end, r.waiting,
             r.exec_s, r.e2e, r.violated, r.excess, r.overhead_s)
            for r in results]


# ----------------------------------------------------------------------------
# the properties

def _check_multiplier_monotone(alpha: float, b_max: int):
    ms = [batch_multiplier(alpha, b) for b in range(1, b_max + 1)]
    ts = [batch_throughput(alpha, b) for b in range(1, b_max + 1)]
    assert ms[0] == 1.0
    assert all(0 < m <= 1.0 for m in ms)
    assert all(a >= b for a, b in zip(ms, ms[1:]))      # members slow down
    assert all(a <= b for a, b in zip(ts, ts[1:]))      # batch speeds up
    if alpha > 0:
        assert all(a > b for a, b in zip(ms, ms[1:]))


def _check_budgets_and_streaming(seed: int, kind: str, max_batch: int,
                                 utilization: float):
    cd = _cd()
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, kind, n_jobs=80, fleet=fleet, seed=seed,
                    utilization=utilization, serving="batched")
    sim = Simulator(cd, SynergAI(), fleet=fleet, seed=seed,
                    serving="batched", max_batch=max_batch)
    res = sim.run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    for ws in sim.cluster.workers.values():
        assert isinstance(ws, BatchedWorkerSim)
        assert ws.peak_batch <= max_batch          # slot budget held
        assert not ws.active                       # everything drained
    for r in res:
        assert 0.0 < r.ttft <= r.e2e + 1e-9        # first token comes first
        assert r.tpot >= 0.0
        assert r.start >= r.job.arrival - 1e-9


def _check_kv_budget(n_jobs: int, queries: int):
    """A pool sized for ~2.5 microbatch caches can never batch above 2,
    whatever the workload shape."""
    from repro.core.offline import characterize as char
    from repro.core.perfmodel import profile_engine
    spec = default_engines()["gemma-2b/bf16"]
    prof = profile_engine(spec)
    hbm = 1.2 * (prof.weights_bytes + 2.5 * prof.kv_bytes) / 0.9
    pool = WorkerPool("tiny", 1, (OperatingMode("m", 1.0, 1, 1000.0),),
                      (1, 1), True, chip_hbm_bytes=hbm)
    cd = char({spec.name: spec}, [pool])
    ent = cd.optimal(spec.name, "tiny")
    assert batch_profile(ent, spec, pool).kv_limit == 2
    jobs = [Job(i, spec.name, queries, 1e6, 0.0) for i in range(n_jobs)]
    sim = Simulator(cd, SynergAI(), fleet=[pool], serving="batched",
                    max_batch=8, exec_noise=0.0)
    res = sim.run(jobs)
    assert len(res) == n_jobs
    ws = sim.cluster.workers["tiny"]
    assert ws.peak_batch <= 2                      # KV budget held


def _check_batch1_equals_job_mode(seed: int, kind: str,
                                  utilization: float, policy_cls):
    cd = _cd()
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, kind, n_jobs=60, fleet=fleet, seed=seed,
                    utilization=utilization)
    a = Simulator(cd, policy_cls(), fleet=fleet, seed=seed).run(jobs)
    b = Simulator(cd, policy_cls(), fleet=fleet, seed=seed,
                  serving="batched", max_batch=1).run(jobs)
    assert _result_key(a) == _result_key(b)


def _check_trace_replay_preserves(seed: int, kind: str, serving: str):
    """Export -> replay preserves the arrival order, every job's token
    counts (aggregate prompt/decode totals match exactly), and the
    trace's burstiness: ``index_of_dispersion`` of the replayed arrivals
    equals the source's bit-for-bit (arrivals round-trip exactly)."""
    import os
    import tempfile
    cd = _cd()
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(cd, kind, n_jobs=60, fleet=fleet, seed=seed,
                    serving=serving)
    fd, path = tempfile.mkstemp(suffix=".jsonl")
    os.close(fd)
    try:
        save_trace(path, jobs)
        back = replay(path)
    finally:
        os.unlink(path)
    assert [j.id for j in back] == [j.id for j in jobs]
    assert all(a.arrival <= b.arrival for a, b in zip(back, back[1:]))
    assert [j.arrival for j in back] == [j.arrival for j in jobs]
    assert sum(j.queries for j in back) == sum(j.queries for j in jobs)
    if serving == "batched":
        assert (sum(j.request.prompt_tokens for j in back)
                == sum(j.request.prompt_tokens for j in jobs))
        assert (sum(j.request.decode_tokens for j in back)
                == sum(j.request.decode_tokens for j in jobs))
    t_src = np.array([j.arrival for j in jobs])
    t_rep = np.array([j.arrival for j in back])
    window = max(1.0, float(t_src.max()) / 16.0)
    assert (index_of_dispersion(t_rep, window)
            == index_of_dispersion(t_src, window))


# ----------------------------------------------------------------------------
# hypothesis drivers (skip cleanly without the library)

@settings(max_examples=25, deadline=None)
@given(alpha=st.floats(0.0, 1.0), b_max=st.integers(2, 64))
def test_prop_multiplier_monotone(alpha, b_max):
    _check_multiplier_monotone(alpha, b_max)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["poisson", "mmpp", "flash"]),
       max_batch=st.integers(1, 12),
       utilization=st.floats(0.5, 1.6))
def test_prop_budgets_and_streaming(seed, kind, max_batch, utilization):
    _check_budgets_and_streaming(seed, kind, max_batch, utilization)


@settings(max_examples=5, deadline=None)
@given(n_jobs=st.integers(2, 10), queries=st.integers(100, 2000))
def test_prop_kv_budget(n_jobs, queries):
    _check_kv_budget(n_jobs, queries)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["poisson", "mmpp", "flash"]),
       utilization=st.floats(0.5, 1.6))
def test_prop_batch1_equals_job_mode(seed, kind, utilization):
    _check_batch1_equals_job_mode(seed, kind, utilization, SynergAI)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["poisson", "mmpp", "diurnal", "flash",
                             "multi-tenant", "drift"]),
       serving=st.sampled_from(["job", "batched"]))
def test_prop_trace_replay_preserves(seed, kind, serving):
    _check_trace_replay_preserves(seed, kind, serving)


# ----------------------------------------------------------------------------
# seeded fallbacks: the same properties, pinned inputs, always run

@pytest.mark.parametrize("alpha,b_max", [(0.0, 8), (0.15, 16), (1.0, 32)])
def test_multiplier_monotone_seeded(alpha, b_max):
    _check_multiplier_monotone(alpha, b_max)


@pytest.mark.parametrize("seed,kind,max_batch,utilization", [
    (13, "mmpp", 4, 1.4),
    (29, "flash", 8, 0.9),
])
def test_budgets_and_streaming_seeded(seed, kind, max_batch, utilization):
    _check_budgets_and_streaming(seed, kind, max_batch, utilization)


def test_kv_budget_seeded():
    _check_kv_budget(7, 700)


@pytest.mark.parametrize("seed,kind,policy_cls", [
    (17, "mmpp", SynergAI),
    (23, "poisson", RoundRobin),
])
def test_batch1_equals_job_mode_seeded(seed, kind, policy_cls):
    _check_batch1_equals_job_mode(seed, kind, 1.2, policy_cls)


@pytest.mark.parametrize("seed,kind,serving", [
    (31, "mmpp", "job"),
    (37, "drift", "batched"),
    (41, "multi-tenant", "batched"),
])
def test_trace_replay_preserves_seeded(seed, kind, serving):
    _check_trace_replay_preserves(seed, kind, serving)
