"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle in interpret mode (assignment requirement), plus hypothesis
property tests on the scheduler-score kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or the skip shim

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_routing import moe_routing
from repro.kernels.rwkv_scan import rwkv_scan
from repro.kernels.scheduler_score import scheduler_score

TOL = dict(rtol=2e-3, atol=2e-3)
TOL32 = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


# ----------------------------------------------------------------------------
# flash attention: shape x dtype x mask sweep


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 256, 4, 1, 128),    # MQA, wide head
    (2, 128, 2, 2, 32),     # small head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, S, H, hd), dtype)
    k = rand(ks[1], (B, S, K, hd), dtype)
    v = rand(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, K, hd), jnp.float32)
    v = rand(ks[2], (B, S, K, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL32)


# ----------------------------------------------------------------------------
# decode attention


@pytest.mark.parametrize("B,S,H,K,hd,k_valid", [
    (2, 512, 8, 2, 64, 512),
    (1, 1024, 4, 1, 128, 700),   # partially filled cache
    (4, 512, 4, 4, 64, 33),      # barely-warm cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, S, H, K, hd, k_valid, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (B, 1, H, hd), dtype)
    k = rand(ks[1], (B, S, K, hd), dtype)
    v = rand(ks[2], (B, S, K, hd), dtype)
    out = decode_attention(q, k, v, k_valid, bk=256, interpret=True)
    want = ref.decode_attention_ref(q, k, v, k_valid)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


# ----------------------------------------------------------------------------
# moe routing


@pytest.mark.parametrize("T,D,E,k", [(256, 64, 8, 2), (128, 128, 16, 2),
                                     (256, 32, 160, 6)])
def test_moe_routing_sweep(T, D, E, k):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = rand(ks[0], (T, D), jnp.float32)
    w = rand(ks[1], (D, E), jnp.float32)
    gates = moe_routing(x, w, k, bt=128, interpret=True)
    want = ref.moe_routing_ref(x, w, k)
    np.testing.assert_allclose(np.asarray(gates), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    # exactly k experts selected per token, gates sum to 1
    nz = (np.asarray(gates) > 0).sum(axis=1)
    assert (nz == k).all()
    np.testing.assert_allclose(np.asarray(gates).sum(1), 1.0, rtol=1e-5)


# ----------------------------------------------------------------------------
# rwkv scan


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 128, 2, 32, 32), (2, 256, 4, 64, 64), (1, 64, 1, 16, 16)])
def test_rwkv_scan_sweep(B, S, H, hd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, H, hd), jnp.float32)
    v = rand(ks[2], (B, S, H, hd), jnp.float32)
    # decay in (0, 1) like exp(-exp(w))
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, S, H, hd), jnp.float32)))
    u = rand(ks[4], (H, hd), jnp.float32)
    out = rwkv_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    want = ref.rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_scan_matches_model_layer():
    """The kernel must agree with the model's sequential WKV recurrence."""
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    cfg = reduced(get_config("rwkv6-1.6b"))
    B, S = 2, 64
    hd = cfg.ssm.rwkv_head_dim
    H = cfg.d_model // hd
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, H, hd), jnp.float32)
    v = rand(ks[2], (B, S, H, hd), jnp.float32)
    w = jnp.exp(-jnp.exp(rand(ks[3], (B, S, H, hd), jnp.float32)))
    u = rand(ks[4], (H, hd), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(rwkv_scan(r, k, v, w, u, chunk=16, interpret=True)),
        np.asarray(ref.rwkv_scan_ref(r, k, v, w, u)), rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------------
# scheduler score (the paper's Eq. 2-4 at fleet scale)


def test_scheduler_score_matches_oracle():
    rng = np.random.default_rng(0)
    J, W = 300, 17
    qps = rng.uniform(0.5, 100, (J, W)).astype(np.float32)
    qps[rng.random((J, W)) < 0.2] = 0.0          # infeasible pairs
    pre = rng.uniform(0.1, 10, (J, W)).astype(np.float32)
    q = rng.integers(100, 5000, J).astype(np.float32)
    rem = rng.uniform(1, 2000, J).astype(np.float32)
    est, best, urg, acc = scheduler_score(qps, pre, q, rem, bj=128,
                                          interpret=True)
    est_r, best_r, urg_r, acc_r = ref.scheduler_score_ref(qps, pre, q, rem)
    feas = qps > 0
    np.testing.assert_allclose(np.asarray(est)[feas], est_r[feas],
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(acc), acc_r)
    np.testing.assert_array_equal(np.asarray(best), best_r)
    np.testing.assert_allclose(np.asarray(urg), urg_r, rtol=1e-4, atol=1e-2)


def test_scheduler_score_matches_core_estimator(configdict):
    """Kernel vs the production numpy estimator on a real queue."""
    from repro.core.estimator import estimate_matrix
    from repro.core.job import make_experiment
    workers = ["cloud-pod", "edge-large", "edge-small"]
    jobs = make_experiment(configdict, "DH", "FH", seed=11)
    now = 100.0
    s = estimate_matrix(configdict, jobs, workers, now)
    J, W = len(jobs), len(workers)
    qps = np.zeros((J, W), np.float32)
    pre = np.zeros((J, W), np.float32)
    for ji, job in enumerate(jobs):
        for wi, w in enumerate(workers):
            ent = configdict.optimal(job.engine, w)
            if ent:
                qps[ji, wi] = ent.qps
                pre[ji, wi] = ent.preproc_s
    q = np.array([j.queries for j in jobs], np.float32)
    rem = np.array([j.t_qos - (now - j.arrival) for j in jobs], np.float32)
    est, best, urg, acc = scheduler_score(qps, pre, q, rem, interpret=True)
    feas = np.isfinite(s.t_estimated)
    np.testing.assert_allclose(np.asarray(est)[feas],
                               s.t_estimated[feas].astype(np.float32),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(acc).astype(bool), s.acceptable)
    np.testing.assert_allclose(np.asarray(urg), s.urgency.astype(np.float32),
                               rtol=1e-4, atol=0.5)


# ----------------------------------------------------------------------------
# pure-*numpy* oracles (independent of the jnp ref module) + the padding
# edges, so moe_routing / rwkv_scan / the ops wrappers stop being dark


def _moe_np(x, w, top_k):
    """Numpy mirror of the kernel: softmax over router logits, iterative
    top-k with first-index tie-breaks, renormalized over the selection."""
    logits = np.asarray(x, np.float32) @ np.asarray(w, np.float32)
    e = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    remaining = probs.copy()
    sel = np.zeros_like(probs)
    for _ in range(top_k):
        pick = np.zeros_like(probs, bool)
        pick[np.arange(len(probs)), remaining.argmax(-1)] = True
        pick &= remaining > 0
        sel += np.where(pick, probs, 0.0)
        remaining[pick] = -1.0
    return sel / np.maximum(sel.sum(-1, keepdims=True), 1e-9)


def _rwkv_np(r, k, v, w, u):
    """Numpy mirror of the sequential WKV recurrence."""
    r, k, v, w = (np.asarray(a, np.float32) for a in (r, k, v, w))
    u = np.asarray(u, np.float32)
    B, S, H, hd = r.shape
    state = np.zeros((B, H, hd, hd), np.float32)
    out = np.zeros_like(r)
    for t in range(S):
        kv = k[:, t, :, :, None] * v[:, t, :, None, :]
        out[:, t] = np.einsum(
            "bhk,bhkv->bhv", r[:, t], state + u[None, :, :, None] * kv)
        state = w[:, t, :, :, None] * state + kv
    return out


@pytest.mark.parametrize("T,D,E,k,bt", [
    (96, 32, 8, 2, 128),     # T < bt: block clamps to the full batch
    (192, 64, 16, 1, 64),    # multi-block, top-1
    (128, 32, 6, 4, 128),    # k large relative to E
])
def test_moe_routing_vs_numpy_oracle(T, D, E, k, bt):
    rng = np.random.default_rng(42)
    x = rng.standard_normal((T, D)).astype(np.float32)
    w = rng.standard_normal((D, E)).astype(np.float32)
    got = np.asarray(moe_routing(x, w, k, bt=bt, interpret=True))
    np.testing.assert_allclose(got, _moe_np(x, w, k),
                               rtol=1e-4, atol=1e-5)
    assert ((got > 0).sum(axis=1) == k).all()


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 48, 2, 16, 64),      # S < chunk: single clamped chunk
    (2, 96, 1, 32, 32),      # multi-chunk, state carried across
])
def test_rwkv_scan_vs_numpy_oracle(B, S, H, hd, chunk):
    rng = np.random.default_rng(7)
    shape = (B, S, H, hd)
    r = rng.standard_normal(shape).astype(np.float32)
    k = rng.standard_normal(shape).astype(np.float32)
    v = rng.standard_normal(shape).astype(np.float32)
    w = np.exp(-np.exp(rng.standard_normal(shape))).astype(np.float32)
    u = rng.standard_normal((H, hd)).astype(np.float32)
    got = np.asarray(rwkv_scan(r, k, v, w, u, chunk=chunk,
                               interpret=True))
    np.testing.assert_allclose(got, _rwkv_np(r, k, v, w, u),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------------
# ops.py: the jit'd public wrappers (interpret auto-resolves off-TPU)


def test_ops_wrappers_match_references():
    from repro.kernels import ops

    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, S, H, K, hd = 1, 128, 4, 2, 32
    q = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, K, hd), jnp.float32)
    v = rand(ks[2], (B, S, K, hd), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v, causal=True)),
        np.asarray(ref.flash_attention_ref(q, k, v, causal=True)),
        **TOL32)

    qd = rand(ks[3], (B, 1, H, hd), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.decode_attention(qd, k, v, 100, bk=64)),
        np.asarray(ref.decode_attention_ref(qd, k, v, 100)), **TOL32)

    rng = np.random.default_rng(11)
    x = rng.standard_normal((128, 32)).astype(np.float32)
    w = rng.standard_normal((32, 8)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ops.moe_routing(x, w, 2)), _moe_np(x, w, 2),
        rtol=1e-4, atol=1e-5)

    shape = (1, 64, 2, 16)
    r_ = rng.standard_normal(shape).astype(np.float32)
    k_ = rng.standard_normal(shape).astype(np.float32)
    v_ = rng.standard_normal(shape).astype(np.float32)
    w_ = np.exp(-np.exp(rng.standard_normal(shape))).astype(np.float32)
    u_ = rng.standard_normal((2, 16)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ops.rwkv_scan(r_, k_, v_, w_, u_, chunk=32)),
        _rwkv_np(r_, k_, v_, w_, u_), rtol=2e-4, atol=2e-4)

    qps = rng.uniform(0.5, 50, (40, 7)).astype(np.float32)
    qps[rng.random((40, 7)) < 0.2] = 0.0
    pre = rng.uniform(0.1, 5, (40, 7)).astype(np.float32)
    qn = rng.integers(10, 500, 40).astype(np.float32)
    rem = rng.uniform(1, 500, 40).astype(np.float32)
    est, best, urg, acc = ops.scheduler_score(qps, pre, qn, rem, bj=64)
    est_r, best_r, urg_r, acc_r = ref.scheduler_score_ref(qps, pre, qn,
                                                          rem)
    np.testing.assert_array_equal(np.asarray(best), best_r)
    np.testing.assert_array_equal(np.asarray(acc), acc_r)
    feas = qps > 0
    np.testing.assert_allclose(np.asarray(est)[feas], est_r[feas],
                               rtol=1e-5)


# ----------------------------------------------------------------------------
# the fused whole-tick kernel (device-resident path): placement parity
# against a direct numpy transcription of SynergAI._place


def test_scheduler_tick_matches_numpy_placement():
    import jax.numpy as jnp2
    from repro.kernels.scheduler_score import scheduler_tick

    rng = np.random.default_rng(3)
    cap, W, J, bj = 64, 16, 40, 8
    Jp = 40
    pool = rng.uniform(0.5, 30, (cap, W)).astype(np.float32)
    pool[rng.random((cap, W)) < 0.15] = np.inf
    slots = rng.permutation(cap)[:J].astype(np.int32)
    t_rem = rng.uniform(-5, 40, J).astype(np.float32)
    pen = np.where(rng.random(W) < 0.3, 2.0, 1.0).astype(np.float32)
    bw = rng.uniform(0, 10, W).astype(np.float32)
    avail = rng.random(W) < 0.6
    zero = np.zeros(J, np.int32)
    inf = np.full(J, np.inf, np.float32)
    one = np.ones(J, np.float32)
    emask = np.ones((1, W), bool)
    assign, order = scheduler_tick(
        jnp2.asarray(pool), jnp2.asarray(pool), jnp2.asarray(pool),
        jnp2.zeros((1, W), jnp2.float32), jnp2.asarray(slots),
        jnp2.asarray(t_rem), jnp2.asarray(inf), jnp2.asarray(inf),
        jnp2.asarray(one), jnp2.asarray(zero), jnp2.asarray(zero),
        jnp2.asarray(zero), jnp2.asarray(zero), jnp2.asarray(emask),
        jnp2.asarray(pen), jnp2.asarray(bw),
        jnp2.asarray(np.zeros(W, np.float32)), jnp2.asarray(avail),
        bj=bj, interpret=True)
    assign, order = np.asarray(assign), np.asarray(order)

    # numpy transcription of the scoring + _place walk
    t = pool[slots] * pen[None, :]
    acc = t_rem[:, None] >= t
    urg = t_rem - pool[slots].min(axis=1)
    doom = ~acc.any(axis=1)
    feas = np.isfinite(t)
    costd = t + bw[None, :]
    best = np.where(feas, costd, np.inf).min(axis=1)
    elig = np.where(doom[:, None], feas & (t <= 1.5 * best[:, None]),
                    acc)
    ranked = np.where(elig, np.where(doom[:, None], costd, t), np.inf)
    want_order = np.lexsort((urg, doom))
    np.testing.assert_array_equal(order, want_order)
    want = np.full(J, -1, np.int32)
    open_slots = avail.copy()
    for ji in want_order:
        cand = np.where(open_slots, ranked[ji], np.inf)
        wi = int(cand.argmin())
        if np.isfinite(cand[wi]):
            want[ji] = wi
            open_slots[wi] = False
    np.testing.assert_array_equal(assign, want)
    assert (assign >= 0).any()          # something actually placed


@settings(max_examples=25, deadline=None)
@given(j=st.integers(1, 40), w=st.integers(1, 8), seed=st.integers(0, 999))
def test_scheduler_score_property(j, w, seed):
    rng = np.random.default_rng(seed)
    qps = rng.uniform(0, 50, (j, w)).astype(np.float32)
    pre = rng.uniform(0, 5, (j, w)).astype(np.float32)
    q = rng.integers(1, 1000, j).astype(np.float32)
    rem = rng.uniform(-10, 500, j).astype(np.float32)
    est, best, urg, acc = scheduler_score(qps, pre, q, rem, bj=16,
                                          interpret=True)
    est, best, urg, acc = map(np.asarray, (est, best, urg, acc))
    for ji in range(j):
        feas = qps[ji] > 0
        if not feas.any():
            assert best[ji] == -1
            continue
        # Eq. 4: chosen worker is acceptable-minimal when acceptance exists
        if acc[ji].any():
            cand = np.where(acc[ji], est[ji], np.inf)
            assert np.isclose(est[ji][best[ji]], cand.min())
        # urgency consistent with the min estimate
        assert np.isclose(urg[ji], rem[ji] - est[ji][feas].min(),
                          rtol=1e-4, atol=1e-2)
