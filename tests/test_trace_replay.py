"""Trace replay (``workload.save_trace`` / ``load_trace`` / ``replay``):
export → load → replay round-trips are exact at the full ``JobResult``
stream level, a replayed MMPP schedule is pinned bit-for-bit by a golden
digest, malformed trace files fail loudly with the offending line, and
``bench_traces`` reports every policy under replay / drift / a
correlated-region outage."""

import hashlib
import math
import os

import pytest

from repro.core.job import Job, Request
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import (load_trace, replay, save_trace, scenario,
                                 synth_failures)


def _result_key(results):
    """Every JobResult field that the simulator computes (job identity,
    placement, all timings, all flags) — bit-level, no rounding."""
    return sorted(
        (r.job.id, r.job.engine, r.job.queries, r.job.t_qos,
         r.job.arrival, r.job.tenant, r.worker, r.config, r.start, r.end,
         r.waiting, r.exec_s, r.e2e, r.violated, r.excess, r.overhead_s,
         r.ttft, r.tpot, r.ttft_violated, r.tpot_violated,
         r.prefill_worker) for r in results)


# ----------------------------------------------------------------------------
# round-trip equality


@pytest.mark.parametrize("serving,streaming", [
    ("job", None),
    ("batched", (2.0, 2.5)),
])
def test_export_load_replay_roundtrip_exact(configdict, tmp_path, serving,
                                            streaming):
    """A completed Simulator run exported with save_trace and fed back
    through replay reproduces the original JobResult stream exactly —
    including token-level Requests and streaming deadlines."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=80, fleet=fleet, seed=5,
                    utilization=1.2, serving=serving, streaming=streaming)
    res_a = Simulator(configdict, SynergAI(), fleet=fleet, seed=5,
                      serving=serving).run(jobs)
    path = tmp_path / "trace.jsonl"
    n = save_trace(path, res_a)                  # export the *run*
    assert n == 80
    replayed = replay(str(path))
    # the reloaded jobs are field-identical, ids preserved
    by_id = {j.id: j for j in jobs}
    for j in replayed:
        o = by_id[j.id]
        assert (j.engine, j.queries, j.t_qos, j.arrival, j.tenant) == \
            (o.engine, o.queries, o.t_qos, o.arrival, o.tenant)
        assert j.request == o.request
    res_b = Simulator(configdict, SynergAI(), fleet=fleet, seed=5,
                      serving=serving).run(replayed)
    assert _result_key(res_a) == _result_key(res_b)


def test_replay_accepts_jobs_results_and_paths(configdict, tmp_path):
    jobs = scenario(configdict, "poisson", n_jobs=20,
                    fleet=synth_fleet(1, 1, 1), seed=1)
    res = Simulator(configdict, SynergAI(),
                    fleet=synth_fleet(1, 1, 1), seed=1).run(jobs)
    path = tmp_path / "t.jsonl"
    save_trace(path, jobs)                       # from jobs ...
    a = [(j.id, j.arrival, j.engine) for j in replay(str(path))]
    save_trace(path, res)                        # ... and from results
    b = [(j.id, j.arrival, j.engine) for j in replay(str(path))]
    c = [(j.id, j.arrival, j.engine) for j in replay(res)]
    d = [(j.id, j.arrival, j.engine) for j in replay(jobs)]
    assert a == b == c == d


# ----------------------------------------------------------------------------
# golden digest: one replayed MMPP schedule, bit-for-bit

# sha256 over the canonical per-job result lines (repr floats) of
# scenario(mmpp, n_jobs=40, synth_fleet(1, 2, 2), seed=7,
# utilization=1.2) exported, replayed and run under SynergAI, seed=7.
# Any change to the trace format, the workload generators, the scheduler
# or the event heap that shifts this schedule by one bit fails here.
REPLAY_GOLDEN_DIGEST = \
    "91f3689b8ef38d43982aed542e312c381899d279d83064f5b3efe5f76e078189"


def test_golden_digest_replayed_mmpp(configdict, tmp_path):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2)
    path = tmp_path / "golden.jsonl"
    save_trace(path, jobs)
    res = Simulator(configdict, SynergAI(), fleet=fleet,
                    seed=7).run(replay(str(path)))
    canon = "\n".join(
        f"{r.job.id},{r.worker},{r.config},{r.start!r},{r.end!r},"
        f"{r.ttft!r},{r.tpot!r},{int(r.violated)}"
        for r in sorted(res, key=lambda r: r.job.id))
    assert hashlib.sha256(canon.encode()).hexdigest() == \
        REPLAY_GOLDEN_DIGEST


# ----------------------------------------------------------------------------
# malformed traces fail loudly


def _write(path, text):
    path.write_text(text)
    return str(path)


def test_malformed_trace_lines_raise(configdict, tmp_path):
    header = '{"synergai_trace": 1, "jobs": 1}\n'
    good = ('{"id": 0, "arrival": 0.5, "engine": "gemma-2b/bf16", '
            '"queries": 100, "t_qos": 9.0, "tenant": ""}\n')
    # happy path first: the fixture lines themselves are valid
    jobs = load_trace(_write(tmp_path / "ok.jsonl", header + good))
    assert jobs[0].engine == "gemma-2b/bf16" and jobs[0].request is None

    with pytest.raises(ValueError, match="empty file"):
        load_trace(_write(tmp_path / "empty.jsonl", ""))
    with pytest.raises(ValueError, match="not a SynergAI trace"):
        load_trace(_write(tmp_path / "nohdr.jsonl", good + good))
    with pytest.raises(ValueError, match="unsupported trace version"):
        load_trace(_write(tmp_path / "vers.jsonl",
                          '{"synergai_trace": 99}\n' + good))
    with pytest.raises(ValueError, match=":2: bad record"):
        load_trace(_write(tmp_path / "garbled.jsonl",
                          header + "not json at all\n"))
    with pytest.raises(ValueError, match=":3: bad job record"):
        load_trace(_write(tmp_path / "missing.jsonl",
                          header + good +
                          '{"id": 1, "arrival": 2.0}\n'))
    with pytest.raises(ValueError, match=":2: bad job record"):
        load_trace(_write(tmp_path / "mistyped.jsonl", header +
                          good.replace('"queries": 100',
                                       '"queries": "many"')))
    with pytest.raises(ValueError, match="promises 2 jobs"):
        load_trace(_write(tmp_path / "count.jsonl",
                          '{"synergai_trace": 1, "jobs": 2}\n' + good))
    with pytest.raises(ValueError, match=":3: duplicate job id 0"):
        load_trace(_write(tmp_path / "dup.jsonl",
                          '{"synergai_trace": 1, "jobs": 2}\n'
                          + good + good))


def test_save_trace_roundtrips_request_fields(tmp_path):
    jobs = [Job(0, "gemma-2b/bf16", 123, 4.5, 0.25, tenant="chat",
                request=Request(1000, 2000, ttft_qos=1.25,
                                tpot_qos=0.001)),
            Job(1, "qwen3-4b/bf16", 7, 8.25, 1.75)]
    path = tmp_path / "req.jsonl"
    save_trace(path, jobs)
    back = load_trace(str(path))
    assert back[0].request == jobs[0].request
    assert back[1].request is None
    assert [j.tenant for j in back] == ["chat", ""]


# ----------------------------------------------------------------------------
# bench_traces: every policy under replay / drift / correlated outage


def test_bench_traces_sections_and_replay_exactness(configdict):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import POLICIES, bench_traces
    out = bench_traces(configdict, n_jobs=250, pools=(1, 2, 2),
                       emit=lambda *_: None)
    assert out[("replay", "exact")]["replay_exact"] is True
    for section in ("replay", "drift", "outage"):
        for P in POLICIES:
            s = out[(section, P.name)]
            assert s["jobs"] == 250
            assert math.isfinite(s["e2e_p99_s"])
