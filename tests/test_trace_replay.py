"""Trace replay (``workload.save_trace`` / ``load_trace`` / ``replay``):
export → load → replay round-trips are exact at the full ``JobResult``
stream level, a replayed MMPP schedule is pinned bit-for-bit by a golden
digest, malformed trace files fail loudly with the offending line, and
``bench_traces`` reports every policy under replay / drift / a
correlated-region outage."""

import hashlib
import math
import os

import pytest

from repro.core.job import Job, Request
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import (load_trace, replay, save_trace, scenario,
                                 synth_failures)


def _result_key(results):
    """Every JobResult field that the simulator computes (job identity,
    placement, all timings, all flags) — bit-level, no rounding."""
    return sorted(
        (r.job.id, r.job.engine, r.job.queries, r.job.t_qos,
         r.job.arrival, r.job.tenant, r.worker, r.config, r.start, r.end,
         r.waiting, r.exec_s, r.e2e, r.violated, r.excess, r.overhead_s,
         r.ttft, r.tpot, r.ttft_violated, r.tpot_violated,
         r.prefill_worker) for r in results)


# ----------------------------------------------------------------------------
# round-trip equality


@pytest.mark.parametrize("serving,streaming", [
    ("job", None),
    ("batched", (2.0, 2.5)),
])
def test_export_load_replay_roundtrip_exact(configdict, tmp_path, serving,
                                            streaming):
    """A completed Simulator run exported with save_trace and fed back
    through replay reproduces the original JobResult stream exactly —
    including token-level Requests and streaming deadlines."""
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=80, fleet=fleet, seed=5,
                    utilization=1.2, serving=serving, streaming=streaming)
    res_a = Simulator(configdict, SynergAI(), fleet=fleet, seed=5,
                      serving=serving).run(jobs)
    path = tmp_path / "trace.jsonl"
    n = save_trace(path, res_a)                  # export the *run*
    assert n == 80
    replayed = replay(str(path))
    # the reloaded jobs are field-identical, ids preserved
    by_id = {j.id: j for j in jobs}
    for j in replayed:
        o = by_id[j.id]
        assert (j.engine, j.queries, j.t_qos, j.arrival, j.tenant) == \
            (o.engine, o.queries, o.t_qos, o.arrival, o.tenant)
        assert j.request == o.request
    res_b = Simulator(configdict, SynergAI(), fleet=fleet, seed=5,
                      serving=serving).run(replayed)
    assert _result_key(res_a) == _result_key(res_b)


def test_replay_accepts_jobs_results_and_paths(configdict, tmp_path):
    jobs = scenario(configdict, "poisson", n_jobs=20,
                    fleet=synth_fleet(1, 1, 1), seed=1)
    res = Simulator(configdict, SynergAI(),
                    fleet=synth_fleet(1, 1, 1), seed=1).run(jobs)
    path = tmp_path / "t.jsonl"
    save_trace(path, jobs)                       # from jobs ...
    a = [(j.id, j.arrival, j.engine) for j in replay(str(path))]
    save_trace(path, res)                        # ... and from results
    b = [(j.id, j.arrival, j.engine) for j in replay(str(path))]
    c = [(j.id, j.arrival, j.engine) for j in replay(res)]
    d = [(j.id, j.arrival, j.engine) for j in replay(jobs)]
    assert a == b == c == d


# ----------------------------------------------------------------------------
# golden digest: one replayed MMPP schedule, bit-for-bit

# sha256 over the canonical per-job result lines (repr floats) of
# scenario(mmpp, n_jobs=40, synth_fleet(1, 2, 2), seed=7,
# utilization=1.2) exported, replayed and run under SynergAI, seed=7.
# Any change to the trace format, the workload generators, the scheduler
# or the event heap that shifts this schedule by one bit fails here.
REPLAY_GOLDEN_DIGEST = \
    "91f3689b8ef38d43982aed542e312c381899d279d83064f5b3efe5f76e078189"


def test_golden_digest_replayed_mmpp(configdict, tmp_path):
    fleet = synth_fleet(1, 2, 2)
    jobs = scenario(configdict, "mmpp", n_jobs=40, fleet=fleet, seed=7,
                    utilization=1.2)
    path = tmp_path / "golden.jsonl"
    save_trace(path, jobs)
    res = Simulator(configdict, SynergAI(), fleet=fleet,
                    seed=7).run(replay(str(path)))
    canon = "\n".join(
        f"{r.job.id},{r.worker},{r.config},{r.start!r},{r.end!r},"
        f"{r.ttft!r},{r.tpot!r},{int(r.violated)}"
        for r in sorted(res, key=lambda r: r.job.id))
    assert hashlib.sha256(canon.encode()).hexdigest() == \
        REPLAY_GOLDEN_DIGEST


# ----------------------------------------------------------------------------
# malformed traces fail loudly


def _write(path, text):
    path.write_text(text)
    return str(path)


def test_malformed_trace_lines_raise(configdict, tmp_path):
    header = '{"synergai_trace": 1, "jobs": 1}\n'
    good = ('{"id": 0, "arrival": 0.5, "engine": "gemma-2b/bf16", '
            '"queries": 100, "t_qos": 9.0, "tenant": ""}\n')
    # happy path first: the fixture lines themselves are valid
    jobs = load_trace(_write(tmp_path / "ok.jsonl", header + good))
    assert jobs[0].engine == "gemma-2b/bf16" and jobs[0].request is None

    with pytest.raises(ValueError, match="empty file"):
        load_trace(_write(tmp_path / "empty.jsonl", ""))
    with pytest.raises(ValueError, match="not a SynergAI trace"):
        load_trace(_write(tmp_path / "nohdr.jsonl", good + good))
    with pytest.raises(ValueError, match="unsupported trace version"):
        load_trace(_write(tmp_path / "vers.jsonl",
                          '{"synergai_trace": 99}\n' + good))
    with pytest.raises(ValueError, match=":2: bad record"):
        load_trace(_write(tmp_path / "garbled.jsonl",
                          header + "not json at all\n"))
    with pytest.raises(ValueError, match=":3: bad job record"):
        load_trace(_write(tmp_path / "missing.jsonl",
                          header + good +
                          '{"id": 1, "arrival": 2.0}\n'))
    with pytest.raises(ValueError, match=":2: bad job record"):
        load_trace(_write(tmp_path / "mistyped.jsonl", header +
                          good.replace('"queries": 100',
                                       '"queries": "many"')))
    with pytest.raises(ValueError, match="promises 2 jobs"):
        load_trace(_write(tmp_path / "count.jsonl",
                          '{"synergai_trace": 1, "jobs": 2}\n' + good))
    with pytest.raises(ValueError, match=":3: duplicate job id 0"):
        load_trace(_write(tmp_path / "dup.jsonl",
                          '{"synergai_trace": 1, "jobs": 2}\n'
                          + good + good))


def test_save_trace_roundtrips_request_fields(tmp_path):
    jobs = [Job(0, "gemma-2b/bf16", 123, 4.5, 0.25, tenant="chat",
                request=Request(1000, 2000, ttft_qos=1.25,
                                tpot_qos=0.001)),
            Job(1, "qwen3-4b/bf16", 7, 8.25, 1.75)]
    path = tmp_path / "req.jsonl"
    save_trace(path, jobs)
    back = load_trace(str(path))
    assert back[0].request == jobs[0].request
    assert back[1].request is None
    assert [j.tenant for j in back] == ["chat", ""]


# ----------------------------------------------------------------------------
# bench_traces: every policy under replay / drift / correlated outage


def test_bench_traces_sections_and_replay_exactness(configdict):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import POLICIES, bench_traces
    out = bench_traces(configdict, n_jobs=250, pools=(1, 2, 2),
                       emit=lambda *_: None)
    assert out[("replay", "exact")]["replay_exact"] is True
    for section in ("replay", "drift", "outage"):
        for P in POLICIES:
            s = out[(section, P.name)]
            assert s["jobs"] == 250
            assert math.isfinite(s["e2e_p99_s"])
    for name in ("stale", "online", "oracle"):
        s = out[("drift+recharacterize", name)]
        assert s["jobs"] == 250
        assert math.isfinite(s["e2e_p99_s"])


def test_bench_drift_recovery_smoke_schema(configdict):
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    from scheduler_experiments import bench_drift_recovery
    blob = bench_drift_recovery(configdict, smoke=True,
                                emit=lambda *_: None)
    assert blob["schema"] == 1
    variants = [c["variant"] for c in blob["configs"]]
    assert variants == ["drift-stale", "drift-online", "drift-oracle"]
    for c in blob["configs"]:
        assert c["violations"] >= 0 and c["wall_s"] > 0
    online = blob["configs"][1]
    assert "violation_ratio_stale_vs_online" in online
    assert "drift_headline" not in blob        # smoke: no gating blob


# ----------------------------------------------------------------------------
# Azure-LLM-trace import adapter


AZURE_ROWS = [
    ("2023-11-16 18:17:00.9303036", 2048, 16),
    ("2023-11-16 18:17:01.0801247", 4096, 256),
    ("2023-11-16 18:17:01.3396663", 1024, 256),
    ("2023-11-16 18:17:01.5612882", 512, 512),
    ("2023-11-16 18:17:02.1179282", 1024, 512),
    ("2023-11-16 18:17:04.1489291", 4096, 64),
    ("2023-11-16 18:17:04.3345679", 128, 128),
    ("2023-11-16 18:17:06.6384600", 2048, 256),
    ("2023-11-16 18:17:07.6408494", 1024, 16),
    ("2023-11-16 18:17:08.4582986", 4096, 16),
    ("2023-11-16 18:17:09.6356801", 128, 64),
    ("2023-11-16 18:17:11.6934041", 512, 512),
]


def _azure_csv(path, rows=AZURE_ROWS,
               header="TIMESTAMP,ContextTokens,GeneratedTokens"):
    path.write_text(header + "\n" +
                    "\n".join(f"{t},{c},{g}" for t, c, g in rows) + "\n")
    return str(path)


def test_azure_adapter_jobs_and_roundtrip(configdict, tmp_path):
    """The adapter yields well-formed arrival-sorted jobs that replay
    bit-for-bit through the native trace format."""
    from repro.core.engines import engine_catalogue
    from repro.core.workload import load_azure_llm_trace
    path = _azure_csv(tmp_path / "azure.csv")
    jobs = load_azure_llm_trace(configdict, path)
    assert len(jobs) == len(AZURE_ROWS)
    assert [j.id for j in jobs] == list(range(len(jobs)))
    assert jobs[0].arrival == 0.0
    assert all(jobs[i].arrival <= jobs[i + 1].arrival
               for i in range(len(jobs) - 1))
    cat = set(engine_catalogue())
    for j in jobs:
        assert j.engine in cat
        assert j.queries >= 1 and j.t_qos > 0
        assert j.request is not None
    # the exact token counts survive verbatim
    by_arrival = sorted(AZURE_ROWS)
    assert [(j.request.prompt_tokens, j.request.decode_tokens)
            for j in jobs] == [(c, g) for _t, c, g in by_arrival]
    # round-trip through the native replay format, bit-for-bit
    out = tmp_path / "native.jsonl"
    save_trace(out, jobs)
    back = replay(str(out))
    assert [(j.id, j.arrival, j.engine, j.queries, j.t_qos, j.tenant,
             j.request) for j in jobs] == \
           [(j.id, j.arrival, j.engine, j.queries, j.t_qos, j.tenant,
             j.request) for j in back]
    # and the replayed jobs run
    fleet = synth_fleet(1, 1, 1)
    res = Simulator(configdict, SynergAI(), fleet=fleet, seed=0).run(back)
    assert len(res) == len(jobs)


def test_azure_adapter_options(configdict, tmp_path):
    from repro.core.workload import load_azure_llm_trace
    path = _azure_csv(tmp_path / "azure.csv")
    jobs = load_azure_llm_trace(configdict, path)
    # qos_scale scales every deadline; max_jobs truncates; the engine
    # mix spreads over more than one shape-tied engine
    scaled = load_azure_llm_trace(configdict, path, qos_scale=2.0)
    assert all(s.t_qos == pytest.approx(2 * j.t_qos)
               for s, j in zip(scaled, jobs))
    few = load_azure_llm_trace(configdict, path, max_jobs=3)
    assert len(few) == 3
    assert len({j.engine for j in jobs}) >= 2
    assert all(j.tenant == "azure" for j in jobs)
    # float-seconds timestamps and case-insensitive headers also parse
    alt = _azure_csv(tmp_path / "alt.csv",
                     rows=[("3.5", 256, 64), ("1.25", 512, 128)],
                     header="timestamp,CONTEXTTOKENS,generatedtokens")
    back = load_azure_llm_trace(configdict, alt)
    assert [j.arrival for j in back] == [0.0, 2.25]


def test_azure_adapter_malformed_rows_raise(configdict, tmp_path):
    from repro.core.workload import load_azure_llm_trace

    def load(name, text):
        p = tmp_path / name
        p.write_text(text)
        return lambda: load_azure_llm_trace(configdict, str(p))

    with pytest.raises(ValueError, match=":1: .*expected a CSV header"):
        load("empty.csv", "")()
    with pytest.raises(ValueError, match=":1: missing column"):
        load("cols.csv", "TIMESTAMP,ContextTokens\n1.0,5\n")()
    with pytest.raises(ValueError, match=":2: row has 2 cells"):
        load("short.csv",
             "TIMESTAMP,ContextTokens,GeneratedTokens\n1.0,5\n")()
    with pytest.raises(ValueError, match=":3: non-numeric token count"):
        load("nan.csv", "TIMESTAMP,ContextTokens,GeneratedTokens\n"
             "1.0,5,5\n2.0,five,5\n")()
    with pytest.raises(ValueError, match=":2: non-positive token"):
        load("zero.csv", "TIMESTAMP,ContextTokens,GeneratedTokens\n"
             "1.0,0,5\n")()
    with pytest.raises(ValueError, match=":2: bad TIMESTAMP"):
        load("when.csv", "TIMESTAMP,ContextTokens,GeneratedTokens\n"
             "someday,5,5\n")()
    with pytest.raises(ValueError, match="header but no rows"):
        load("hdr.csv", "TIMESTAMP,ContextTokens,GeneratedTokens\n")()
