"""Serving bridge (continuous batching, ``serving="batched"``): job-level
equivalence at forced batch size 1, single-request batches, KV-budget-
bounded batch formation, same-engine batching rules, token-count service
modulation, batching's throughput win under overload, and failure
recovery."""

import numpy as np
import pytest

from repro.core.baselines import RoundRobin
from repro.core.constants import OperatingMode
from repro.core.engines import default_engines
from repro.core.job import Job, Request, exec_time
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.serving_bridge import (batch_multiplier, batch_profile,
                                       batch_stats, batch_throughput,
                                       default_request, solo_service)
from repro.core.simulator import BatchedWorkerSim, Simulator
from repro.core.simulator_legacy import LegacySimulator
from repro.core.slo_mael import SloMael
from repro.core.workers import WorkerPool, synth_fleet
from repro.core.workload import attach_requests, scenario, synth_failures


def _key(results):
    return [(r.job.id, r.worker, r.config, r.start, r.end, r.waiting,
             r.exec_s, r.e2e, r.violated, r.excess, r.overhead_s)
            for r in results]


# ----------------------------------------------------------------------------
# batching math


def test_multiplier_and_throughput_shape():
    assert batch_multiplier(0.5, 1) == 1.0
    ms = [batch_multiplier(0.5, b) for b in range(1, 9)]
    assert all(a > b for a, b in zip(ms, ms[1:]))     # members slow down
    ts = [batch_throughput(0.5, b) for b in range(1, 9)]
    assert all(a < b for a, b in zip(ts, ts[1:]))     # aggregate speeds up
    # alpha=1 is processor sharing: no aggregate gain
    assert batch_throughput(1.0, 8) == pytest.approx(1.0)


def test_batched_worker_multiplier_matches_bridge(configdict):
    ws = BatchedWorkerSim(synth_fleet(1, 0, 0)[0], batch_alpha_=0.35)
    for b in (1, 2, 5, 11):
        assert ws.multiplier(b) == batch_multiplier(0.35, b)


def test_solo_service_default_tokens_match_exec_time(configdict):
    spec = default_engines()["gemma-2b/bf16"]
    pool = synth_fleet(1, 0, 0)[0]
    ent = configdict.optimal(spec.name, pool.name)
    prof = batch_profile(ent, spec, pool)
    # no Request: bit-for-bit exec_time
    work, prefill = solo_service(ent, prof, None, 1234)
    assert work == exec_time(ent, 1234)
    assert ent.preproc_s < prefill < work
    # engine-default Request: algebraically the same service time
    work_r, _ = solo_service(ent, prof, default_request(spec, 1234), 1234)
    assert np.isclose(work_r, work, rtol=1e-9)


# ----------------------------------------------------------------------------
# single-request batches + job-level equivalence


def test_single_request_batch_is_exactly_job_level(configdict):
    job = Job(0, "gemma-2b/bf16", 1000, 500.0, 0.0)
    sim = Simulator(configdict, SynergAI(), exec_noise=0.0,
                    serving="batched")
    res = sim.run([job])
    assert len(res) == 1
    r = res[0]
    ent = configdict.optimal(r.job.engine, r.worker)
    assert r.exec_s == exec_time(ent, r.job.queries)
    ws = sim.cluster.workers[r.worker]
    assert ws.peak_batch == 1 and ws.admitted == 1
    assert not ws.active                                # batch drained
    spec = default_engines()["gemma-2b/bf16"]
    assert ws.decoded_tokens == 1000 * spec.decode_len


@pytest.mark.parametrize("policy_cls", [SynergAI, SloMael, RoundRobin])
def test_forced_batch1_matches_job_level(configdict, policy_cls):
    """max_batch=1 on un-annotated jobs is the job-level simulator,
    bit-for-bit (same schedule, same noise draws, same results)."""
    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "mmpp", n_jobs=250, fleet=fleet, seed=5)
    a = Simulator(configdict, policy_cls(), fleet=fleet, seed=5).run(jobs)
    b = Simulator(configdict, policy_cls(), fleet=fleet, seed=5,
                  serving="batched", max_batch=1).run(jobs)
    assert _key(a) == _key(b)


# ----------------------------------------------------------------------------
# batch formation


def test_kv_budget_caps_batch_formation(configdict):
    """A worker whose HBM fits weights + ~2.5 microbatch caches must cap
    its continuous batch at 2 members even with max_batch slots free."""
    from repro.core.perfmodel import profile_engine
    spec = default_engines()["gemma-2b/bf16"]
    prof = profile_engine(spec)
    hbm = 1.2 * (prof.weights_bytes + 2.5 * prof.kv_bytes) / 0.9
    pool = WorkerPool("tiny", 1, (OperatingMode("m", 1.0, 1, 1000.0),),
                      (1, 1), True, chip_hbm_bytes=hbm)
    cd = characterize({spec.name: spec}, [pool])
    ent = cd.optimal(spec.name, "tiny")
    bp = batch_profile(ent, spec, pool)
    assert bp.kv_limit == 2
    jobs = [Job(i, spec.name, 500, 1e6, 0.0) for i in range(6)]
    sim = Simulator(cd, SynergAI(), fleet=[pool], serving="batched",
                    max_batch=8, exec_noise=0.0)
    res = sim.run(jobs)
    assert len(res) == 6
    ws = sim.cluster.workers["tiny"]
    assert ws.peak_batch == 2          # KV-evicted, not slot-evicted
    assert ws.kv_limit == 2


def test_batches_are_same_engine_only(configdict):
    fleet = synth_fleet(1, 0, 0)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, serving="batched")
    ws = sim.cluster.workers[fleet[0].name]
    spec = default_engines()["gemma-2b/bf16"]
    ent = configdict.optimal(spec.name, fleet[0].name)
    prof = batch_profile(ent, spec, fleet[0])
    ws.admit(0.0, 0, spec.name, ent, prof, default_request(spec, 100),
             10.0, 2.0)
    assert ws.can_admit(spec.name, 0.0)
    assert not ws.can_admit("qwen3-4b/bf16", 0.0)    # live batch: gemma only
    ws.finish(0)
    assert ws.can_admit("qwen3-4b/bf16", 0.0)        # empty batch: model swap


def test_depth_penalty_views(configdict):
    fleet = synth_fleet(1, 0, 0)
    sim = Simulator(configdict, SynergAI(), fleet=fleet, serving="batched",
                    max_batch=2)
    cluster = sim.cluster
    name = fleet[0].name
    ws = cluster.workers[name]
    assert cluster.depth_penalty(name, 0.0) == 1.0   # empty batch
    spec = default_engines()["gemma-2b/bf16"]
    ent = configdict.optimal(spec.name, name)
    prof = batch_profile(ent, spec, fleet[0])
    ws.admit(0.0, 0, spec.name, ent, prof, default_request(spec, 100),
             10.0, 2.0)
    assert cluster.depth_penalty(name, 0.0) == 1.0 + ws.batch_alpha_
    ws.admit(0.0, 1, spec.name, ent, prof, default_request(spec, 100),
             10.0, 2.0)
    # full batch: a job would wait it out, no join penalty
    assert cluster.depth_penalty(name, 0.0) == 1.0
    job = Job(9, "qwen3-4b/bf16", 100, 1e6, 0.0)
    assert not cluster.admit_ok(job, name, 0.0)


# ----------------------------------------------------------------------------
# token-level requests


def test_attach_requests_and_scenario_knob(configdict):
    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "multi-tenant", n_jobs=150, fleet=fleet,
                    seed=2)
    assert all(j.request is None for j in jobs)
    jobs = scenario(configdict, "multi-tenant", n_jobs=150, fleet=fleet,
                    seed=2, serving="batched")
    assert all(j.request is not None for j in jobs)
    assert all(j.request.prompt_tokens > 0 and j.request.decode_tokens > 0
               for j in jobs)
    # same seed -> same annotations
    again = scenario(configdict, "multi-tenant", n_jobs=150, fleet=fleet,
                     seed=2, serving="batched")
    assert [j.request for j in jobs] == [j.request for j in again]
    with pytest.raises(ValueError):
        scenario(configdict, "mmpp", n_jobs=10, serving="nope")


def test_token_counts_modulate_service_time(configdict):
    spec = default_engines()["gemma-2b/bf16"]
    base = 1000 * spec.decode_len

    def run_one(decode_tokens):
        job = Job(0, spec.name, 1000, 1e6, 0.0,
                  request=Request(1000 * spec.prefill_len, decode_tokens))
        res = Simulator(configdict, SynergAI(), exec_noise=0.0,
                        serving="batched").run([job])
        return res[0].exec_s

    assert run_one(4 * base) > run_one(base) > run_one(base // 4)


# ----------------------------------------------------------------------------
# end-to-end: throughput, conservation, failures


def test_batching_wins_under_overload(configdict):
    """The point of the bridge: under sustained overload, continuous
    batching drains the queue faster than exclusive job-level service —
    fewer QoS violations and a lower p99."""
    fleet = synth_fleet(2, 3, 3)
    stats = {}
    for serving in ("job", "batched"):
        jobs = scenario(configdict, "mmpp", n_jobs=400, fleet=fleet,
                        seed=3, utilization=1.5, serving=serving)
        res = Simulator(configdict, SynergAI(), fleet=fleet, seed=3,
                        serving=serving).run(jobs)
        assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
        e2e = sorted(r.e2e for r in res)
        stats[serving] = (sum(r.violated for r in res),
                          e2e[int(0.99 * len(e2e))])
    assert stats["batched"][0] < stats["job"][0]
    assert stats["batched"][1] < stats["job"][1]


def test_batched_failures_requeue_and_complete(configdict):
    fleet = synth_fleet(2, 3, 3)
    jobs = scenario(configdict, "flash", n_jobs=300, fleet=fleet, seed=4,
                    serving="batched")
    failures = synth_failures(fleet, jobs[-1].arrival, mtbf_s=400.0,
                              mttr_s=80.0, seed=4)
    assert failures
    sim = Simulator(configdict, SynergAI(), fleet=fleet, failures=failures,
                    seed=4, serving="batched")
    res = sim.run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    for r in res:       # nothing completes inside a failure window
        for f in failures:
            if f.worker == r.worker:
                assert (r.end <= f.at + 1e-6
                        or r.end >= f.at + f.duration - 1e-6), (r, f)
    assert batch_stats(sim.cluster)    # bridge actually served batches


def test_batched_conservation_and_stats(configdict):
    fleet = synth_fleet(2, 2, 2)
    jobs = scenario(configdict, "poisson", n_jobs=300, fleet=fleet,
                    seed=1, utilization=1.2, serving="batched")
    sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=1,
                    serving="batched")
    res = sim.run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    for r in res:
        assert r.start >= r.job.arrival - 1e-9
        assert np.isclose(r.e2e, r.end - r.job.arrival)
        assert r.exec_s > 0 and r.excess >= 0
        assert r.violated == (r.e2e > r.job.t_qos)
    st = batch_stats(sim.cluster)
    assert sum(s["admitted"] for s in st.values()) == len(jobs)
    assert max(s["peak_batch"] for s in st.values()) > 1
    assert all(s["decoded_tokens"] > 0 for s in st.values())


def test_batched_elastic_clones_serve_and_retire(configdict):
    fleet = synth_fleet(1, 1, 1)
    jobs = scenario(configdict, "flash", n_jobs=200, fleet=fleet, seed=2,
                    utilization=1.5, serving="batched")
    sim = Simulator(configdict, SynergAI(), fleet=fleet, seed=2,
                    serving="batched", elastic_max=3, elastic_threshold=4)
    res = sim.run(jobs)
    assert sorted(r.job.id for r in res) == sorted(j.id for j in jobs)
    assert any("__clone" in r.worker for r in res)   # clones took traffic
    assert sim._clones == 0                          # ...and retired idle


# ----------------------------------------------------------------------------
# guard rails


def test_legacy_simulator_rejects_batched(configdict):
    sim = LegacySimulator(configdict, SynergAI(), serving="batched")
    with pytest.raises(NotImplementedError):
        sim.run([Job(0, "gemma-2b/bf16", 100, 100.0, 0.0)])


def test_speculative_batched_combination_rejected(configdict):
    with pytest.raises(ValueError):
        Simulator(configdict, SynergAI(), serving="batched",
                  speculative=True)
    with pytest.raises(ValueError):
        Simulator(configdict, SynergAI(), serving="typo")
