"""Incremental scheduler core: score-cache invalidation + equivalence.

Covers the PR's acceptance anchors: the cross-tick ``ScoreCache`` keeps
``SynergAI`` bit-for-bit identical to the uncached full-matrix path
(deterministic runs + a hypothesis property behind the conftest shim
with seeded fallbacks, both serving modes), elastic clone arrivals
*extend* the cached columns instead of flushing, failures bump the
fleet generation and flush, a first-sighted engine extends the
``_EngineTable`` rows mid-run, and the ``Cluster`` struct-of-arrays
mirror agrees with the per-worker scalar state at every tick."""

import functools

import numpy as np
import pytest
from conftest import given, settings, st

from repro.core.estimator import estimate_matrix
from repro.core.job import Job, make_experiment
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.scorecache import ScoreCache
from repro.core.simulator import (BatchedWorkerSim, FailureEvent,
                                  Simulator)
from repro.core.workers import synth_fleet
from repro.core.workload import scenario


@functools.lru_cache(maxsize=None)
def _cd():
    # session-style cache that doesn't tangle pytest fixtures with @given
    return characterize()


def _result_key(results):
    return [(r.job.id, r.worker, r.config, r.start, r.end, r.waiting,
             r.exec_s, r.e2e, r.violated, r.excess, r.ttft, r.tpot)
            for r in results]


def _run(cd, policy, jobs, **kw):
    return _result_key(Simulator(cd, policy, **kw).run(jobs))


# ----------------------------------------------------------------------------
# cached == uncached, deterministically

def _check_cached_equals_uncached(seed, kind, utilization, serving,
                                  streaming=None, disaggregate=False,
                                  failures=False, elastic=0):
    cd = _cd()
    fleet = synth_fleet(1, 2, 2, disaggregate=disaggregate)
    jobs = scenario(cd, kind, n_jobs=120, fleet=fleet, seed=seed,
                    utilization=utilization, serving=serving,
                    streaming=streaming)
    kw = dict(fleet=fleet, seed=seed, serving=serving)
    if failures:
        span = jobs[-1].arrival
        from repro.core.workload import synth_failures
        kw["failures"] = synth_failures(fleet, span, mtbf_s=span / 2,
                                        mttr_s=60.0, seed=seed)
    if elastic:
        kw.update(elastic_max=elastic, elastic_threshold=4)
    a = _run(cd, SynergAI(), jobs, **kw)
    b = _run(cd, SynergAI(incremental=False), jobs, **kw)
    assert a == b


@pytest.mark.parametrize("seed,kind,serving,streaming,disagg", [
    (1, "mmpp", "job", None, False),
    (2, "poisson", "batched", None, False),
    (3, "mmpp", "batched", (2.0, 2.5), False),
    (4, "multi-tenant", "batched", (1.5, 2.0), True),
    (5, "drift", "job", None, False),
])
def test_cached_equals_uncached_seeded(seed, kind, serving, streaming,
                                       disagg):
    _check_cached_equals_uncached(seed, kind, 1.1, serving,
                                  streaming=streaming,
                                  disaggregate=disagg)


def test_cached_equals_uncached_under_failures_and_elastic():
    _check_cached_equals_uncached(7, "mmpp", 1.3, "job", failures=True)
    _check_cached_equals_uncached(8, "flash", 1.3, "job", elastic=2)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["poisson", "mmpp", "flash", "multi-tenant"]),
       utilization=st.floats(0.6, 1.5),
       serving=st.sampled_from(["job", "batched"]))
def test_cached_equals_uncached_property(seed, kind, utilization, serving):
    """Cached and uncached SynergAI produce identical assignment streams
    under random workloads in both serving modes."""
    _check_cached_equals_uncached(seed, kind, utilization, serving)


# ----------------------------------------------------------------------------
# invalidation: elastic columns, failure generations, drift engines

def _sim_cluster(cd, serving="job", fleet=None):
    sim = Simulator(cd, SynergAI(), fleet=fleet, serving=serving)
    return sim, sim.cluster


def test_elastic_clone_extends_columns(configdict):
    """Appending a pool (elastic provisioning) extends the cached rows by
    the new columns — no flush, and the widened rows match a fresh
    uncached score of the same queue."""
    import dataclasses

    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    sim, cluster = _sim_cluster(cd, fleet=fleet)
    jobs = make_experiment(cd, "DL", "FH", seed=3)
    cache = ScoreCache()
    slots = cache.sync(cd, jobs, cluster)
    assert cache.flushes == 0 and cache.col_extends == 0
    w0 = cache._W
    base = cluster.workers["cloud-pod"].pool
    clone = dataclasses.replace(base, name="cloud-pod__clone1")
    cluster.workers[clone.name] = cluster._make_worker(clone)
    gen_before = cluster.fleet_gen
    slots2 = cache.sync(cd, jobs, cluster)
    assert cluster.fleet_gen == gen_before          # sync reads, no bump
    assert cache.col_extends == 1 and cache.flushes == 0
    assert cache._W == w0 + 1
    names = cluster.arrays.names
    ref = estimate_matrix(cd, jobs, names, now=0.0)
    np.testing.assert_array_equal(cache.t_matrix(slots2),
                                  ref.t_estimated)
    np.testing.assert_array_equal(cache.min_estimate(slots2),
                                  ref.t_estimated.min(axis=1))
    # retiring a pool is a non-append membership change -> flush
    del cluster.workers[clone.name]
    cache.sync(cd, jobs, cluster)
    assert cache.flushes == 1


def test_failure_bumps_fleet_gen_and_flushes(configdict):
    cd = configdict
    _, cluster = _sim_cluster(cd)
    jobs = make_experiment(cd, "DL", "FL", seed=1)
    cache = ScoreCache()
    cache.sync(cd, jobs, cluster)
    gen = cluster.fleet_gen
    # the failure-injection write (what Simulator.run does on a
    # FailureEvent) bumps the failure generation...
    cluster.workers["edge-large"].failed_until = 50.0
    assert cluster.fleet_gen == gen + 1
    assert cluster.fail_gen >= 1
    # ...which invalidates the cache wholesale on the next tick
    cache.sync(cd, jobs, cluster)
    assert cache.flushes == 1
    # rows are rebuilt and still exact
    slots = cache.sync(cd, jobs, cluster)
    ref = estimate_matrix(cd, jobs, cluster.arrays.names, now=0.0)
    np.testing.assert_array_equal(cache.t_matrix(slots), ref.t_estimated)


def test_first_sighted_engine_extends_table_rows():
    """A drift trace can surface an engine mid-run; its rows extend the
    shared ``_EngineTable`` and the cache on first sighting."""
    from repro.core.estimator import _table

    cd = characterize()     # fresh ConfigDict: an untouched row cache
    _, cluster = _sim_cluster(cd)
    jobs = [Job(i, "gemma-2b/bf16", 1000, 500.0, float(i))
            for i in range(6)]
    cache = ScoreCache()
    cache.sync(cd, jobs, cluster)
    tab = _table(cd, cluster.arrays.names, False,
                 token=cluster.worker_token)
    n0 = len(tab.index)
    assert "qwen3-32b/bf16" not in tab.index
    late = Job(99, "qwen3-32b/bf16", 800, 500.0, 6.0)
    slots = cache.sync(cd, jobs + [late], cluster)
    assert len(tab.index) == n0 + 1 and "qwen3-32b/bf16" in tab.index
    ref = estimate_matrix(cd, jobs + [late], cluster.arrays.names,
                          now=0.0)
    np.testing.assert_array_equal(cache.t_matrix(slots), ref.t_estimated)


def test_argmin_hint_is_true_minimizer_through_column_extension(configdict):
    """``argmin_estimate`` (the incremental depth-penalty fast path's
    acquittal hint) always points at a true minimizer of the cached row
    — including after an elastic column extension changes which worker
    is fastest."""
    import dataclasses

    cd = configdict
    fleet = synth_fleet(1, 2, 2)
    sim, cluster = _sim_cluster(cd, fleet=fleet)
    jobs = make_experiment(cd, "DL", "FH", seed=3)
    cache = ScoreCache()

    def check(slots):
        t = cache.t_matrix(slots)
        amin = cache.argmin_estimate(slots)
        np.testing.assert_array_equal(t[np.arange(len(t)), amin],
                                      t.min(axis=1))
        np.testing.assert_array_equal(cache.min_estimate(slots),
                                      t.min(axis=1))

    check(cache.sync(cd, jobs, cluster))
    # append a cloud clone: the extension path must keep the hint valid
    base = cluster.workers["cloud-pod"].pool
    clone = dataclasses.replace(base, name="cloud-pod__amin")
    cluster.workers[clone.name] = cluster._make_worker(clone)
    slots = cache.sync(cd, jobs, cluster)
    assert cache.col_extends == 1
    check(slots)


class _PenProbeSynergAI(SynergAI):
    """Flags ticks where the batched depth penalty is actually active
    (some worker mid-batch), so the equivalence assertion below is
    known to exercise the penalized incremental fast path."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.saw_penalty = False

    def schedule(self, now, queue, cluster):
        if (cluster.depth_penalty_array(now) != 1.0).any():
            self.saw_penalty = True
        return super().schedule(now, queue, cluster)


def test_penalized_incremental_matches_uncached_at_depth():
    """serving='batched' with live batch depths: the incremental lazy
    path (argmin-hint doom short-circuit over penalized rows) stays
    bit-for-bit identical to the uncached full-matrix path, and batch
    depth actually changed across ticks (the penalty was exercised)."""
    cd = _cd()
    fleet = synth_fleet(1, 2, 2)
    for seed in (3, 9):
        jobs = scenario(cd, "mmpp", n_jobs=150, fleet=fleet, seed=seed,
                        utilization=1.5, serving="batched")
        kw = dict(fleet=fleet, seed=seed, serving="batched")
        probe = _PenProbeSynergAI()
        a = _run(cd, probe, jobs, **kw)
        b = _run(cd, SynergAI(incremental=False), jobs, **kw)
        assert probe.saw_penalty     # depth > 0 happened mid-run
        assert a == b


def test_requeued_job_reuses_warm_row(configdict):
    """Slots are reclaimed lazily: a job that leaves the queue (placed)
    and comes back (failure requeue) finds its row slot intact."""
    cd = configdict
    _, cluster = _sim_cluster(cd)
    jobs = make_experiment(cd, "DL", "FL", seed=2)
    cache = ScoreCache()
    cache.sync(cd, jobs, cluster)
    computed = cache.rows_computed
    slot_of_first = cache._slot[jobs[0].id]
    # job 0 departs for a tick, then returns
    cache.sync(cd, jobs[1:], cluster)
    slots = cache.sync(cd, jobs, cluster)
    assert cache.rows_computed == computed       # no recompute
    assert cache._slot[jobs[0].id] == slot_of_first
    ref = estimate_matrix(cd, jobs, cluster.arrays.names, now=0.0)
    np.testing.assert_array_equal(cache.t_matrix(slots), ref.t_estimated)


# ----------------------------------------------------------------------------
# struct-of-arrays mirror: vector views == scalar predicates, every tick

class _ProbingSynergAI(SynergAI):
    """Asserts the Cluster struct-of-arrays mirror against the scalar
    worker state (and the vector masks against the scalar predicates)
    on every scheduling tick, then schedules normally."""

    def schedule(self, now, queue, cluster):
        a = cluster.arrays
        avail = cluster.avail_array(now)
        busy_wait = cluster.busy_wait_array(now)
        pen = cluster.depth_penalty_array(now)
        for i, (name, ws) in enumerate(cluster.workers.items()):
            assert a.names[i] == name
            assert a.busy_until[i] == ws.busy_until
            assert a.failed_until[i] == ws.failed_until
            assert bool(avail[i]) == ws.idle(now)
            assert busy_wait[i] == max(0.0, ws.busy_until - now,
                                       ws.failed_until - now)
            assert pen[i] == cluster.depth_penalty(name, now)
            if isinstance(ws, BatchedWorkerSim):
                assert a.depth[i] == len(ws.active)
        for eng in {j.engine for j in queue}:
            for ph in ("full", "prefill", "decode"):
                m = cluster.admit_engine_mask(eng, now, ph)
                for i, name in enumerate(a.names):
                    assert bool(m[i]) == cluster.admit_engine_ok(
                        eng, name, now, phase=ph), (eng, ph, name)
        return super().schedule(now, queue, cluster)


@pytest.mark.parametrize("serving,disagg", [("job", False),
                                            ("batched", False),
                                            ("batched", True)])
def test_soa_mirror_consistent_through_run(configdict, serving, disagg):
    fleet = synth_fleet(1, 2, 2, disaggregate=disagg)
    jobs = scenario(configdict, "mmpp", n_jobs=80, fleet=fleet, seed=6,
                    utilization=1.1, serving=serving)
    failures = [FailureEvent("edge-large", at=20.0, duration=30.0)]
    res = Simulator(configdict, _ProbingSynergAI(), fleet=fleet, seed=6,
                    serving=serving, failures=failures).run(jobs)
    assert len(res) == len(jobs)


def test_soa_mirror_tracks_elastic_membership(configdict):
    jobs = scenario(configdict, "flash", n_jobs=120, seed=9,
                    utilization=1.5)
    sim = Simulator(configdict, _ProbingSynergAI(), seed=9,
                    elastic_max=2, elastic_threshold=4)
    res = sim.run(jobs)
    assert len(res) == len(jobs)
    # clones retired once pressure subsided -> mirror followed the dict
    assert len(sim.cluster.arrays.names) == len(sim.cluster.workers)
