"""Trace replay demo: export a completed run as a JSON-lines serving
trace, replay it bit-for-bit, then stress the same fleet with the two
scenario axes that make recorded traffic interesting again:

* **replay** — ``save_trace`` writes every job (arrival, engine, token
  counts, tenant, QoS budgets) at full float precision;
  ``replay(path)`` feeds it back through the event heap and reproduces
  the original ``JobResult`` stream exactly (same fleet / policy /
  simulator seed).  Recorded production traffic becomes a regression
  benchmark.
* **drift** — ``scenario(kind="drift")``: engine popularity migrates
  from the edge-friendly mix the offline phase calibrated for onto the
  heavyweights (``DriftedArrivals``), so the scheduler's profiled
  expectations go stale mid-trace.
* **correlated outage** — ``synth_failures(regions=True, correlation=)``
  downs a sampled fraction of a region's pools simultaneously
  (shared-infrastructure failures), instead of independent single-pool
  blips.

    PYTHONPATH=src python examples/replay_trace.py [--jobs 1500]
        [--utilization 1.3] [--regions 3] [--correlation 0.6]
"""

import argparse
import os
import tempfile
import time

from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import replay, save_trace, scenario, synth_failures

parser = argparse.ArgumentParser(
    description=__doc__,
    formatter_class=argparse.RawDescriptionHelpFormatter)
parser.add_argument("--jobs", type=int, default=1500)
parser.add_argument("--pools", type=int, nargs=3, default=(2, 5, 5),
                    metavar=("CLOUD", "EDGE_LG", "EDGE_SM"))
parser.add_argument("--utilization", type=float, default=1.3)
parser.add_argument("--regions", type=int, default=3,
                    help="region count for the correlated-outage run")
parser.add_argument("--correlation", type=float, default=0.6,
                    help="fraction of a region downed per outage event")
parser.add_argument("--trace", default=None,
                    help="replay this trace file instead of generating "
                         "(and exporting) an mmpp overload trace")
args = parser.parse_args()

cd = characterize()
fleet = synth_fleet(*args.pools, regions=args.regions)


def run(jobs, failures=(), label=""):
    t0 = time.perf_counter()
    res = Simulator(cd, SynergAI(), fleet=fleet, failures=failures,
                    seed=0).run(jobs)
    s = summarize(res)
    print(f"{label:18s} violations={s['violations']:5d} "
          f"wait={s['waiting_avg_s']:7.1f}s p99={s['e2e_p99_s']:7.1f}s "
          f"wall={time.perf_counter() - t0:4.1f}s")
    return res


if args.trace:
    jobs = replay(args.trace)
    print(f"replaying {len(jobs)} jobs from {args.trace}\n")
    run(jobs, label="replay")
else:
    jobs = scenario(cd, "mmpp", n_jobs=args.jobs, fleet=fleet,
                    utilization=args.utilization, seed=0)
    base = run(jobs, label="recorded run")
    fd, path = tempfile.mkstemp(suffix=".jsonl", prefix="synergai_mmpp_")
    os.close(fd)
    n = save_trace(path, base)                    # export the *run*
    replayed = run(replay(path), label="replayed trace")
    key = lambda rs: sorted((r.job.id, r.worker, r.start, r.end)
                            for r in rs)
    print(f"{'':18s} exported {n} records -> {path}; "
          f"bit-for-bit: {key(base) == key(replayed)}\n")

    # the same fleet under engine-popularity drift
    run(scenario(cd, "drift", n_jobs=args.jobs, fleet=fleet,
                 utilization=args.utilization, seed=0), label="drift")

    # ... and under a correlated regional outage
    span = jobs[-1].arrival
    failures = synth_failures(fleet, span, mtbf_s=span, mttr_s=180.0,
                              seed=0, regions=True,
                              correlation=args.correlation)
    outages = len({f.at for f in failures})
    run(jobs, failures=failures,
        label=f"{outages} region outages")
