"""End-to-end serving driver (the paper's kind of workload): batched
requests flow through the SynergAI scheduler onto engine replicas, and the
selected engine actually executes generation with a real model + KV cache.

The model is a reduced-config arch (CPU-friendly); on a TPU fleet the same
code path runs the full configs under the production mesh.

    PYTHONPATH=src python examples/serve_cluster.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.core.estimator import candidate_order, estimate_matrix
from repro.core.offline import characterize
from repro.models.registry import build_model
from repro.serving.engine import InferenceEngine

# --- bring up two real engine replicas (reduced configs on CPU) -----------
ARCHS = {"qwen3-4b/bf16": "qwen3-4b", "rwkv6-1.6b/bf16": "rwkv6-1.6b"}
replicas = {}
for engine_name, arch in ARCHS.items():
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    replicas[engine_name] = InferenceEngine(model, params, max_len=64)
print(f"brought up {len(replicas)} engine replicas")

# --- offline phase: the scheduler's view of the fleet ----------------------
cd = characterize()
workers = ["cloud-pod", "edge-large", "edge-small"]


# --- request loop: schedule with Eq. 1-4, execute on the replica ----------
class Request:
    def __init__(self, rid, engine, prompt_len, gen_len, t_qos):
        self.id, self.engine = rid, engine
        self.queries = 50
        self.t_qos = t_qos
        self.arrival = time.perf_counter()
        self.prompt_len, self.gen_len = prompt_len, gen_len


requests = [
    Request(0, "qwen3-4b/bf16", 16, 8, t_qos=30.0),
    Request(1, "rwkv6-1.6b/bf16", 16, 8, t_qos=30.0),
    Request(2, "qwen3-4b/bf16", 32, 8, t_qos=60.0),
]

key = jax.random.PRNGKey(7)
for req in requests:
    # SynergAI worker selection (Eq. 1-4) against the fleet model
    score = estimate_matrix(cd, [req], workers, now=0.0)
    order = candidate_order(score, 0)
    worker = workers[order[0]] if order else "cloud-pod"
    ent = cd.optimal(req.engine, worker)
    # execute on the local replica (stands in for the selected worker)
    eng = replicas[req.engine]
    toks = jax.random.randint(key, (2, req.prompt_len), 0,
                              eng.model.cfg.vocab)
    t0 = time.perf_counter()
    out = eng.generate({"tokens": toks}, req.gen_len)
    dt = time.perf_counter() - t0
    print(f"req {req.id} [{req.engine}] -> {worker} "
          f"(c*={ent.mode}/r{ent.chips_per_replica}); generated "
          f"{out.shape[1]} tokens x batch {out.shape[0]} in {dt:.2f}s")

s = replicas["qwen3-4b/bf16"].stats
print(f"\nqwen replica stats: prefill {s.prefill_tokens} tok, "
      f"decoded {s.decoded_tokens} tok over {s.batches} batches")
