"""Hierarchical region-sharded scheduling demo: route a multi-region
fleet through the two-level scheduler and compare it against flat
SynergAI on identical traffic.

* **two levels** — ``HierarchicalSynergAI`` keeps an O(k) global
  router (per-region engine capacity, failure health, queue pressure,
  drift-tracking engine-mix EWMA) that homes each arrival to a region;
  k per-region SynergAI cores then score only their own pools over
  region-sliced score-cache views.
* **spillover** — a job whose home region is saturated may run in a
  foreign region, paying the ``REGION_XFER`` WAN charge (``xfer_s`` on
  the assignment); the demo counts spills and shows the charge.
* **per-region calibration** — ``regional_scenario`` generates one
  independently calibrated stream per region (rate *and* feasible
  engine mix) and merges by arrival time, so small regions are not
  over-driven by a global rate.
* **flat equivalence** — with one region (or an untagged fleet) the
  hierarchical wrapper delegates wholesale to flat SynergAI,
  bit-for-bit; the demo checks it live.

    PYTHONPATH=src python examples/route_regions.py [--jobs 2000]
        [--regions 16] [--utilization 1.1]
"""

import argparse
import time

from repro.core.hierarchy import HierarchicalSynergAI
from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import region_rates, regional_scenario

parser = argparse.ArgumentParser(
    description=__doc__,
    formatter_class=argparse.RawDescriptionHelpFormatter)
parser.add_argument("--jobs", type=int, default=2000)
parser.add_argument("--pools", type=int, nargs=3, default=(4, 14, 14),
                    metavar=("CLOUD", "EDGE_LG", "EDGE_SM"))
parser.add_argument("--regions", type=int, default=16)
parser.add_argument("--utilization", type=float, default=1.1)
args = parser.parse_args()

cd = characterize()
fleet = synth_fleet(*args.pools, regions=args.regions)
rates = region_rates(cd, fleet, utilization=args.utilization)
print(f"{len(fleet)} pools across {len(rates)} regions; per-region "
      f"arrival rates {min(rates.values()):.2f}"
      f"-{max(rates.values()):.2f} jobs/s")

jobs = regional_scenario(cd, "mmpp", n_jobs=args.jobs, fleet=fleet,
                         utilization=args.utilization, seed=0)


def run(pol, label):
    t0 = time.perf_counter()
    res = Simulator(cd, pol, fleet=fleet, seed=0).run(jobs)
    s = summarize(res)
    spills = getattr(pol, "spills", 0)
    print(f"{label:12s} violations={s['violations']:5d} "
          f"wait={s['waiting_avg_s']:6.1f}s p99={s['e2e_p99_s']:6.1f}s "
          f"spills={spills:4d} wall={time.perf_counter() - t0:5.1f}s")
    return res


flat = run(SynergAI(), "flat")
hier_pol = HierarchicalSynergAI()
hier = run(hier_pol, "hierarchical")

# the WAN charge shows up on spilled placements only
spilled = hier_pol.spills
if spilled:
    print(f"{'':12s} {spilled} placements crossed regions and paid the "
          f"REGION_XFER WAN charge")

# flat equivalence: one region (or no tags) collapses to flat SynergAI
one = synth_fleet(1, 2, 2, regions=1)
jobs1 = regional_scenario(cd, "mmpp", n_jobs=200, fleet=one,
                          utilization=1.1, seed=1)
key = lambda rs: sorted((r.job.id, r.worker, r.start, r.end) for r in rs)
a = Simulator(cd, SynergAI(), fleet=one, seed=1).run(jobs1)
b = Simulator(cd, HierarchicalSynergAI(), fleet=one, seed=1).run(jobs1)
print(f"{'':12s} regions=1 bit-for-bit flat: {key(a) == key(b)}")
