"""Reproduce the paper's §5 evaluation: SynergAI vs five baselines and
SLO-MAEL across DL-FL / DL-FH / DH-FH (Figures 7-10).

    PYTHONPATH=src python examples/scheduler_comparison.py
"""

import numpy as np

from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                  MostRecentlyUsed, RoundRobin,
                                  StrictRoundRobin)
from repro.core.job import make_experiment
from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.slo_mael import SloMael

cd = characterize()
policies = [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
            MostRecentlyUsed, BestEffort, SloMael, SynergAI]
totals = {}
for exp, (d, f) in [("DL-FL", ("DL", "FL")), ("DL-FH", ("DL", "FH")),
                    ("DH-FH", ("DH", "FH"))]:
    print(f"\n=== {exp} (24 jobs x 5 seeds) ===")
    for P in policies:
        v, wait, excess = 0, [], []
        for seed in (1, 2, 3, 4, 5):
            jobs = make_experiment(cd, d, f, seed=seed)
            s = summarize(Simulator(cd, P(), seed=seed).run(jobs))
            v += s["violations"]
            wait.append(s["waiting_avg_s"])
            excess.append(s["excess_avg_s"])
        totals[P.name] = totals.get(P.name, 0) + v
        print(f"  {P.name:9s} violations={v:3d}  wait={np.mean(wait):7.1f}s"
              f"  excess={np.mean(excess):7.1f}s")

syn = totals["SynergAI"]
print(f"\nSLO-MAEL / SynergAI violations: {totals['SLO-MAEL'] / syn:.2f}x "
      f"(paper: 2.4x)")
base = np.mean([totals[n] for n in ["RR", "SRR", "LRU", "MRU", "BE"]])
print(f"baselines / SynergAI violations: {base / syn:.2f}x (paper: 7.1x)")
