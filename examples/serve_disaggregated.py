"""Streaming-QoS demo: TTFT/TPOT deadlines on aggregated vs
prefill/decode-disaggregated pools.

Every job carries per-class streaming SLOs (``Request.ttft_qos`` /
``tpot_qos``, stamped by ``scenario(..., streaming=...)``).  The same
overloaded trace is served twice with continuous batching:

* **aggregated** — every pool serves whole jobs; a burst of prefills
  queues behind long-running decode-heavy batches, so time-to-first-token
  suffers.
* **disaggregated** — ``synth_fleet(..., disaggregate=...)`` tags
  replicas prefill-only or decode-only; prefill pools turn over in the
  short prompt pass, the KV cache ships over the disaggregation link
  (``serving_bridge.kv_transfer_s``), and the decode phase is placed
  independently.  First tokens come fast; the shrunken decode side pays
  in TPOT — the classic trade.

Design note: docs/serving_bridge.md (streaming + disaggregation
sections).

    PYTHONPATH=src python examples/serve_disaggregated.py [--jobs 1500]
        [--kind mmpp] [--utilization 1.3] [--prefill-frac 0.4]
"""

import argparse
import time

from repro.core.metrics import summarize, summarize_by_tenant
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import SCENARIOS, scenario

parser = argparse.ArgumentParser(
    description=__doc__,
    formatter_class=argparse.RawDescriptionHelpFormatter)
parser.add_argument("--jobs", type=int, default=1500)
parser.add_argument("--pools", type=int, nargs=3, default=(2, 5, 5),
                    metavar=("CLOUD", "EDGE_LG", "EDGE_SM"))
parser.add_argument("--kind", choices=SCENARIOS, default="mmpp")
parser.add_argument("--utilization", type=float, default=1.3)
parser.add_argument("--ttft-scale", type=float, default=2.0,
                    help="TTFT deadline as a multiple of each engine's "
                         "profiled first-token time")
parser.add_argument("--tpot-scale", type=float, default=2.5,
                    help="TPOT deadline as a multiple of each engine's "
                         "profiled per-token decode time")
parser.add_argument("--prefill-frac", type=float, default=0.4,
                    help="share of each archetype's replicas tagged "
                         "prefill-only in the disaggregated fleet")
args = parser.parse_args()

cd = characterize()
streaming = (args.ttft_scale, args.tpot_scale)
print(f"{args.kind} x {args.jobs} jobs at {args.utilization:.1f}x "
      f"capacity; TTFT/TPOT scales {streaming}\n")

for label, fleet in (
        ("aggregated", synth_fleet(*args.pools)),
        ("disaggregated", synth_fleet(*args.pools,
                                      disaggregate=args.prefill_frac))):
    jobs = scenario(cd, args.kind, n_jobs=args.jobs, fleet=fleet,
                    utilization=args.utilization, seed=0,
                    serving="batched", streaming=streaming)
    sim = Simulator(cd, SynergAI(), fleet=fleet, seed=0, serving="batched")
    t0 = time.perf_counter()
    res = sim.run(jobs)
    wall = time.perf_counter() - t0
    s = summarize(res)
    print(f"{label:14s} ttft_viol={s['ttft_violations']:5d} "
          f"tpot_viol={s['tpot_violations']:5d} "
          f"e2e_viol={s['violations'] :5d} "
          f"ttft_p99={s['ttft_p99_s']:6.1f}s "
          f"tpot_p99={1e3 * s['tpot_p99_s']:6.2f}ms "
          f"wall={wall:4.1f}s")
    if label == "disaggregated":
        n_split = sum(r.prefill_worker is not None
                      and r.prefill_worker != r.worker for r in res)
        print(f"{'':14s} {n_split} of {len(res)} jobs decoded on a "
              f"different pool than they prefilled on")
        for tenant, ts in summarize_by_tenant(res).items():
            print(f"{'':14s} tenant {tenant:12s} "
                  f"ttft_p99={ts.get('ttft_p99_s', float('nan')):6.1f}s "
                  f"ttft_viol={ts['ttft_violations']}")
