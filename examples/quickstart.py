"""Quickstart: characterize the fleet offline, then schedule a burst of
inference jobs with SynergAI — the paper's full pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.job import make_experiment
from repro.core.metrics import placement, summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator

# --- Offline phase (paper §4.1): DSE over engines x workers x modes -------
cd = characterize()
print("Configuration Dictionary entries:", len(cd.table))
ent = cd.optimal("qwen3-32b/bf16", "cloud-pod")
print(f"qwen3-32b on cloud-pod -> c* = {ent.mode}/r{ent.chips_per_replica} "
      f"({ent.qps:.1f} QPS, bottleneck: {ent.bottleneck})")

# --- Online phase (paper §4.2): QoS-aware scheduling ----------------------
jobs = make_experiment(cd, demand="DL", freq="FH", seed=0)
sim = Simulator(cd, SynergAI(), seed=0)
results = sim.run(jobs)
stats = summarize(results)
print(f"\nscheduled {stats['jobs']} jobs: "
      f"{stats['violations']} QoS violations, "
      f"avg wait {stats['waiting_avg_s']:.1f}s, "
      f"avg e2e {stats['e2e_avg_s']:.1f}s")
print("placement:", {k: f"{v:.0%}" for k, v in placement(results).items()})
