"""Serving-bridge demo: the same bursty trace served job-level vs through
continuous batching (``serving="batched"``), with per-pool batch stats.

The bridge gives the scheduler eyes on batching — the dominant real-world
throughput lever: token-level requests (Pareto-sampled prompt/decode
counts), same-engine batch formation under slot + KV-cache-byte budgets,
and queue-depth-adjusted latency estimates.  Under load, batched serving
drains the backlog several times faster at far fewer QoS violations.
Design note: docs/serving_bridge.md.

    PYTHONPATH=src python examples/serve_bridge.py [--jobs 1500]
        [--kind mmpp] [--utilization 1.3] [--max-batch 8]
"""

import argparse
import time

from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.serving_bridge import batch_stats
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import SCENARIOS, scenario

parser = argparse.ArgumentParser(
    description=__doc__,
    formatter_class=argparse.RawDescriptionHelpFormatter)
parser.add_argument("--jobs", type=int, default=1500)
parser.add_argument("--pools", type=int, nargs=3, default=(2, 5, 5),
                    metavar=("CLOUD", "EDGE_LG", "EDGE_SM"))
parser.add_argument("--kind", choices=SCENARIOS, default="mmpp")
parser.add_argument("--utilization", type=float, default=1.3,
                    help="offered load vs job-level fleet capacity; >1 "
                         "overloads exclusive serving, which batching "
                         "absorbs")
parser.add_argument("--max-batch", type=int, default=8,
                    help="continuous-batch slot budget per worker")
args = parser.parse_args()

cd = characterize()
fleet = synth_fleet(*args.pools)
print(f"fleet: {len(fleet)} pools; {args.kind} x {args.jobs} jobs at "
      f"{args.utilization:.1f}x job-level capacity\n")

rows = {}
for serving in ("job", "batched"):
    jobs = scenario(cd, args.kind, n_jobs=args.jobs, fleet=fleet,
                    utilization=args.utilization, seed=0, serving=serving)
    sim = Simulator(cd, SynergAI(), fleet=fleet, seed=0, serving=serving,
                    max_batch=args.max_batch)
    t0 = time.perf_counter()
    res = sim.run(jobs)
    wall = time.perf_counter() - t0
    s = summarize(res)
    rows[serving] = s
    print(f"{serving:8s} violations={s['violations']:5d} "
          f"wait={s['waiting_avg_s']:7.1f}s p99={s['e2e_p99_s']:7.1f}s "
          f"makespan={max(r.end for r in res):7.0f}s wall={wall:5.2f}s")
    if serving == "batched":
        st = batch_stats(sim.cluster)
        top = sorted(st.items(), key=lambda kv: -kv[1]["decoded_tokens"])
        print("\nbusiest batched pools:")
        for name, v in top[:5]:
            print(f"  {name:16s} admitted={v['admitted']:5d} "
                  f"peak_batch={v['peak_batch']:2d} "
                  f"prefill_tok={v['prefill_tokens'] / 1e6:7.1f}M "
                  f"decode_tok={v['decoded_tokens'] / 1e6:7.1f}M")

v_job, v_bat = rows["job"]["violations"], rows["batched"]["violations"]
print(f"\nheadline: batching cuts QoS violations "
      f"{v_job / max(1, v_bat):.1f}x "
      f"(p99 {rows['job']['e2e_p99_s']:.0f}s -> "
      f"{rows['batched']['e2e_p99_s']:.0f}s)")
