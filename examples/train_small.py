"""Train a small LM for a few hundred steps with checkpoint/restart —
the fault-tolerance leg of the framework (kill it mid-run and re-launch:
it resumes from the latest atomic checkpoint).

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.training import checkpoint
from repro.training.data import DataLoader
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt_small")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced(get_config("qwen3-4b"), n_layers=4, d_model=128, d_ff=512,
                  n_heads=4, n_kv_heads=2, head_dim=32, vocab=512)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)

    start = 0
    latest = checkpoint.latest_step(args.ckpt_dir)
    if latest is not None:
        import numpy as np
        state = checkpoint.restore(args.ckpt_dir,
                                   jax.tree.map(np.asarray, state))
        start = latest
        print(f"resumed from checkpoint at step {start}")

    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    dl = DataLoader(cfg.vocab, batch=16, seq=64, seed=start)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(dl).items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % 20 == 0:
            print(f"step {step + 1:4d}  loss {float(metrics['loss']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({(step + 1 - start) / (time.time() - t0):.1f} it/s)")
        if (step + 1) % args.ckpt_every == 0:
            path = checkpoint.save(args.ckpt_dir, step + 1, state)
            print(f"checkpointed -> {path}")
    dl.close()
    print("done; final loss should be well below the ~6.2 random baseline")


if __name__ == "__main__":
    main()
