"""Fleet-scale scenario demo: a 64-pool synthetic cluster serving bursty,
diurnal and multi-tenant traffic with worker failures, scheduled by
SynergAI on the event-heap simulator — optionally scored by the Pallas
kernel, optionally served through the continuous-batching serving bridge
(--serving batched; see docs/serving_bridge.md).

    PYTHONPATH=src python examples/fleet_scale.py [--jobs 2000] [--pallas]
    PYTHONPATH=src python examples/fleet_scale.py --serving batched
"""

import argparse
import time

from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.workers import synth_fleet
from repro.core.workload import (SCENARIOS, index_of_dispersion, scenario,
                                 synth_failures)

parser = argparse.ArgumentParser()
parser.add_argument("--jobs", type=int, default=2000)
parser.add_argument("--pools", type=int, nargs=3, default=(8, 28, 28),
                    metavar=("CLOUD", "EDGE_LG", "EDGE_SM"))
parser.add_argument("--serving", choices=("job", "batched"),
                    default="job",
                    help="service model: exclusive job-level occupancy "
                         "(paper §5.1) or the continuous-batching serving "
                         "bridge (token-level requests, KV-bounded "
                         "batches)")
parser.add_argument("--max-batch", type=int, default=8,
                    help="continuous-batch slot budget per worker "
                         "(batched serving only)")
parser.add_argument("--pallas", action="store_true",
                    help="score with the Pallas kernel; interpret mode "
                         "emulates the TPU op-by-op on CPU, so keep "
                         "--jobs <= ~100 off-accelerator")
args = parser.parse_args()

cd = characterize()
fleet = synth_fleet(*args.pools)
print(f"fleet: {len(fleet)} pools "
      f"(cloud={args.pools[0]}, edge-large={args.pools[1]}, "
      f"edge-small={args.pools[2]})")

score_fn = None
if args.pallas:
    from repro.core.pallas_scoring import make_pallas_score_fn
    score_fn = make_pallas_score_fn()

for kind in SCENARIOS:
    jobs = scenario(cd, kind, n_jobs=args.jobs, fleet=fleet,
                    seed=0, serving=args.serving)
    span = jobs[-1].arrival
    disp = index_of_dispersion([j.arrival for j in jobs], 60.0)
    failures = synth_failures(fleet, span, mtbf_s=2 * span, mttr_s=120.0,
                              seed=0)
    t0 = time.perf_counter()
    res = Simulator(cd, SynergAI(score_fn=score_fn), fleet=fleet,
                    failures=failures, seed=0, serving=args.serving,
                    max_batch=args.max_batch).run(jobs)
    dt = time.perf_counter() - t0
    s = summarize(res)
    print(f"{kind:13s} span={span:7.0f}s dispersion={disp:6.1f} "
          f"failures={len(failures):3d} violations={s['violations']:5d} "
          f"wait={s['waiting_avg_s']:7.1f}s p99={s['e2e_p99_s']:7.1f}s "
          f"wall={dt:5.2f}s")
