"""Fig. 7/8/9/10 analogues: the three experiments (DL-FL, DL-FH, DH-FH) for
all policies + SLO-MAEL comparison, aggregated over seeds."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                  MostRecentlyUsed, RoundRobin,
                                  StrictRoundRobin)
from repro.core.job import make_experiment
from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.slo_mael import SloMael

POLICIES = [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
            MostRecentlyUsed, BestEffort, SloMael, SynergAI]
EXPERIMENTS = [("DL-FL", "DL", "FL"), ("DL-FH", "DL", "FH"),
               ("DH-FH", "DH", "FH")]


def run(cd=None, seeds=(1, 2, 3, 4, 5), emit=print):
    cd = cd or characterize()
    results = {}
    for exp, d, f in EXPERIMENTS:
        for P in POLICIES:
            agg = {"violations": 0, "waiting": [], "e2e": [], "p99": [],
                   "excess": [], "overhead": []}
            for seed in seeds:
                jobs = make_experiment(cd, d, f, seed=seed)
                s = summarize(Simulator(cd, P(), seed=seed).run(jobs))
                agg["violations"] += s["violations"]
                agg["waiting"].append(s["waiting_avg_s"])
                agg["e2e"].append(s["e2e_avg_s"])
                agg["p99"].append(s["e2e_p99_s"])
                agg["excess"].append(s["excess_avg_s"])
                agg["overhead"].append(s["overhead_avg_s"])
            results[(exp, P.name)] = agg
            emit(f"scheduler,{exp},{P.name},"
                 f"violations={agg['violations']},"
                 f"wait_s={np.mean(agg['waiting']):.1f},"
                 f"e2e_s={np.mean(agg['e2e']):.1f},"
                 f"p99_s={np.mean(agg['p99']):.1f},"
                 f"excess_s={np.mean(agg['excess']):.1f}")
    # headlines vs the paper
    v = lambda name: sum(results[(e, name)]["violations"]
                         for e, _, _ in EXPERIMENTS)
    base_names = ["RR", "SRR", "LRU", "MRU", "BE"]
    v_syn, v_mael = v("SynergAI"), v("SLO-MAEL")
    v_base = np.mean([v(n) for n in base_names])
    e_syn = np.mean([np.mean(results[(e, "SynergAI")]["excess"])
                     for e, _, _ in EXPERIMENTS])
    e_base = np.mean([np.mean(results[(e, n)]["excess"])
                      for e, _, _ in EXPERIMENTS for n in base_names])
    emit(f"scheduler_headline,slomael_over_synergai="
         f"{v_mael / max(1, v_syn):.2f}x,paper=2.4x")
    emit(f"scheduler_headline,baselines_over_synergai="
         f"{v_base / max(1, v_syn):.2f}x,paper=7.1x")
    emit(f"scheduler_headline,excess_baselines_over_synergai="
         f"{e_base / max(e_syn, 1e-9):.2f}x,paper=5.3x")
    return results
