"""Fig. 7/8/9/10 analogues: the three experiments (DL-FL, DL-FH, DH-FH) for
all policies + SLO-MAEL comparison, aggregated over seeds — plus the
fleet-scale benches:

* ``bench_fleet``   — 10k-job x 64-pool MMPP scenario under every policy on
  the event-heap engine, and the old-vs-new simulator wall-clock
  head-to-head (seed tick-scanning loop vs indexed event heap).
* ``bench_scoring`` — numpy ``estimate_matrix`` vs the Pallas
  ``scheduler_score`` kernel at J~2048 x W=256.
* ``bench_serving`` — job-level vs batched (serving bridge) QoS and
  wall-clock on an overloaded MMPP fleet scenario: the same trace served
  with exclusive job occupancy vs continuous batching
  (``serving="batched"``).
* ``bench_streaming`` — streaming QoS under the bridge: the mmpp overload
  preset with per-class TTFT/TPOT deadlines, served on an aggregated
  fleet vs a prefill/decode-disaggregated one
  (``synth_fleet(..., disaggregate=...)``).  Headline: disaggregation
  cuts TTFT violations (prefill pools turn over fast; decodes can't camp
  on them) at the cost of TPOT pressure on the shrunken decode side.
* ``bench_sched_overhead`` — per-tick scheduler *decision* wall-clock
  under a standing MMPP backlog with queue churn: the PR4 uncached
  full-matrix path vs the incremental score-cache path
  (``SynergAI(incremental=False)`` vs default) vs the Pallas backends
  (v1 kernel, fused v2), sweeping J up to 50k jobs and W up to 256
  pools.  Writes ``BENCH_SCHED.json`` (``--sched-json``) — the committed
  copy at the repo root is the perf-trajectory baseline that
  ``tools/check_perf_regression.py`` gates nightly CI against.
* ``bench_traces`` — the trace-driven scenario subsystem: every policy on
  (a) a *replayed* mmpp overload trace (exported with ``save_trace``,
  fed back through ``replay`` — the SynergAI replay is checked
  bit-for-bit against the exporting run), (b) engine-popularity *drift*
  (``scenario(kind="drift")``: the offline-calibrated mix goes stale
  mid-trace), and (c) a *correlated-region outage*
  (``synth_failures(regions=..., correlation=...)``: a sampled fraction
  of a region's pools goes down simultaneously).
* ``bench_overload`` — goodput under 2x sustained overload with
  flapping regional failures and a WAN partition: uncontrolled vs
  ``OverloadController`` (doom shedding + per-region queue caps) on the
  identical trace and fault timeline.  Headline: controlled goodput
  >= 1.5x uncontrolled at bounded p99 queue depth
  (``overload_headline``, gated nightly).

Run standalone:  PYTHONPATH=src python benchmarks/scheduler_experiments.py
(see --help for the fleet/scoring/serving knobs; ``--json`` dumps the
fleet/serving/streaming bench outputs for CI artifacts)
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                  MostRecentlyUsed, RoundRobin,
                                  StrictRoundRobin)
from repro.core.job import make_experiment
from repro.core.metrics import summarize
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.slo_mael import SloMael

POLICIES = [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
            MostRecentlyUsed, BestEffort, SloMael, SynergAI]
EXPERIMENTS = [("DL-FL", "DL", "FL"), ("DL-FH", "DL", "FH"),
               ("DH-FH", "DH", "FH")]


def run(cd=None, seeds=(1, 2, 3, 4, 5), emit=print):
    cd = cd or characterize()
    results = {}
    for exp, d, f in EXPERIMENTS:
        for P in POLICIES:
            agg = {"violations": 0, "waiting": [], "e2e": [], "p99": [],
                   "excess": [], "overhead": []}
            for seed in seeds:
                jobs = make_experiment(cd, d, f, seed=seed)
                s = summarize(Simulator(cd, P(), seed=seed).run(jobs))
                agg["violations"] += s["violations"]
                agg["waiting"].append(s["waiting_avg_s"])
                agg["e2e"].append(s["e2e_avg_s"])
                agg["p99"].append(s["e2e_p99_s"])
                agg["excess"].append(s["excess_avg_s"])
                agg["overhead"].append(s["overhead_avg_s"])
            results[(exp, P.name)] = agg
            emit(f"scheduler,{exp},{P.name},"
                 f"violations={agg['violations']},"
                 f"wait_s={np.mean(agg['waiting']):.1f},"
                 f"e2e_s={np.mean(agg['e2e']):.1f},"
                 f"p99_s={np.mean(agg['p99']):.1f},"
                 f"excess_s={np.mean(agg['excess']):.1f}")
    # headlines vs the paper
    v = lambda name: sum(results[(e, name)]["violations"]
                         for e, _, _ in EXPERIMENTS)
    base_names = ["RR", "SRR", "LRU", "MRU", "BE"]
    v_syn, v_mael = v("SynergAI"), v("SLO-MAEL")
    v_base = np.mean([v(n) for n in base_names])
    e_syn = np.mean([np.mean(results[(e, "SynergAI")]["excess"])
                     for e, _, _ in EXPERIMENTS])
    e_base = np.mean([np.mean(results[(e, n)]["excess"])
                      for e, _, _ in EXPERIMENTS for n in base_names])
    emit(f"scheduler_headline,slomael_over_synergai="
         f"{v_mael / max(1, v_syn):.2f}x,paper=2.4x")
    emit(f"scheduler_headline,baselines_over_synergai="
         f"{v_base / max(1, v_syn):.2f}x,paper=7.1x")
    emit(f"scheduler_headline,excess_baselines_over_synergai="
         f"{e_base / max(e_syn, 1e-9):.2f}x,paper=5.3x")
    return results


# ---------------------------------------------------------------------------
# fleet scale


def bench_fleet(cd=None, n_jobs=10_000, pools=(8, 28, 28),
                utilization=0.8, kind="mmpp", with_failures=True,
                emit=print):
    """The 10k-job x 64-pool scenario under every policy (event heap), then
    the old-vs-new simulator wall-clock comparison."""
    from repro.core.simulator import Simulator
    from repro.core.simulator_legacy import LegacySimulator
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario, synth_failures

    cd = cd or characterize()
    fleet = synth_fleet(*pools)
    W = len(fleet)
    jobs = scenario(cd, kind, n_jobs=n_jobs, fleet=fleet,
                    utilization=utilization, seed=0)
    span = jobs[-1].arrival
    failures = (synth_failures(fleet, span, mtbf_s=span, mttr_s=120.0,
                               seed=0) if with_failures else [])
    emit(f"fleet_scenario,{kind},jobs={n_jobs},pools={W},"
         f"span_s={span:.0f},failures={len(failures)}")
    walls = {}
    for P in POLICIES:
        t0 = time.perf_counter()
        res = Simulator(cd, P(), fleet=fleet, failures=failures,
                        seed=0).run(jobs)
        dt = time.perf_counter() - t0
        walls[(P.name, "event-heap")] = (dt, sum(r.violated for r in res))
        s = summarize(res)
        emit(f"fleet,{kind},{P.name},violations={s['violations']},"
             f"wait_s={s['waiting_avg_s']:.1f},p99_s={s['e2e_p99_s']:.1f},"
             f"wall_s={dt:.2f},jobs_per_s={n_jobs / dt:.0f}")
    # old vs new: the seed's tick-scanning loop (rescans every worker,
    # failure and running job per iteration) against the indexed event
    # heap, on the full trace.  The event-heap runs above (same trace,
    # same seed) already produced the "new" numbers; only the legacy loop
    # needs to run here.  SynergAI is scoring-bound so the engines tie
    # there; the cheap policies expose the loop overhead itself.
    for P in (SynergAI, RoundRobin, StrictRoundRobin):
        t0 = time.perf_counter()
        res = LegacySimulator(cd, P(), fleet=fleet, failures=failures,
                              seed=0).run(jobs)
        walls[(P.name, "legacy")] = (time.perf_counter() - t0,
                                     sum(r.violated for r in res))
        for label in ("legacy", "event-heap"):
            wall, viol = walls[(P.name, label)]
            emit(f"simulator,{label},{P.name},jobs={n_jobs},pools={W},"
                 f"wall_s={wall:.2f},violations={viol}")
        speedup = (walls[(P.name, "legacy")][0]
                   / max(walls[(P.name, "event-heap")][0], 1e-9))
        emit(f"simulator_headline,{P.name},"
             f"event_heap_speedup={speedup:.2f}x")
    return walls


def bench_scoring(cd=None, J=2048, pools=(86, 85, 85), iters=5, emit=print):
    """numpy estimate_matrix vs the Pallas scheduler_score kernel on a
    fleet-scale queue (J x 256)."""
    from repro.core.estimator import estimate_matrix
    from repro.core.pallas_scoring import make_pallas_score_fn
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario

    cd = cd or characterize()
    fleet = synth_fleet(*pools)
    workers = [w.name for w in fleet]
    jobs = scenario(cd, "multi-tenant", n_jobs=J, fleet=fleet,
                    seed=0)
    now = jobs[-1].arrival  # everything queued
    pallas_fn = make_pallas_score_fn()
    s_np = s_pl = None
    walls = {}
    for label, fn in (("numpy", estimate_matrix), ("pallas", pallas_fn)):
        fn(cd, jobs, workers, now)       # warm caches / tracing
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(cd, jobs, workers, now)
        walls[label] = (time.perf_counter() - t0) / iters
        if label == "numpy":
            s_np = out
        else:
            s_pl = out
        emit(f"scoring,{label},J={len(jobs)},W={len(workers)},"
             f"wall_ms={walls[label] * 1e3:.2f}")
    agree = int((s_np.best_worker == s_pl.best_worker).sum())
    # interpret mode emulates the TPU kernel op-by-op on CPU — the point
    # here is bit-level agreement and the [J, W] shape, not speed; compiled
    # TPU numbers come from benchmarks/kernels_bench.py on real hardware
    emit(f"scoring_headline,pallas_interpret_vs_numpy="
         f"{walls['numpy'] / max(walls['pallas'], 1e-9):.2f}x,"
         f"best_worker_agree={agree}/{len(jobs)}")
    return walls


def bench_serving(cd=None, n_jobs=2000, pools=(2, 5, 5),
                  utilization=1.3, kind="mmpp", emit=print):
    """Job-level vs batched serving on the same overloaded fleet scenario:
    what the scheduler gains once it can see continuous batching (the
    dominant real-world throughput lever; see docs/serving_bridge.md)."""
    from repro.core.simulator import Simulator
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario

    cd = cd or characterize()
    fleet = synth_fleet(*pools)
    out = {}
    for serving in ("job", "batched"):
        jobs = scenario(cd, kind, n_jobs=n_jobs, fleet=fleet,
                        utilization=utilization, seed=0, serving=serving)
        for P in (SynergAI, SloMael, RoundRobin):
            t0 = time.perf_counter()
            res = Simulator(cd, P(), fleet=fleet, seed=0,
                            serving=serving).run(jobs)
            dt = time.perf_counter() - t0
            s = summarize(res)
            out[(serving, P.name)] = s
            emit(f"serving,{kind},{serving},{P.name},"
                 f"violations={s['violations']},"
                 f"wait_s={s['waiting_avg_s']:.1f},"
                 f"p99_s={s['e2e_p99_s']:.1f},wall_s={dt:.2f}")
    v_job = out[("job", "SynergAI")]["violations"]
    v_bat = out[("batched", "SynergAI")]["violations"]
    emit(f"serving_headline,SynergAI,job_over_batched_violations="
         f"{v_job / max(1, v_bat):.2f}x,"
         f"p99_job_s={out[('job', 'SynergAI')]['e2e_p99_s']:.1f},"
         f"p99_batched_s={out[('batched', 'SynergAI')]['e2e_p99_s']:.1f}")
    return out


def bench_streaming(cd=None, n_jobs=1500, pools=(2, 5, 5),
                    utilization=1.3, kind="mmpp", streaming=(2.0, 2.5),
                    prefill_frac=0.4, emit=print):
    """Aggregated vs prefill/decode-disaggregated serving under streaming
    SLOs: the same overloaded trace with per-class TTFT/TPOT deadlines
    (``scenario(..., streaming=...)``) on a plain batched fleet vs one
    whose replicas are phase-tagged.  ``prefill_frac`` overprovisions the
    short latency-critical phase (0.4 vs the work's ~15% prefill share)
    so TTFT survives bursts — the classic disaggregation trade: first
    tokens come fast, decode capacity shrinks."""
    from repro.core.simulator import Simulator
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario

    cd = cd or characterize()
    fleets = {"aggregated": synth_fleet(*pools),
              "disaggregated": synth_fleet(*pools,
                                           disaggregate=prefill_frac)}
    out = {}
    for label, fleet in fleets.items():
        jobs = scenario(cd, kind, n_jobs=n_jobs, fleet=fleet,
                        utilization=utilization, seed=0,
                        serving="batched", streaming=streaming)
        for P in (SynergAI, SloMael, RoundRobin):
            t0 = time.perf_counter()
            res = Simulator(cd, P(), fleet=fleet, seed=0,
                            serving="batched").run(jobs)
            dt = time.perf_counter() - t0
            s = summarize(res)
            out[(label, P.name)] = s
            emit(f"streaming,{kind},{label},{P.name},"
                 f"ttft_violations={s['ttft_violations']},"
                 f"tpot_violations={s['tpot_violations']},"
                 f"violations={s['violations']},"
                 f"ttft_p99_s={s.get('ttft_p99_s', float('nan')):.2f},"
                 f"tpot_p99_ms={1e3 * s.get('tpot_p99_s', float('nan')):.2f},"
                 f"p99_s={s['e2e_p99_s']:.1f},wall_s={dt:.2f}")
    agg = out[("aggregated", "SynergAI")]
    dis = out[("disaggregated", "SynergAI")]
    emit(f"streaming_headline,SynergAI,"
         f"ttft_violations_agg={agg['ttft_violations']},"
         f"ttft_violations_disagg={dis['ttft_violations']},"
         f"agg_over_disagg="
         f"{agg['ttft_violations'] / max(1, dis['ttft_violations']):.2f}x,"
         f"ttft_p99_agg_s={agg.get('ttft_p99_s', float('nan')):.2f},"
         f"ttft_p99_disagg_s={dis.get('ttft_p99_s', float('nan')):.2f}")
    return out


def bench_sched_overhead(cd=None, sizes=((2_000, (8, 28, 28)),
                                         (10_000, (8, 28, 28))),
                         iters=40, churn=64, tick=1.0,
                         pallas_max_j=2_000, utilization=0.8,
                         only=None, interpret=True, strict_parity=False,
                         emit=print):
    """Per-tick scheduler decision time under a standing MMPP backlog.

    A synthetic tick loop keeps the queue depth at ~J while churning it
    exactly like the simulator does: each tick frees ``churn`` workers,
    times one ``SynergAI.schedule`` call, applies the assignments
    (placed jobs leave the queue, their workers go busy) and injects
    ``churn`` fresh arrivals.  That makes the *incremental* cost visible:
    the cached variant re-scores only the churn, the uncached variant
    rebuilds the full [J, W] matrix every tick, and the device-resident
    ``pallas-resident`` variant ships only the churn's rows host->device
    and runs the whole decision as one fused dispatch.  Pallas variants
    run in interpret mode on CPU by default (the kernel emulated
    op-by-op — wall-clock is not the point there), capped at
    ``pallas_max_j``; ``interpret=False`` runs the compiled backend and
    suffixes the variant names ``-compiled`` so accelerator numbers land
    under their own regression keys (parity-gated, ratio-tracked —
    never floored until real accelerator baselines are committed).

    Every Pallas variant's per-tick assignments are compared against the
    cached numpy variant's on the identical churn stream and recorded as
    ``assignments_match_cached``; ``strict_parity=True`` raises on any
    mismatch (the CI smoke contract).  ``only`` restricts the run to
    ``("cached", only)`` for the tier-1 smoke leg."""
    import numpy as np

    from repro.core.job import exec_time
    from repro.core.pallas_scoring import make_pallas_score_fn
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario

    cd = cd or characterize()
    suffix = "" if interpret else "-compiled"
    variants = [
        ("uncached", lambda: SynergAI(incremental=False)),
        ("cached", lambda: SynergAI()),
        ("pallas" + suffix, lambda: SynergAI(
            score_fn=make_pallas_score_fn(interpret=interpret))),
        ("pallas-v2" + suffix, lambda: SynergAI(
            score_fn=make_pallas_score_fn(v2=True, interpret=interpret))),
        ("pallas-resident" + suffix, lambda: SynergAI(
            score_fn=make_pallas_score_fn(device_cache=True,
                                          interpret=interpret))),
    ]
    if only is not None:
        keep = {"cached", only, only + suffix}
        variants = [v for v in variants if v[0] in keep]
    results = []
    for J, pools in sizes:
        fleet = synth_fleet(*pools)
        W = len(fleet)
        base = {}
        cached_log = None
        for name, mk in variants:
            if name.startswith("pallas") and J > pallas_max_j:
                continue
            # fresh identical workload per variant (jobs are mutated by
            # the churn loop below)
            jobs = scenario(cd, "mmpp", n_jobs=J + iters * churn,
                            fleet=fleet, utilization=utilization, seed=0)
            queue = list(jobs[:J])
            reservoir = jobs[J:]
            now = queue[-1].arrival
            pol = mk()
            sim = Simulator(cd, pol, fleet=fleet, seed=0)
            cl = sim.cluster
            rng = np.random.default_rng(0)
            names = cl.arrays.names
            pol.schedule(now, queue, cl)        # warm caches / tracing
            ticks, placed_total, asg_log = [], 0, []
            for i in range(iters):
                now += tick
                for wi in rng.choice(W, size=min(churn, W),
                                     replace=False):
                    cl.workers[names[wi]].busy_until = now
                t0 = time.perf_counter()
                asg = pol.schedule(now, queue, cl)
                ticks.append(time.perf_counter() - t0)
                asg_log.append([(a.job.id, a.worker) for a in asg])
                placed = set()
                for a in asg:
                    cl.workers[a.worker].busy_until = (
                        now + exec_time(a.entry, a.job.queries))
                    placed.add(a.job.id)
                placed_total += len(placed)
                queue = [j for j in queue if j.id not in placed]
                fresh = reservoir[i * churn:(i + 1) * churn]
                for j in fresh:
                    j.arrival = now
                queue.extend(fresh)
            mean_ms = 1e3 * float(np.mean(ticks))
            p50_ms = 1e3 * float(np.median(ticks))
            rec = {"variant": name, "J": J, "W": W, "serving": "job",
                   "iters": iters, "churn": churn,
                   "mean_tick_ms": mean_ms, "p50_tick_ms": p50_ms,
                   "placed_per_tick": placed_total / iters}
            if name == "uncached":
                base[(J, W)] = mean_ms
            if (J, W) in base:
                rec["speedup_vs_uncached"] = base[(J, W)] / mean_ms
            if name == "cached":
                cached_log = asg_log
            if name.startswith("pallas") and cached_log is not None:
                match = asg_log == cached_log
                rec["assignments_match_cached"] = match
                if strict_parity and not match:
                    bad = next(i for i, (a, b)
                               in enumerate(zip(asg_log, cached_log))
                               if a != b)
                    raise RuntimeError(
                        f"{name} diverged from cached at tick {bad}: "
                        f"{asg_log[bad][:4]} != {cached_log[bad][:4]}")
            if name.startswith("pallas-resident"):
                dc = pol.cache
                rec["hd_bytes_per_tick"] = dc.bytes_to_device / dc.ticks
                rec["rows_uploaded"] = dc.rows_uploaded
                rec["fail_masks"] = dc.fail_masks
                rec["flushes"] = dc.flushes
            results.append(rec)
            emit(f"sched_overhead,{name},J={J},W={W},"
                 f"mean_tick_ms={mean_ms:.2f},p50_tick_ms={p50_ms:.2f},"
                 f"speedup_vs_uncached="
                 f"{rec.get('speedup_vs_uncached', 1.0):.2f}x"
                 + (",parity="
                    + ("ok" if rec["assignments_match_cached"]
                       else "MISMATCH")
                    if "assignments_match_cached" in rec else ""))
    head = [r for r in results
            if r["variant"] == "cached" and r["J"] == 10_000]
    blob = {"schema": 1, "bench": "bench_sched_overhead",
            "configs": results}
    if head:
        blob["headline"] = {
            "J": head[0]["J"], "W": head[0]["W"],
            "cached_mean_tick_ms": head[0]["mean_tick_ms"],
            "speedup_cached_vs_uncached":
                head[0].get("speedup_vs_uncached", 1.0)}
        emit(f"sched_overhead_headline,J={head[0]['J']},"
             f"W={head[0]['W']},cached_vs_uncached="
             f"{head[0].get('speedup_vs_uncached', 1.0):.2f}x,target=5x")
    return blob


def bench_regions(cd=None, pools=(256, 896, 896), regions_sweep=(8, 16, 32),
                  J=4096, iters=12, churn=256, tick=1.0, utilization=0.8,
                  smoke=False, emit=print):
    """Flat vs hierarchical per-tick decision time at fleet scale.

    The same standing-backlog churn loop as ``bench_sched_overhead``
    (free ``churn`` workers, time one ``schedule`` call, apply, inject
    arrivals), run over a region-tagged fleet at each region count in
    ``regions_sweep``: ``flat`` is the incremental ``SynergAI`` scoring
    all W pools every tick, ``hier`` is ``HierarchicalSynergAI`` routing
    in O(k) and scoring only region slices.  Region tags are inert to
    the flat policy, so both variants face the identical workload.
    ``speedup_hier_vs_flat`` is hardware-independent (both sides
    measured in-process) and is what the nightly perf gate watches
    (``tools/check_perf_regression.py``, 4x floor at the headline
    config).  ``smoke=True`` shrinks everything to a seconds-long CI
    sanity leg (the ratio is meaningless at that size — the smoke leg
    only proves the bench runs)."""
    import numpy as np

    from repro.core.hierarchy import HierarchicalSynergAI
    from repro.core.job import exec_time
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario

    cd = cd or characterize()
    if smoke:
        pools, regions_sweep = (8, 28, 28), (4,)
        J, iters, churn = 500, 4, 32
    variants = [("flat", lambda: SynergAI()),
                ("hier", lambda: HierarchicalSynergAI())]
    results = []
    for k in regions_sweep:
        fleet = synth_fleet(*pools, regions=k)
        W = len(fleet)
        base = {}
        for name, mk in variants:
            jobs = scenario(cd, "mmpp", n_jobs=J + iters * churn,
                            fleet=fleet, utilization=utilization, seed=0)
            queue = list(jobs[:J])
            reservoir = jobs[J:]
            now = queue[-1].arrival
            pol = mk()
            sim = Simulator(cd, pol, fleet=fleet, seed=0)
            cl = sim.cluster
            rng = np.random.default_rng(0)
            names = cl.arrays.names
            for j in queue:
                pol.on_arrival(j, cl, now)      # the simulator's hook
            pol.schedule(now, queue, cl)        # warm caches / tables
            ticks, placed_total = [], 0
            for i in range(iters):
                now += tick
                for wi in rng.choice(W, size=min(churn, W),
                                     replace=False):
                    cl.workers[names[wi]].busy_until = now
                t0 = time.perf_counter()
                asg = pol.schedule(now, queue, cl)
                ticks.append(time.perf_counter() - t0)
                placed = set()
                for a in asg:
                    cl.workers[a.worker].busy_until = (
                        now + a.xfer_s
                        + exec_time(a.entry, a.job.queries))
                    placed.add(a.job.id)
                placed_total += len(placed)
                queue = [j for j in queue if j.id not in placed]
                fresh = reservoir[i * churn:(i + 1) * churn]
                for j in fresh:
                    j.arrival = now
                    pol.on_arrival(j, cl, now)
                queue.extend(fresh)
            mean_ms = 1e3 * float(np.mean(ticks))
            p50_ms = 1e3 * float(np.median(ticks))
            rec = {"variant": name, "J": J, "W": W, "serving": "job",
                   "regions": k, "iters": iters, "churn": churn,
                   "mean_tick_ms": mean_ms, "p50_tick_ms": p50_ms,
                   "placed_per_tick": placed_total / iters}
            if name == "flat":
                base[(J, W, k)] = mean_ms
            else:
                rec["speedup_hier_vs_flat"] = base[(J, W, k)] / mean_ms
                rec["spills"] = pol.spills
            results.append(rec)
            emit(f"regions,{name},J={J},W={W},k={k},"
                 f"mean_tick_ms={mean_ms:.2f},p50_tick_ms={p50_ms:.2f},"
                 f"speedup_hier_vs_flat="
                 f"{rec.get('speedup_hier_vs_flat', 1.0):.2f}x")
    blob = {"schema": 1, "bench": "bench_regions", "configs": results}
    if not smoke:
        head = [r for r in results if r["variant"] == "hier"
                and r["regions"] >= 16] or \
               [r for r in results if r["variant"] == "hier"]
        if head:
            h = head[0]
            blob["regions_headline"] = {
                "J": h["J"], "W": h["W"], "regions": h["regions"],
                "hier_mean_tick_ms": h["mean_tick_ms"],
                "speedup_hier_vs_flat": h["speedup_hier_vs_flat"]}
            emit(f"regions_headline,J={h['J']},W={h['W']},"
                 f"k={h['regions']},hier_vs_flat="
                 f"{h['speedup_hier_vs_flat']:.2f}x,target=4x")
    return blob


def bench_traces(cd=None, n_jobs=1500, pools=(2, 5, 5), utilization=1.3,
                 n_regions=3, correlation=0.6, emit=print):
    """The trace-driven scenarios under every policy: a replayed mmpp
    overload trace (bit-for-bit against the exporting run), engine-
    popularity drift, and a correlated multi-region outage."""
    import os
    import tempfile

    from repro.core.simulator import Simulator
    from repro.core.workers import synth_fleet
    from repro.core.workload import (replay, save_trace, scenario,
                                     synth_failures)

    cd = cd or characterize()
    fleet = synth_fleet(*pools, regions=n_regions)
    out = {}

    def sweep(section, jobs, failures=()):
        for P in POLICIES:
            t0 = time.perf_counter()
            res = Simulator(cd, P(), fleet=fleet, failures=failures,
                            seed=0).run(jobs)
            dt = time.perf_counter() - t0
            s = summarize(res)
            out[(section, P.name)] = s
            emit(f"traces,{section},{P.name},"
                 f"violations={s['violations']},"
                 f"wait_s={s['waiting_avg_s']:.1f},"
                 f"p99_s={s['e2e_p99_s']:.1f},wall_s={dt:.2f}")

    # (a) replay: export a completed run, feed it back, pin equality
    jobs = scenario(cd, "mmpp", n_jobs=n_jobs, fleet=fleet,
                    utilization=utilization, seed=0)
    base = Simulator(cd, SynergAI(), fleet=fleet, seed=0).run(jobs)
    fd, path = tempfile.mkstemp(suffix=".jsonl", prefix="synergai_trace_")
    os.close(fd)
    try:
        save_trace(path, base)
        replayed = replay(path)
        res_r = Simulator(cd, SynergAI(), fleet=fleet, seed=0).run(replayed)
        key = lambda rs: sorted((r.job.id, r.worker, r.start, r.end,
                                 r.ttft, r.tpot) for r in rs)
        exact = key(base) == key(res_r)
        out[("replay", "exact")] = {"replay_exact": exact,
                                    "records": len(replayed)}
        emit(f"traces,replay,exact={exact},records={len(replayed)}")
        sweep("replay", replayed)
    finally:
        os.unlink(path)

    # (b) drift: the capacity-proportional mix flips edge<->heavy shares
    drift_jobs = scenario(cd, "drift", n_jobs=n_jobs, fleet=fleet,
                          utilization=utilization, seed=0)
    sweep("drift", drift_jobs)

    # (c) correlated-region outage on the replayed trace's timeline
    span = jobs[-1].arrival
    failures = synth_failures(fleet, span, mtbf_s=span, mttr_s=180.0,
                              seed=0, regions=True,
                              correlation=correlation)
    emit(f"traces,outage,regions={n_regions},correlation={correlation},"
         f"failure_events={len(failures)}")
    sweep("outage", jobs, failures=failures)

    # (d) drift + online re-characterization: unmodeled pool slowdowns
    # (synth_degradations) under the drift mix, SynergAI with a stale
    # offline profile vs the online loop vs the true-factor oracle
    from repro.core.recharacterize import OnlineRecharacterizer
    from repro.core.simulator import Cluster
    from repro.core.workload import synth_degradations
    degs = synth_degradations(fleet, drift_jobs[-1].arrival, factor=5.0,
                              fraction=0.35, prefix="edge", seed=0)
    truth = {d.worker: d.factor for d in degs}
    for name, rc in (("stale", None), ("online", OnlineRecharacterizer()),
                     ("oracle", None)):
        if name == "oracle":
            rc = OnlineRecharacterizer(detect=False)
            rc.seed(Cluster(cd, list(fleet)), worker_factors=truth)
        t0 = time.perf_counter()
        res = Simulator(cd, SynergAI(recharacterizer=rc), fleet=fleet,
                        degradations=degs, seed=0).run(list(drift_jobs))
        dt = time.perf_counter() - t0
        s = summarize(res)
        out[("drift+recharacterize", name)] = s
        extra = (f",refreshes={rc.refreshes}"
                 if rc is not None and name == "online" else "")
        emit(f"traces,drift+recharacterize,{name},"
             f"violations={s['violations']},"
             f"wait_s={s['waiting_avg_s']:.1f},"
             f"p99_s={s['e2e_p99_s']:.1f},wall_s={dt:.2f}{extra}")

    v = lambda section, name: out[(section, name)]["violations"]
    base_names = ["RR", "SRR", "LRU", "MRU", "BE"]
    for section in ("replay", "drift", "outage"):
        v_base = np.mean([v(section, n) for n in base_names])
        emit(f"traces_headline,{section},baselines_over_synergai="
             f"{v_base / max(1, v(section, 'SynergAI')):.2f}x")
    emit(f"traces_headline,drift+recharacterize,stale_over_online="
         f"{v('drift+recharacterize', 'stale') / max(1, v('drift+recharacterize', 'online')):.2f}x")
    return out


def bench_drift_recovery(cd=None, n_jobs=6000, pools=(2, 5, 5),
                         n_regions=3, utilization=0.6, factor=5.0,
                         fraction=0.35, smoke=False, emit=print):
    """Violations under unmodeled physics drift, with and without the
    online re-characterization loop — the committed ``drift_headline``
    the nightly perf gate enforces.

    A third of the way into a drift-mix trace, ``fraction`` of the edge
    pools silently degrade to ``factor``x their characterized service
    time (``synth_degradations`` — thermal throttling, a colocated
    tenant; nothing tells the policies).  Three SynergAI runs on the
    identical trace and degradation timeline:

    - ``stale``  — the offline profile, trusted forever (the paper's
      open loop; keeps placing work on pools it believes are fast).
    - ``online`` — ``OnlineRecharacterizer``: residual-triggered
      refreshes re-fit the per-(engine, worker) effective rates and
      placement routes around the slow pools within a few completions.
    - ``oracle`` — the true factors installed at t=0 with detection
      muted: the floor the online loop converges toward.

    The headline ``violation_ratio_stale_vs_online`` is deterministic
    (fixed seeds, fixed timeline) and hardware-independent, so the gate
    fails on any code change that erodes recovery by >30% — and on a
    drop below the 5x acceptance floor.  ``smoke=True`` shrinks the
    trace to a seconds-long CI sanity leg (the ratio is noise at that
    size; the smoke leg only proves the bench runs)."""
    from repro.core.recharacterize import OnlineRecharacterizer
    from repro.core.simulator import Cluster
    from repro.core.workers import synth_fleet
    from repro.core.workload import scenario, synth_degradations

    cd = cd or characterize()
    if smoke:
        n_jobs = 800
    fleet = synth_fleet(*pools, regions=n_regions)
    W = len(fleet)
    jobs = scenario(cd, "drift", n_jobs=n_jobs, fleet=fleet,
                    utilization=utilization, seed=0)
    degs = synth_degradations(fleet, jobs[-1].arrival, factor=factor,
                              fraction=fraction, prefix="edge", seed=0)
    truth = {d.worker: d.factor for d in degs}
    blob = {"schema": 1, "bench": "bench_drift_recovery", "configs": []}
    viol = {}
    for name in ("stale", "online", "oracle"):
        rc = None
        if name == "online":
            rc = OnlineRecharacterizer()
        elif name == "oracle":
            rc = OnlineRecharacterizer(detect=False)
            rc.seed(Cluster(cd, list(fleet)), worker_factors=truth)
        t0 = time.perf_counter()
        res = Simulator(cd, SynergAI(recharacterizer=rc), fleet=fleet,
                        degradations=degs, seed=0).run(list(jobs))
        dt = time.perf_counter() - t0
        s = summarize(res)
        viol[name] = s["violations"]
        cfg = {"variant": f"drift-{name}", "J": n_jobs, "W": W,
               "serving": "job", "factor": factor, "fraction": fraction,
               "violations": s["violations"],
               "wait_avg_s": s["waiting_avg_s"],
               "e2e_p99_s": s["e2e_p99_s"], "wall_s": dt}
        if name == "online":
            cfg["refreshes"] = rc.refreshes
        blob["configs"].append(cfg)
        emit(f"drift_recovery,{name},J={n_jobs},W={W},"
             f"violations={s['violations']},wall_s={dt:.2f}")
    ratio = viol["stale"] / max(1, viol["online"])
    for cfg in blob["configs"]:
        if cfg["variant"] == "drift-online":
            cfg["violation_ratio_stale_vs_online"] = ratio
    if not smoke:
        blob["drift_headline"] = {
            "J": n_jobs, "W": W, "factor": factor, "fraction": fraction,
            "violations_stale": viol["stale"],
            "violations_online": viol["online"],
            "violations_oracle": viol["oracle"],
            "violation_ratio_stale_vs_online": ratio}
    emit(f"drift_recovery_headline,stale_over_online={ratio:.2f}x,"
         f"oracle_violations={viol['oracle']}")
    return blob


def bench_energy(cd=None, n_jobs=2000, pools=(2, 5, 5), n_regions=3,
                 utilization=0.6, energy_weight=1e-2,
                 hier_energy_weight=1e-1, smoke=False, emit=print):
    """Energy/carbon-aware objective vs the energy-blind scheduler on one
    trace — the committed ``energy_headline`` the nightly perf gate
    enforces.

    Five runs of the identical region-tagged MMPP trace: flat SynergAI
    energy-blind / energy-weighted / carbon-weighted (the same weight
    scaled by a synthetic per-region diurnal ``CarbonTrace``), then
    ``HierarchicalSynergAI`` blind vs carbon-aware (the weight also
    steers the O(k) router toward the currently-cleanest region).  In
    this fleet the cloud pod is the per-query energy hog (556 J/job vs
    280-420 J on the edge slices), so the aware runs pull work *onto*
    edge: edge joules rise while fleet-wide active energy and carbon
    fall — the headline rides on *active* joules (``total - idle``;
    the idle floor is a span-fixed constant no placement policy can
    move while every pool stays powered, and it is what the post-hoc
    carbon accounting meters too) and on ``carbon_kg``, with
    edge/total/idle breakdowns reported per config.

    The energy term enters only the placement *ranking* (acceptability,
    doom and urgency stay purely time-derived), so QoS cannot collapse
    by construction — the headline run shows the aware variants with
    *fewer* deadline misses than blind, and the gate holds
    ``violation_overhead`` at +10%.  Carbon is accounted post-hoc per
    job at the grid intensity of the serving pool's region at the job's
    service midpoint, so time-shifted *and* region-shifted placements
    both register.  Everything is deterministic (fixed seeds, no
    timing in any gated number); ``smoke=True`` shrinks the trace to a
    seconds-long CI sanity leg (reductions are noise at that size — the
    smoke leg only proves the bench runs)."""
    from repro.core.energy import offload_fraction
    from repro.core.hierarchy import HierarchicalSynergAI
    from repro.core.workers import synth_fleet
    from repro.core.workload import CarbonTrace, scenario

    cd = cd or characterize()
    if smoke:
        n_jobs = min(n_jobs, 300)
    fleet = synth_fleet(*pools, regions=n_regions)
    W = len(fleet)
    jobs = scenario(cd, "mmpp", n_jobs=n_jobs, fleet=fleet,
                    utilization=utilization, seed=3)
    regions = sorted({w.region for w in fleet})
    # two diurnal periods over the trace: the cleanest region moves
    trace = CarbonTrace.synth(regions, period_s=2.0 * jobs[-1].arrival)

    def carbon_kg(results, cluster):
        grams = 0.0
        for r in results:
            ent = cd.optimal(r.job.engine, r.worker)
            region = cluster.workers[r.worker].pool.region
            g_kwh = trace.intensity(region, 0.5 * (r.start + r.end))
            grams += ent.power_w * r.exec_s / 3.6e6 * g_kwh
        return grams / 1e3

    variants = [
        ("flat-blind", 0.0, lambda: SynergAI()),
        ("flat-energy", energy_weight,
         lambda: SynergAI(energy_weight=energy_weight)),
        ("flat-carbon", energy_weight,
         lambda: SynergAI(energy_weight=energy_weight, carbon=trace)),
        ("hier-blind", 0.0, lambda: HierarchicalSynergAI()),
        ("hier-carbon", hier_energy_weight,
         lambda: HierarchicalSynergAI(energy_weight=hier_energy_weight,
                                      carbon=trace)),
    ]
    blob = {"schema": 1, "bench": "bench_energy", "configs": []}
    stats = {}
    for name, ew, mk in variants:
        t0 = time.perf_counter()
        sim = Simulator(cd, mk(), fleet=fleet, seed=3)
        res = sim.run(list(jobs))
        dt = time.perf_counter() - t0
        s = summarize(res)
        ws = sim.cluster.workers.values()
        edge_j = sum(w.energy_j for w in ws if w.pool.is_edge)
        total_j = sum(w.total_energy_j for w in ws)
        idle_j = sum(w.idle_energy_j for w in ws)
        kg = carbon_kg(res, sim.cluster)
        stats[name] = (s["violations"], total_j - idle_j, kg)
        cfg = {"variant": f"energy-{name}", "J": n_jobs, "W": W,
               "serving": "job", "regions": n_regions,
               "energy_weight": ew, "violations": s["violations"],
               "edge_energy_mj": edge_j / 1e6,
               "total_energy_mj": total_j / 1e6,
               "idle_energy_mj": idle_j / 1e6, "carbon_kg": kg,
               "offload": offload_fraction(res, sim.cluster),
               "wall_s": dt}
        blob["configs"].append(cfg)
        emit(f"energy,{name},J={n_jobs},W={W},"
             f"violations={s['violations']},"
             f"total_mj={total_j / 1e6:.2f},carbon_kg={kg:.3f},"
             f"offload={cfg['offload']:.2f}")
    v_blind, e_blind, c_blind = stats["flat-blind"]
    v_energy, e_energy, _ = stats["flat-energy"]
    v_carbon, _, c_carbon = stats["flat-carbon"]
    hv_blind, _, hc_blind = stats["hier-blind"]
    hv_carbon, _, hc_carbon = stats["hier-carbon"]
    e_cut = 1.0 - e_energy / e_blind
    c_cut = 1.0 - c_carbon / c_blind
    h_cut = 1.0 - hc_carbon / hc_blind
    overhead = (max(v_energy, v_carbon) - v_blind) / max(1, v_blind)
    for cfg in blob["configs"]:
        if cfg["variant"] == "energy-flat-energy":
            cfg["energy_reduction_vs_blind"] = e_cut
        elif cfg["variant"] == "energy-flat-carbon":
            cfg["carbon_reduction_vs_blind"] = c_cut
        elif cfg["variant"] == "energy-hier-carbon":
            cfg["carbon_reduction_vs_blind"] = h_cut
    if not smoke:
        blob["energy_headline"] = {
            "J": n_jobs, "W": W, "regions": n_regions,
            "energy_weight": energy_weight,
            "violations_blind": v_blind, "violations_energy": v_energy,
            "violations_carbon": v_carbon,
            "violations_hier_blind": hv_blind,
            "violations_hier_carbon": hv_carbon,
            "energy_reduction": e_cut, "carbon_reduction": c_cut,
            "hier_carbon_reduction": h_cut,
            "violation_overhead": overhead}
    emit(f"energy_headline,energy_cut={e_cut:.3f},carbon_cut={c_cut:.3f},"
         f"hier_carbon_cut={h_cut:.3f},violation_overhead={overhead:.3f}")
    return blob


def bench_overload(cd=None, n_jobs=4000, pools=(2, 5, 5), n_regions=3,
                   utilization=2.0, patience=8.0, queue_cap=12,
                   retry_budget=3, smoke=False, emit=print):
    """Goodput under sustained overload with and without the
    ``OverloadController`` — the committed ``overload_headline`` the
    nightly perf gate enforces.

    The fleet is driven at ``utilization`` ~= 2x its capacity (an MMPP
    trace it can never drain), with flapping regional failures
    (``synth_failures(..., flap=3)``: pools oscillate between apparent
    health and crash-restart, killing whatever was placed during the
    up-phase) and a WAN partition severing one region pair for the
    middle half of the trace (``LinkFailureEvent`` — no spillover, no
    cross-region KV).  Clients are impatient (``patience`` x t_qos) and
    kills retry under an exponential-backoff budget, so both runs reach
    a terminal outcome for every job.  Two ``HierarchicalSynergAI`` runs
    of the identical trace and fault timeline:

    - ``uncontrolled`` — no controller: every job is scheduled until it
      completes (usually violated), abandons, or exhausts its retries.
      The queue grows without bound and service effort smears across
      jobs that are already past their deadline.
    - ``controlled`` — ``OverloadController(queue_cap=...)``: certainly-
      doomed jobs (``t_rem < min_est``, the score cache's own bound) are
      shed on sight and each region's queue is capped to the cap-most-
      schedulable jobs, so servers only run work that can still meet
      QoS.

    The headline ``goodput_ratio_controlled_vs_uncontrolled`` (within-
    QoS completions per second, ``metrics.summarize``'s ``goodput_jps``)
    must hold >= 1.5x with the controlled run's p99 queue depth under
    ``queue_depth_bound`` — shedding buys *useful* completions, not just
    a shorter queue.  Deterministic (fixed seeds, no timing in any gated
    number); ``smoke=True`` shrinks the trace to a seconds-long CI
    sanity leg (ratios are noise at that size — the smoke leg only
    proves the bench runs)."""
    from repro.core.hierarchy import HierarchicalSynergAI
    from repro.core.metrics import OUTCOMES
    from repro.core.overload import OverloadController
    from repro.core.simulator import LinkFailureEvent
    from repro.core.workers import synth_fleet
    from repro.core.workload import regional_scenario, synth_failures

    cd = cd or characterize()
    if smoke:
        n_jobs = min(n_jobs, 400)
    fleet = synth_fleet(*pools, regions=n_regions)
    W = len(fleet)
    jobs = regional_scenario(cd, "mmpp", n_jobs=n_jobs, fleet=fleet,
                             utilization=utilization, seed=0,
                             patience=patience)
    span = jobs[-1].arrival
    fails = synth_failures(fleet, span, mtbf_s=span / 2.0,
                           mttr_s=span / 12.0, seed=0, regions=True,
                           correlation=0.5, flap=3)
    links = [LinkFailureEvent("r0", "r1", 0.25 * span, 0.5 * span)]
    depth_bound = 6 * queue_cap * n_regions
    blob = {"schema": 1, "bench": "bench_overload", "configs": []}
    stats = {}
    for name in ("uncontrolled", "controlled"):
        ctrl = (OverloadController(queue_cap=queue_cap)
                if name == "controlled" else None)
        t0 = time.perf_counter()
        sim = Simulator(cd, HierarchicalSynergAI(overload=ctrl),
                        fleet=fleet, failures=fails, link_failures=links,
                        retry_budget=retry_budget, seed=0)
        res = sim.run(list(jobs))
        dt = time.perf_counter() - t0
        s = summarize(res)
        p99 = float(np.percentile(sim.queue_depths, 99))
        stats[name] = (s["goodput_jps"], p99)
        cfg = {"variant": f"overload-{name}", "J": n_jobs, "W": W,
               "serving": "job", "regions": n_regions,
               "utilization": utilization, "goodput_jps": s["goodput_jps"],
               "queue_depth_p99": p99, "wall_s": dt}
        for o in OUTCOMES:
            cfg[o] = s[o]
        if ctrl is not None:
            cfg["shed_doom_total"] = ctrl.shed_doom_total
            cfg["shed_backpressure_total"] = ctrl.shed_backpressure_total
        blob["configs"].append(cfg)
        emit(f"overload,{name},J={n_jobs},W={W},"
             f"goodput_jps={s['goodput_jps']:.3f},depth_p99={p99:.0f},"
             + ",".join(f"{o}={s[o]}" for o in OUTCOMES)
             + f",wall_s={dt:.2f}")
    g_un, _ = stats["uncontrolled"]
    g_ct, p99_ct = stats["controlled"]
    ratio = g_ct / max(g_un, 1e-12)
    for cfg in blob["configs"]:
        if cfg["variant"] == "overload-controlled":
            cfg["goodput_ratio_controlled_vs_uncontrolled"] = ratio
    if not smoke:
        blob["overload_headline"] = {
            "J": n_jobs, "W": W, "regions": n_regions,
            "utilization": utilization, "queue_cap": queue_cap,
            "goodput_uncontrolled_jps": g_un,
            "goodput_controlled_jps": g_ct,
            "goodput_ratio_controlled_vs_uncontrolled": ratio,
            "queue_depth_p99_controlled": p99_ct,
            "queue_depth_bound": depth_bound}
    emit(f"overload_headline,controlled_over_uncontrolled={ratio:.2f}x,"
         f"depth_p99={p99_ct:.0f}/{depth_bound}")
    return blob


def main(argv=None):
    import argparse
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--jobs", type=int, default=10_000,
                   help="fleet-scale trace length (bench_fleet)")
    p.add_argument("--pools", type=int, nargs=3, default=(8, 28, 28),
                   metavar=("CLOUD", "EDGE_LG", "EDGE_SM"),
                   help="synth_fleet replica counts per archetype")
    p.add_argument("--kind", default="mmpp",
                   help="scenario preset: poisson | mmpp | diurnal | "
                        "flash | multi-tenant")
    p.add_argument("--skip-paper", action="store_true",
                   help="skip the 24-job paper experiments")
    p.add_argument("--skip-scoring", action="store_true")
    p.add_argument("--skip-serving", action="store_true",
                   help="skip the job-level vs batched serving-bridge "
                        "comparison (scenario(..., serving='batched'))")
    p.add_argument("--skip-streaming", action="store_true",
                   help="skip the streaming-QoS aggregated vs "
                        "disaggregated comparison (bench_streaming)")
    p.add_argument("--skip-traces", action="store_true",
                   help="skip the trace-driven scenarios (replay / "
                        "drift / correlated-region outage, bench_traces)")
    p.add_argument("--skip-fleet", action="store_true",
                   help="skip the fleet-scale bench_fleet run")
    p.add_argument("--skip-sched", action="store_true",
                   help="skip the per-tick scheduler-overhead bench "
                        "(bench_sched_overhead)")
    p.add_argument("--sched-big", action="store_true",
                   help="extend bench_sched_overhead to the 50k-job x "
                        "256-pool sweep (numpy backends only)")
    p.add_argument("--sched-smoke", metavar="VARIANT", default=None,
                   help="run bench_sched_overhead as a small strict-"
                        "parity smoke of VARIANT (e.g. pallas-resident) "
                        "against the cached numpy path — seconds; the "
                        "tier-1 CI sanity leg; exits nonzero on any "
                        "assignment divergence")
    p.add_argument("--sched-backend", choices=("auto", "interpret",
                                               "compiled"),
                   default="interpret",
                   help="Pallas execution backend for the sched bench: "
                        "interpret (CPU-emulated, the parity reference), "
                        "compiled (lowered kernels; variants recorded "
                        "under '-compiled' keys), auto (compiled on "
                        "TPU, interpret elsewhere)")
    p.add_argument("--sched-json", metavar="PATH", default=None,
                   help="write the bench_sched_overhead + bench_regions "
                        "results as JSON (the BENCH_SCHED.json schema; "
                        "nightly CI gates it with "
                        "tools/check_perf_regression.py)")
    p.add_argument("--skip-regions", action="store_true",
                   help="skip the flat vs hierarchical region bench "
                        "(bench_regions)")
    p.add_argument("--regions-smoke", action="store_true",
                   help="run bench_regions at smoke size only (seconds; "
                        "the tier-1 CI sanity leg)")
    p.add_argument("--skip-drift", action="store_true",
                   help="skip the stale vs online re-characterization "
                        "drift-recovery bench (bench_drift_recovery)")
    p.add_argument("--drift-smoke", action="store_true",
                   help="run bench_drift_recovery at smoke size only "
                        "(seconds; the tier-1 CI sanity leg)")
    p.add_argument("--skip-energy", action="store_true",
                   help="skip the energy/carbon-aware vs energy-blind "
                        "objective bench (bench_energy)")
    p.add_argument("--energy-smoke", action="store_true",
                   help="run bench_energy at smoke size only (seconds; "
                        "the tier-1 CI sanity leg)")
    p.add_argument("--skip-overload", action="store_true",
                   help="skip the overload-control goodput bench "
                        "(bench_overload)")
    p.add_argument("--overload-smoke", action="store_true",
                   help="run bench_overload at smoke size only (seconds; "
                        "the tier-1 CI sanity leg)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="dump the serving/streaming bench summaries as "
                        "JSON (CI artifact)")
    args = p.parse_args(argv)
    cd = characterize()
    blob = {}
    if not args.skip_paper:
        print("# paper experiments (Fig. 7-10)")
        run(cd, seeds=(1, 2, 3))
    if not args.skip_scoring:
        print("# scoring: numpy vs Pallas kernel")
        bench_scoring(cd)
    if args.sched_backend == "auto":
        import jax
        interpret = jax.default_backend() != "tpu"
    else:
        interpret = args.sched_backend == "interpret"
    sched = None
    if args.sched_smoke:
        print(f"# scheduler overhead smoke: cached vs {args.sched_smoke}"
              " (strict parity)")
        sched = bench_sched_overhead(
            cd, sizes=((256, (2, 3, 3)),), iters=6, churn=16,
            only=args.sched_smoke, interpret=interpret,
            strict_parity=True)
    elif not args.skip_sched:
        print("# scheduler overhead: uncached vs score-cache vs Pallas")
        sizes = [(2_000, (8, 28, 28)), (10_000, (8, 28, 28))]
        if args.sched_big:
            sizes.append((50_000, (86, 85, 85)))
        sched = bench_sched_overhead(cd, sizes=tuple(sizes),
                                     interpret=interpret)
    if not args.skip_regions:
        print("# region sharding: flat vs hierarchical scheduler")
        reg = bench_regions(cd, smoke=args.regions_smoke)
        if sched is None:
            sched = reg
        else:
            sched["configs"].extend(reg["configs"])
            if "regions_headline" in reg:
                sched["regions_headline"] = reg["regions_headline"]
    if not args.skip_drift:
        print("# drift recovery: stale profile vs online "
              "re-characterization vs oracle")
        drift = bench_drift_recovery(cd, smoke=args.drift_smoke)
        if sched is None:
            sched = drift
        else:
            sched["configs"].extend(drift["configs"])
            if "drift_headline" in drift:
                sched["drift_headline"] = drift["drift_headline"]
    if not args.skip_energy:
        print("# energy/carbon objective: aware vs energy-blind")
        ene = bench_energy(cd, smoke=args.energy_smoke)
        if sched is None:
            sched = ene
        else:
            sched["configs"].extend(ene["configs"])
            if "energy_headline" in ene:
                sched["energy_headline"] = ene["energy_headline"]
    if not args.skip_overload:
        print("# overload control: controlled vs uncontrolled goodput")
        ov = bench_overload(cd, smoke=args.overload_smoke)
        if sched is None:
            sched = ov
        else:
            sched["configs"].extend(ov["configs"])
            if "overload_headline" in ov:
                sched["overload_headline"] = ov["overload_headline"]
    if args.sched_json and sched is not None:
        import json
        with open(args.sched_json, "w") as f:
            json.dump(sched, f, indent=1)
        print(f"# wrote {args.sched_json}")
    if not args.skip_serving:
        print("# serving bridge: job-level vs batched (mmpp overload)")
        blob["serving"] = bench_serving(cd)
    if not args.skip_streaming:
        print("# streaming QoS: aggregated vs disaggregated pools")
        blob["streaming"] = bench_streaming(cd)
    if not args.skip_traces:
        print("# trace-driven scenarios: replay / drift / region outage")
        blob["traces"] = bench_traces(cd)
    if not args.skip_fleet:
        print(f"# fleet scale ({args.kind})")
        bench_fleet(cd, n_jobs=args.jobs, pools=tuple(args.pools),
                    kind=args.kind)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({bench: {"/".join(k): v for k, v in d.items()}
                       for bench, d in blob.items()}, f, indent=1)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
