"""Fig. 11 analogue: scheduling overhead per policy in the tightest (DH-FH)
experiment — time from dequeue attempt to successful assignment plus the
measured decision-compute time."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                  MostRecentlyUsed, RoundRobin,
                                  StrictRoundRobin)
from repro.core.job import make_experiment
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator

POLICIES = [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
            MostRecentlyUsed, BestEffort, SynergAI]
# SLO-MAEL is excluded as in the paper: its decisions happen at arrival
# (preprocessing), outside the dequeue->assignment window measured here.


def run(cd=None, seeds=(1, 2, 3, 4, 5), emit=print):
    cd = cd or characterize()
    out = {}
    for P in POLICIES:
        ovh = []
        for seed in seeds:
            jobs = make_experiment(cd, "DH", "FH", seed=seed)
            res = Simulator(cd, P(), seed=seed).run(jobs)
            ovh += [r.overhead_s + r.decision_s for r in res]
        ovh = np.array(ovh)
        out[P.name] = ovh
        emit(f"overhead,{P.name},avg_s={ovh.mean():.2f},"
             f"median_s={np.median(ovh):.3f},max_s={ovh.max():.1f},"
             f"p99_s={np.percentile(ovh, 99):.1f}")
    ratio = np.mean([out[n].mean() for n in out if n != "SynergAI"]
                    ) / max(out["SynergAI"].mean(), 1e-9)
    emit(f"overhead_headline,others_over_synergai={ratio:.2f}x,paper=4.44x")
    return out
