"""§Roofline: assemble the three-term roofline table from dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / link_bw   (bytes are per-device)

HLO_FLOPs/bytes come from the loop-aware jaxpr counter (XLA cost_analysis
counts while bodies once — recorded alongside for reference); collective
bytes come from the loop-aware optimized-HLO parse.  Hardware: TPU v5e,
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import SHAPES, get_config

PEAK = 197e12
HBM = 819e9
LINK = 50e9
HBM_BYTES = 16 * 1024**3

FIX_HINTS = {
    "compute": ("compute-bound: reduce recompute (remat policy) or raise "
                "arithmetic intensity (larger microbatch / fused kernels)"),
    "memory": ("memory-bound: int8/fp8 weights or KV, fuse elementwise "
               "chains, keep activations sequence-sharded"),
    "collective": ("collective-bound: reshard to cut all-gathers (larger "
                   "per-device blocks), overlap collectives with compute, "
                   "or move the dominant axis off the slow link"),
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per row


def analyze_record(rec: dict) -> dict:
    chips = rec["n_devices"]
    flops = rec.get("jaxpr_flops_global", 0)
    byts = rec.get("jaxpr_bytes_global", 0)
    coll = sum(rec.get("collective_bytes_per_device", {}).values())
    t_compute = flops / (chips * PEAK)
    t_memory = byts / (chips * HBM)
    t_coll = coll / LINK
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    t_model = mf / (chips * PEAK)
    t_max = max(terms.values()) or 1e-30
    mem = rec.get("memory", {})
    fits = None
    if mem.get("temp_bytes") is not None:
        live = (mem["temp_bytes"] + (mem.get("argument_bytes") or 0)
                + (mem.get("output_bytes") or 0)
                - (mem.get("alias_bytes") or 0))
        fits = live <= HBM_BYTES
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_flops_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": t_model / t_max,
        "fits_hbm": fits,
        "live_bytes_per_device": (None if mem.get("temp_bytes") is None
                                  else live),
        "hint": FIX_HINTS[dom],
    }


def run(art_dir="artifacts/dryrun", emit=print, mesh="single", tag=""):
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        if rec["status"] != "ok" or rec["mesh"] != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        row = analyze_record(rec)
        rows.append(row)
        emit(f"roofline,{row['arch']},{row['shape']},{row['mesh']},"
             f"compute_s={row['t_compute_s']:.4g},"
             f"memory_s={row['t_memory_s']:.4g},"
             f"coll_s={row['t_collective_s']:.4g},"
             f"bottleneck={row['bottleneck']},"
             f"useful={row['useful_flops_ratio']:.2f},"
             f"roofline_frac={row['roofline_fraction']:.3f}")
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        collbound = max(rows, key=lambda r: (r["t_collective_s"]
                                             / (max(r["t_compute_s"],
                                                    r["t_memory_s"]) + 1e-30)))
        emit(f"roofline_summary,worst_fraction={worst['arch']}/"
             f"{worst['shape']}={worst['roofline_fraction']:.3f},"
             f"most_collective_bound={collbound['arch']}/"
             f"{collbound['shape']}")
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective "
           "(s) | bottleneck | 6ND/HLO | roofline frac | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                 f"{r['t_compute_s']:.4g} | {r['t_memory_s']:.4g} | "
                 f"{r['t_collective_s']:.4g} | {r['bottleneck']} | "
                 f"{r['useful_flops_ratio']:.2f} | "
                 f"{r['roofline_fraction']:.3f} | "
                 f"{'y' if r['fits_hbm'] else 'n'} |\n")
    return hdr + body
