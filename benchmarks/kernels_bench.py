"""Kernel microbenchmarks: us_per_call for each Pallas kernel (interpret
mode on CPU — correctness-path timing) vs its jnp oracle."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_routing import moe_routing
from repro.kernels.rwkv_scan import rwkv_scan
from repro.kernels.scheduler_score import scheduler_score


def timeit(fn, *args, n=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(emit=print):
    key = jax.random.PRNGKey(0)
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, K, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, K, hd), jnp.float32)

    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, interpret=True))
    fr = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    emit(f"kernel,flash_attention,us_per_call={timeit(fa, q, k, v):.0f},"
         f"ref_us={timeit(fr, q, k, v):.0f}")

    qd = q[:, :1]
    da = jax.jit(lambda q, k, v: decode_attention(q, k, v, S,
                                                  interpret=True))
    dr = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, S))
    emit(f"kernel,decode_attention,us_per_call={timeit(da, qd, k, v):.0f},"
         f"ref_us={timeit(dr, qd, k, v):.0f}")

    x = jax.random.normal(key, (256, 64), jnp.float32)
    w = jax.random.normal(key, (64, 16), jnp.float32)
    mr = jax.jit(lambda x, w: moe_routing(x, w, 2, interpret=True))
    mrr = jax.jit(lambda x, w: ref.moe_routing_ref(x, w, 2))
    emit(f"kernel,moe_routing,us_per_call={timeit(mr, x, w):.0f},"
         f"ref_us={timeit(mrr, x, w):.0f}")

    r_ = jax.random.normal(key, (1, 128, 2, 32), jnp.float32)
    w_ = jnp.exp(-jnp.exp(jax.random.normal(key, (1, 128, 2, 32))))
    u_ = jax.random.normal(key, (2, 32), jnp.float32)
    rs = jax.jit(lambda r, k, v, w, u: rwkv_scan(r, k, v, w, u, chunk=32,
                                                 interpret=True))
    rr = jax.jit(ref.rwkv_scan_ref)
    emit(f"kernel,rwkv_scan,us_per_call={timeit(rs, r_, r_, r_, w_, u_):.0f},"
         f"ref_us={timeit(rr, r_, r_, r_, w_, u_):.0f}")

    import numpy as np
    rng = np.random.default_rng(0)
    qps = jnp.asarray(rng.uniform(0.1, 100, (512, 16)), jnp.float32)
    pre = jnp.asarray(rng.uniform(0, 5, (512, 16)), jnp.float32)
    qq = jnp.asarray(rng.integers(1, 1000, 512), jnp.float32)
    rem = jnp.asarray(rng.uniform(1, 500, 512), jnp.float32)
    ss = jax.jit(lambda a, b, c, d: scheduler_score(a, b, c, d,
                                                    interpret=True))
    emit(f"kernel,scheduler_score,"
         f"us_per_call={timeit(ss, qps, pre, qq, rem):.0f},"
         f"jobs=512,workers=16")
