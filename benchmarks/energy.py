"""Fig. 12 analogue: normalized edge energy per policy + job placement
shares (explains SLO-MAEL's higher cloud offload, paper §5.4)."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import (BestEffort, LeastRecentlyUsed,
                                  MostRecentlyUsed, RoundRobin,
                                  StrictRoundRobin)
from repro.core.energy import (edge_energy, normalized_edge_energy,
                               offload_fraction)
from repro.core.job import make_experiment
from repro.core.metrics import placement
from repro.core.offline import characterize
from repro.core.scheduler import SynergAI
from repro.core.simulator import Simulator
from repro.core.slo_mael import SloMael

POLICIES = [RoundRobin, StrictRoundRobin, LeastRecentlyUsed,
            MostRecentlyUsed, BestEffort, SloMael, SynergAI]
EXPERIMENTS = [("DL", "FL"), ("DL", "FH"), ("DH", "FH")]


def run(cd=None, seeds=(1, 2, 3), emit=print):
    cd = cd or characterize()
    energy = {}
    offload = {}
    for P in POLICIES:
        acc = {}
        offs = []
        for seed in seeds:
            for d, f in EXPERIMENTS:
                jobs = make_experiment(cd, d, f, seed=seed)
                sim = Simulator(cd, P(), seed=seed)
                res = sim.run(jobs)
                for pool, e in edge_energy(sim.cluster).items():
                    acc[pool] = acc.get(pool, 0.0) + e
                offs.append(offload_fraction(res, sim.cluster))
        energy[P.name] = acc
        offload[P.name] = float(np.mean(offs))
    peak = {p: max(energy[n].get(p, 0.0) for n in energy)
            for p in {p for n in energy for p in energy[n]}}
    base_names = ["RR", "SRR", "LRU", "MRU", "BE"]
    for name, acc in energy.items():
        norm = {p: (0.0 if peak[p] <= 0.0 else acc.get(p, 0.0) / peak[p])
                for p in peak}
        emit(f"energy,{name}," + ",".join(
            f"{p}={v:.3f}" for p, v in sorted(norm.items()))
            + f",cloud_offload={offload[name]:.3f}")
    for pool in sorted(peak):
        base = np.mean([energy[n].get(pool, 0.0) for n in base_names])
        syn = energy["SynergAI"].get(pool, 0.0)
        emit(f"energy_headline,{pool},synergai_vs_baselines="
             f"{100 * (1 - syn / base):.1f}%_reduction,paper=39-43%")
    emit(f"energy_headline,offload,slomael={offload['SLO-MAEL']:.3f},"
         f"synergai={offload['SynergAI']:.3f},"
         f"delta={100 * (offload['SLO-MAEL'] - offload['SynergAI']):.1f}%,"
         "paper=SLO-MAEL offloads 14.89% more")
    return energy, offload
