"""Benchmark harness — one section per paper table/figure.

Prints ``name,...`` CSV lines.  Sections:
  characterization     (Fig. 1, Key Outcome 1)
  vertical_scaling     (Fig. 2, Key Outcome 2)
  operating_modes      (Fig. 3-5 + Table 2, Key Outcomes 3/4)
  scheduler            (Fig. 7/8/9 + Fig. 10 SLO-MAEL comparison)
  overhead             (Fig. 11)
  energy               (Fig. 12)
  kernel               (Pallas kernel microbenches)
  roofline             (dry-run derived; §Roofline in EXPERIMENTS.md)

Fleet-scale entry points (not run here; each has its own CLI):
  benchmarks/scheduler_experiments.py   10k-job x 64-pool scenarios under
      every policy, old-vs-new simulator wall clock, numpy-vs-Pallas
      scoring, the job-level vs batched serving-bridge comparison, and
      the trace-driven bench_traces (replay / drift / correlated-region
      outage) (--jobs/--pools/--kind, --skip-* flags)
  examples/fleet_scale.py               64-pool demo over every
      scenario preset (--serving {job,batched} selects the service
      model; scenario(..., serving="batched") token-level requests)
  examples/serve_bridge.py              serving-bridge demo with
      per-pool batch stats (docs/serving_bridge.md)
  examples/replay_trace.py              trace export/replay bit-for-bit,
      engine-popularity drift, correlated regional outages
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter).parse_args()
    t0 = time.time()
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.core.offline import characterize

    from benchmarks import (characterization, energy, kernels_bench,
                            operating_modes, overhead, roofline,
                            scheduler_experiments, vertical_scaling)

    cd = characterize()
    print("# characterization (Fig. 1)")
    characterization.run(cd)
    print("# vertical scaling (Fig. 2)")
    vertical_scaling.run()
    print("# operating modes (Fig. 3-5)")
    operating_modes.run()
    print("# scheduler experiments (Fig. 7-10)")
    scheduler_experiments.run(cd)
    print("# scheduling overhead (Fig. 11)")
    overhead.run(cd)
    print("# energy (Fig. 12)")
    energy.run(cd)
    print("# kernel microbenches")
    kernels_bench.run()
    print("# roofline (from dry-run artifacts, single-pod)")
    if os.path.isdir("artifacts/dryrun"):
        roofline.run()
    else:
        print("roofline,skipped=no artifacts/dryrun "
              "(run python -m repro.launch.dryrun --all first)")
    print(f"# total bench time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
