"""Fig. 3/4/5 + Table 2 analogue (Key Outcomes 3 & 4): operating-mode impact
on the edge pools, and the per-factor (frequency / #chips / power) view."""

from __future__ import annotations

import numpy as np

from repro.core.engines import default_engines
from repro.core.perfmodel import ConfigPoint, config_space, estimate
from repro.core.workers import default_fleet


def run(emit=print):
    engines = default_engines()
    rows = []
    for pool in default_fleet():
        if not pool.is_edge:
            continue
        per_mode = {}
        by_freq = {}
        by_chips = {}
        by_power = {}
        for mode in pool.modes:
            qpss = []
            for eng in engines.values():
                best = 0.0
                for pt in config_space(eng, pool):
                    if pt.mode != mode:
                        continue
                    est = estimate(eng, pool, pt)
                    if est.feasible:
                        best = max(best, est.qps)
                if best > 0:
                    qpss.append(best)
            if not qpss:
                continue
            per_mode[mode.name] = float(np.mean(qpss))
            by_freq.setdefault(mode.clock_scale, []).extend(qpss)
            by_chips.setdefault(mode.chips_online, []).extend(qpss)
            by_power.setdefault(mode.power_budget_w, []).extend(qpss)
            emit(f"operating_modes,{pool.name},{mode.name},"
                 f"clock={mode.clock_scale:.2f},chips={mode.chips_online},"
                 f"power_w={mode.power_budget_w:.0f},"
                 f"avg_qps={per_mode[mode.name]:.2f}")
        best_mode = max(per_mode, key=per_mode.get)
        worst_mode = min(per_mode, key=per_mode.get)
        emit(f"operating_modes_headline,{pool.name},best={best_mode},"
             f"worst={worst_mode},"
             f"spread={per_mode[best_mode] / per_mode[worst_mode]:.2f}x")
        # KO4: frequency is the dominant factor
        freqs = sorted(by_freq)
        corr_f = np.corrcoef(
            [f for f in freqs for _ in by_freq[f]],
            [q for f in freqs for q in by_freq[f]])[0, 1]
        chips = sorted(by_chips)
        corr_c = np.corrcoef(
            [c for c in chips for _ in by_chips[c]],
            [q for c in chips for q in by_chips[c]])[0, 1]
        emit(f"operating_modes_factors,{pool.name},"
             f"freq_qps_corr={corr_f:.2f},chips_qps_corr={corr_c:.2f},"
             f"paper=frequency dominates (KO4)")
        rows.append((pool.name, per_mode, corr_f, corr_c))
    return rows
