"""Fig. 2 analogue (Key Outcome 2): vertical scaling — QPS vs
chips-per-replica on the cloud pod (the paper's thread-count sweep)."""

from __future__ import annotations

from repro.core.engines import default_engines
from repro.core.perfmodel import ConfigPoint, estimate
from repro.core.workers import default_fleet


def run(emit=print):
    cloud = default_fleet()[0]
    mode = cloud.modes[0]
    engines = default_engines()
    rows = []
    speedups = {}
    for name, eng in engines.items():
        base = None
        for r in (1, 2, 4, 8, 16):
            est = estimate(eng, cloud, ConfigPoint(mode, r))
            if not est.feasible:
                continue
            base = base or est.qps
            rows.append((name, r, est.qps))
            speedups.setdefault(r, []).append(est.qps / base)
            emit(f"vertical_scaling,{name},chips={r},qps={est.qps:.2f},"
                 f"speedup={est.qps / base:.2f}x,bottleneck={est.bottleneck}")
    import numpy as np
    for r in sorted(speedups):
        emit(f"vertical_scaling_avg,chips={r},"
             f"speedup={np.mean(speedups[r]):.2f}x")
    emit("vertical_scaling_headline,paper=1.6x/2.5x/3.8x/4.5x for 2/4/8/16 "
         "threads with diminishing returns past 8")
    return rows
