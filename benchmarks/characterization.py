"""Fig. 1 analogue: characterization of every engine across all workers —
QPS, preprocessing time, execution time (per 1000 queries)."""

from __future__ import annotations

import numpy as np

from repro.core.engines import default_engines
from repro.core.job import exec_time
from repro.core.offline import characterize

WORKERS = ["cloud-pod", "edge-large", "edge-small"]


def run(cd=None, emit=print):
    cd = cd or characterize()
    rows = []
    for e in default_engines():
        for w in WORKERS:
            ent = cd.optimal(e, w)
            if ent is None:
                continue
            rows.append((e, w, ent.qps, ent.preproc_s,
                         exec_time(ent, 1000), ent.mode,
                         ent.chips_per_replica, ent.bottleneck))
            emit(f"characterization,{e},{w},qps={ent.qps:.2f},"
                 f"preproc_s={ent.preproc_s:.2f},"
                 f"exec1000_s={exec_time(ent, 1000):.1f},"
                 f"config={ent.mode}/r{ent.chips_per_replica},"
                 f"bottleneck={ent.bottleneck}")
    # headline: cloud vs edge ratios (paper: x86 is 2.8x/4.2x AGX/NX on QPS)
    by_w = {w: [] for w in WORKERS}
    for e in default_engines():
        ents = {w: cd.optimal(e, w) for w in WORKERS}
        if all(ents.values()):
            for w in WORKERS:
                by_w[w].append(ents[w].qps)
    r_large = np.mean([a / b for a, b in zip(by_w["cloud-pod"],
                                             by_w["edge-large"])])
    r_small = np.mean([a / b for a, b in zip(by_w["cloud-pod"],
                                             by_w["edge-small"])])
    emit(f"characterization_headline,cloud_vs_edge_large={r_large:.2f}x,"
         f"cloud_vs_edge_small={r_small:.2f}x,paper=2.8x/4.2x")
    return rows
