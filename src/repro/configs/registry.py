"""Architecture config registry: ``--arch <id>`` resolution."""

from __future__ import annotations

from repro.configs import (deepseek_v2_236b, gemma_2b, h2o_danube_1_8b,
                           hymba_1_5b, llama32_vision_11b, phi35_moe_42b,
                           qwen3_32b, qwen3_4b, rwkv6_1_6b,
                           seamless_m4t_medium)
from repro.configs.base import SHAPES, ModelConfig, ShapeCell, reduced

ARCHS = {
    "h2o-danube-1.8b": h2o_danube_1_8b.CONFIG,
    "gemma-2b": gemma_2b.CONFIG,
    "qwen3-32b": qwen3_32b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
    "llama-3.2-vision-11b": llama32_vision_11b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(name: str) -> ShapeCell:
    return SHAPES[name]


def cell_applicable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k-token decode needs "
                       "sub-quadratic attention (documented skip)")
    return True, ""


def all_cells():
    """Every assigned (arch, shape) cell with its applicability."""
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, why = cell_applicable(cfg, shape)
            yield arch, cfg, shape, ok, why


__all__ = ["ARCHS", "get_config", "get_shape", "cell_applicable",
           "all_cells", "reduced", "SHAPES"]
