"""deepseek-v2-236b — MLA (kv_lora=512), 2 shared + 160 routed top-6.

Per the assignment config all 60 layers are MoE (the real model's dense first
layer is folded into the uniform stack — noted in DESIGN.md). [arXiv:2405.04434]
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab=102400,
    act="silu",
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
)
