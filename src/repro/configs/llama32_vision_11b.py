"""llama-3.2-vision-11b — decoder backbone with cross-attn image layers.

The modality frontend (ViT encoder + projector) is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings [B, 1601, d_model].
[hf:meta-llama/Llama-3.2-11B-Vision]
"""

from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    act="silu",
    rope_theta=500000.0,
    vision=VisionConfig(n_vision_tokens=1601, cross_attn_every=5),
)
