"""hymba-1.5b — parallel attention + Mamba heads per layer. [arXiv:2411.13676]

Meta-tokens are omitted (orthogonal to scheduling/serving; noted in DESIGN.md).
SWA on all layers except three global ones, per the paper.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    act="silu",
    sliding_window=1024,
    global_layers=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, d_inner_mult=2, d_conv=4),
)
