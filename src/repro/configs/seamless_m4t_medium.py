"""seamless-m4t-medium — encoder-decoder, multimodal. [arXiv:2308.11596]

The speech frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, S, d_model] for the encoder.
"""

from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                 # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    act="gelu",
    encdec=EncDecConfig(n_enc_layers=12),
)
