"""Model configuration dataclasses shared by every assigned architecture."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0           # per-expert intermediate size
    capacity_factor: float = 1.25  # GShard-style dispatch capacity
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16            # N — per-channel state size
    d_inner_mult: int = 2          # d_inner = mult * d_model
    d_conv: int = 4                # depthwise conv width
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)
    rwkv_head_dim: int = 64        # RWKV head size
    lora_rank: int = 64            # RWKV6 ddlerp LoRA rank


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    n_vision_tokens: int = 1601    # stubbed frontend: precomputed patch embeds
    d_vision: int = 0              # 0 -> d_model (post-projector width)
    cross_attn_every: int = 5      # a cross-attn layer every N decoder layers


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 12
    d_source: int = 0              # 0 -> d_model (stubbed audio frame embeds)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | ssm | moe | vlm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"              # silu -> SwiGLU, gelu -> GeGLU
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()   # layers that stay global under SWA
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    scale_embeddings: bool = False        # gemma-style sqrt(d_model) embed scale
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    vision: Optional[VisionConfig] = None
    encdec: Optional[EncDecConfig] = None
    dtype: str = "bfloat16"
    # Serving/runtime knobs (part of the "engine configuration" the SynergAI
    # offline phase tunes per worker):
    remat: bool = True
    attn_chunk: int = 512          # kv-chunk for the XLA flash path
    flash_threshold: int = 2048    # use chunked flash for seq >= threshold

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is O(1)/O(window) per token."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    @property
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and load time)."""
        d, L = self.d_model, self.n_layers
        n_emb = self.vocab * d * 2  # in + out embedding (untied)
        per_layer = 0
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_hd
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.family == "ssm":  # RWKV6 time-mix
            hd = self.ssm.rwkv_head_dim
            per_layer += 4 * d * d + d * d  # r,k,v,g,o projections
            per_layer += 5 * 2 * d * self.ssm.lora_rank  # ddlerp LoRAs
        else:
            per_layer += d * self.n_heads * self.head_dim  # wq
            per_layer += 2 * d * self.n_kv_heads * self.head_dim  # wk, wv
            per_layer += self.n_heads * self.head_dim * d  # wo
        if self.family == "hybrid":  # parallel mamba branch
            di = self.ssm.d_inner_mult * d
            per_layer += 2 * d * di + di * d + di * self.ssm.d_conv
            per_layer += di * (2 * self.ssm.state_dim + 2)
        if self.moe is not None:
            e = self.moe
            ff = e.d_ff_expert or self.d_ff
            per_layer += d * e.n_experts  # router
            per_layer += (e.n_experts + e.n_shared) * 3 * d * ff
        elif self.family == "ssm":
            per_layer += 2 * d * self.d_ff  # RWKV channel-mix (k, v) + receptance
            per_layer += d * d
        else:
            per_layer += 3 * d * self.d_ff  # SwiGLU/GeGLU
        total = n_emb + L * per_layer
        if self.vision is not None:
            n_cross = L // self.vision.cross_attn_every
            total += n_cross * (2 * d * self.n_kv_heads * self.head_dim)
        if self.encdec is not None:
            # encoder layers + decoder cross-attention
            enc_layer = 4 * d * self.head_dim * self.n_heads + 3 * d * self.d_ff
            total += self.encdec.n_enc_layers * enc_layer
            total += L * (4 * d * self.head_dim * self.n_kv_heads)
        return int(total)

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count
        e = self.moe
        ff = e.d_ff_expert or self.d_ff
        d, L = self.d_model, self.n_layers
        inactive = L * (e.n_experts - e.top_k) * 3 * d * ff
        return int(self.param_count - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""

    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab=256,
        dtype="float32",
        remat=False,
        flash_threshold=64,
        attn_chunk=32,
    )
    if cfg.moe is not None:
        # generous capacity so smoke tests see no token dropping (capacity
        # dropping is order-dependent and breaks prefill/decode equivalence)
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=64,
            capacity_factor=8.0)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=8, rwkv_head_dim=16, lora_rank=8)
    if cfg.vision is not None:
        changes["vision"] = dataclasses.replace(
            cfg.vision, n_vision_tokens=17, cross_attn_every=2)
    if cfg.encdec is not None:
        changes["encdec"] = dataclasses.replace(cfg.encdec, n_enc_layers=2)
    if cfg.sliding_window is not None:
        changes["sliding_window"] = 32
    if cfg.global_layers:
        changes["global_layers"] = (0,)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
