"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d_model / rwkv_head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    ssm=SSMConfig(rwkv_head_dim=64, lora_rank=64),
)
