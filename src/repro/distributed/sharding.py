"""Logical-axis sharding rules -> PartitionSpecs for params, optimizer
state, batches and caches, with divisibility fallback.

The rule system is MaxText-style: every parameter leaf is matched (by its
tree path) to a tuple of logical axis names; a rule table maps logical axes
to mesh axes.  A dimension is only sharded if its size divides the mesh-axis
size and the mesh axis is not already used by an earlier dimension of the
same tensor — so GQA heads that don't divide the model axis, batch=1
long-context decode, and the 2-pod mesh all degrade gracefully to
replication instead of failing to lower.
"""

from __future__ import annotations

import fnmatch
import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# leaf path -> logical axes

# evaluated top-down, first match wins; patterns match the dot-joined path
# *without* the group index (e.g. "groups.attn.wq", "embed.tok")
PARAM_AXES = [
    ("embed.tok", ("vocab", "embed")),
    ("embed.head", ("embed", "vocab")),
    ("*wq_a", ("layers", "embed", "lora")),
    ("*wq_b", ("layers", "lora", "heads", "head_dim")),
    ("*wkv_a", ("layers", "embed", "lora")),
    ("*wk_b", ("layers", "lora", "heads", "head_dim")),
    ("*wv_b", ("layers", "lora", "heads", "head_dim")),
    ("*attn.wq", ("layers", "embed", "heads", "head_dim")),
    ("*attn.wk", ("layers", "embed", "kv_heads", "head_dim")),
    ("*attn.wv", ("layers", "embed", "kv_heads", "head_dim")),
    ("*attn.wo", ("layers", "heads", "head_dim", "embed")),
    ("*cross.wq", ("layers", "embed", "heads", "head_dim")),
    ("*cross.wk", ("layers", "embed", "kv_heads", "head_dim")),
    ("*cross.wv", ("layers", "embed", "kv_heads", "head_dim")),
    ("*cross.wo", ("layers", "heads", "head_dim", "embed")),
    ("*moe.router", ("layers", "embed", None)),
    ("*moe.shared.wi", ("layers", "embed", "mlp")),
    ("*moe.shared.wg", ("layers", "embed", "mlp")),
    ("*moe.shared.wo", ("layers", "mlp", "embed")),
    ("*moe.wi", ("layers", "experts", "expert_embed", "expert_mlp")),
    ("*moe.wg", ("layers", "experts", "expert_embed", "expert_mlp")),
    ("*moe.wo", ("layers", "experts", "expert_mlp", "expert_embed")),
    ("*mlp.wi", ("layers", "embed", "mlp")),
    ("*mlp.wg", ("layers", "embed", "mlp")),
    ("*mlp.wo", ("layers", "mlp", "embed")),
    # rwkv time-mix / channel-mix
    ("*tm.lora_*_a", ("layers", "embed", "lora")),
    ("*tm.lora_*_b", ("layers", "lora", "embed")),
    ("*tm.w0", ("layers", "embed")),
    ("*tm.u", ("layers", "embed")),
    ("*tm.mu_*", ("layers", "embed")),
    ("*tm.ln_x", ("layers", "embed")),
    ("*tm.wo", ("layers", "hidden", "embed")),
    ("*tm.w*", ("layers", "embed", "hidden")),
    ("*cm.mu_*", ("layers", "embed")),
    ("*cm.wk", ("layers", "embed", "mlp")),
    ("*cm.wv", ("layers", "mlp", "embed")),
    ("*cm.wr", ("layers", "embed", "hidden")),
    # mamba branch
    ("*mamba.in_proj", ("layers", "embed", "inner")),
    ("*mamba.conv_w", ("layers", None, "inner")),
    ("*mamba.x_proj", ("layers", "inner", None)),
    ("*mamba.dt_proj", ("layers", None, "inner")),
    ("*mamba.dt_bias", ("layers", "inner")),
    ("*mamba.A_log", ("layers", "inner", None)),
    ("*mamba.Dskip", ("layers", "inner")),
    ("*mamba.out_proj", ("layers", "inner", "embed")),
    # norms / gates / everything else: replicate (layers dim kept logical)
    ("*", None),
]

# logical axis -> mesh axis (or tuple of mesh axes)
PARAM_RULES = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "hidden": "model",
    "inner": "model",
    "experts": "model",
    "expert_embed": "data",   # 2D expert-weight sharding (deepseek-scale)
    "embed": None,
    "head_dim": None,
    "layers": None,
    "lora": None,
    "expert_mlp": None,
}

# optimizer state additionally shards big replicated dims over data (ZeRO-1),
# and over the pod axis on the multi-pod mesh (falls back gracefully when
# the mesh has no 'pod' axis or the layer count doesn't divide)
OPT_EXTRA = {"embed": "data", "layers": "pod"}

# training params are FSDP-sharded over data as well (all-gathered per layer
# inside the scan by GSPMD); inference keeps TP-only params for low-latency
# decode.  This is the standard v5e recipe (16 GB HBM/chip).
TRAIN_RULES = dict(PARAM_RULES, embed="data", layers="pod")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            continue  # drop group indices so patterns stay stable
        else:
            parts.append(str(p))
    return ".".join(parts)


def _axes_for(path_str: str):
    for pat, axes in PARAM_AXES:
        if fnmatch.fnmatch(path_str, pat):
            return axes
    return None


def _mesh_sizes(mesh: Mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve(axes, shape, mesh: Mesh, rules) -> P:
    """Logical axes -> PartitionSpec with divisibility + reuse fallback."""
    if axes is None:
        return P()
    sizes = _mesh_sizes(mesh)
    # stacked group params may have one more leading dim than the logical
    # spec (vlm/hymba single-layer groups are stacked with n=1); pad left
    axes = tuple(axes)
    if len(axes) < len(shape):
        axes = (None,) * (len(shape) - len(axes)) + axes
    elif len(axes) > len(shape):
        axes = axes[len(axes) - len(shape):]
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        mesh_ax = rules.get(ax) if ax else None
        if mesh_ax is None:
            out.append(None)
            continue
        maxes = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        maxes = tuple(m for m in maxes if m in sizes)
        total = int(np.prod([sizes[m] for m in maxes])) if maxes else 1
        if (not maxes or any(m in used for m in maxes)
                or dim % total != 0):
            out.append(None)
            continue
        used.update(maxes)
        out.append(mesh_ax if isinstance(mesh_ax, tuple) else mesh_ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(params_tree, mesh: Mesh, rules=None):
    """PartitionSpec pytree for a (shape-only or real) params pytree."""
    rules = rules or PARAM_RULES

    def one(path, leaf):
        return _resolve(_axes_for(_path_str(path)), leaf.shape, mesh, rules)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def opt_pspecs(params_tree, mesh: Mesh):
    rules = dict(PARAM_RULES, **OPT_EXTRA)
    return param_pspecs(params_tree, mesh, rules)


def dp_axes(mesh: Mesh):
    """The data-parallel mesh axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_pspecs(batch_tree, mesh: Mesh):
    """Shard the leading batch dim over the DP axes; everything else
    replicated.  Scalars (decode pos) stay fully replicated."""
    dp = dp_axes(mesh)
    sizes = _mesh_sizes(mesh)
    dp_total = int(np.prod([sizes[a] for a in dp]))

    def one(_, leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % dp_total == 0:
            return P(dp if len(dp) > 1 else dp[0])
        return P()

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_pspecs(cache_tree, mesh: Mesh):
    """Decode-cache sharding: batch dim (axis 1, after the stacked-group
    axis) over DP; the largest remaining dim (KV sequence, recurrent heads,
    or inner channels) over 'model' when divisible."""
    dp = dp_axes(mesh)
    sizes = _mesh_sizes(mesh)
    dp_total = int(np.prod([sizes[a] for a in dp]))
    model = sizes.get("model", 1)

    def one(_, leaf):
        if leaf.ndim <= 1:
            return P()
        spec = [None] * leaf.ndim
        if leaf.shape[1] % dp_total == 0:
            spec[1] = dp if len(dp) > 1 else dp[0]
        tail = [(s, i) for i, s in enumerate(leaf.shape) if i >= 2]
        for s, i in sorted(tail, reverse=True):
            if s % model == 0 and model > 1:
                spec[i] = "model"
                break
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def sharded_decode_attention(q, k, v, k_valid):
    """Distributed flash-decode over a sequence-sharded KV cache.

    GSPMD lowers single-token attention against an S-sharded cache by
    ALL-GATHERING the cache (2.1 GB/layer/step on qwen3-32b decode_32k).
    This shard_map version computes local online-softmax statistics per
    model shard and merges (m, l, o) with pmax/psum — collective payload
    drops from O(cache) to O(B*H*hd).

    q: [B, 1, H, hd] (replicated over model); k/v: [B, S, K, hd] with S
    sharded over 'model'.  Returns [B, 1, H, hd].
    """
    mesh = get_active_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or k.shape[1] % _mesh_sizes(mesh)["model"] != 0):
        return None  # caller falls back to the XLA path
    from jax.experimental.shard_map import shard_map

    sizes = _mesh_sizes(mesh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = int(np.prod([sizes[a] for a in dp])) if dp else 1
    b_ax = (dp if len(dp) > 1 else dp[0]) if (
        dp and q.shape[0] % dp_total == 0) else None
    q_spec = P(b_ax, None, None, None)
    kv_spec = P(b_ax, "model", None, None)

    B, _, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    hd_v = v.shape[-1]

    def local_fn(q_l, k_l, v_l, k_valid_l):
        S_loc = k_l.shape[1]
        offset = jax.lax.axis_index("model") * S_loc
        qf = q_l[:, 0].reshape(-1, K, G, hd).astype(jnp.float32)
        s = jnp.einsum("bkgh,bskh->bkgs", qf, k_l.astype(jnp.float32))
        s = s / jnp.sqrt(jnp.float32(hd))
        valid = (offset + jnp.arange(S_loc)) < k_valid_l
        s = jnp.where(valid[None, None, None, :], s, -1e30)
        m = s.max(axis=-1)
        p = jnp.exp(s - m[..., None])
        l = p.sum(axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", p, v_l.astype(jnp.float32))
        # merge partial softmax stats across the model shards
        m_g = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, "model")
        o_g = jax.lax.psum(o * w[..., None], "model")
        o_g = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return o_g.reshape(-1, 1, H, hd_v).astype(q_l.dtype)

    import jax.numpy as jnp_  # noqa: F401 (kept for clarity)
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(q_spec, kv_spec, kv_spec, P()),
                   out_specs=q_spec, check_rep=False)
    return fn(q, k, v, jnp.asarray(k_valid, jnp.int32))


import jax.numpy as jnp  # noqa: E402  (used by the shard_map path)


def to_shardings(pspec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# --- activation sharding constraints ---------------------------------------
# ``with mesh:`` does not install an abstract mesh for tracing, so the
# launcher threads the active mesh explicitly before lowering.
_ACTIVE_MESH: list = [None]


def set_active_mesh(mesh: Optional[Mesh]):
    _ACTIVE_MESH[0] = mesh


def get_active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH[0]


def constrain_tokens(x):
    """Shard a flattened token tensor [T, ...] over every DP axis and the
    model axis jointly (used around the MoE dispatch, where the [B, S, D]
    -> [T, D] reshape would otherwise let GSPMD replicate 10+ GB of
    activations)."""
    mesh = get_active_mesh()
    if mesh is None:
        return x
    sizes = _mesh_sizes(mesh)
    axes = tuple(mesh.axis_names)
    total = int(np.prod([sizes[a] for a in axes]))
    if x.ndim < 2 or x.shape[0] % total != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(axes)))


def _dp_axis(mesh, batch_dim):
    sizes = _mesh_sizes(mesh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = int(np.prod([sizes[a] for a in dp])) if dp else 1
    if not dp or batch_dim % dp_total != 0:
        return None
    return dp if len(dp) > 1 else dp[0]


def constrain_moe_groups(xg):
    """[B, G, g, D] token groups: batch over DP, groups over 'model' —
    matching the sequence-parallel residual stream so no reshard happens
    on MoE entry/exit."""
    mesh = get_active_mesh()
    if mesh is None or "model" not in mesh.axis_names or xg.ndim != 4:
        return xg
    sizes = _mesh_sizes(mesh)
    g_ax = "model" if (xg.shape[1] % sizes["model"] == 0
                       and xg.shape[1] > 1) else None
    return jax.lax.with_sharding_constraint(
        xg, NamedSharding(mesh, P(_dp_axis(mesh, xg.shape[0]), g_ax)))


def constrain_moe_expert(t):
    """[B, G, E, C, D] expert-major tensors: experts over 'model' — the
    group->expert transition lowers to the canonical MoE all-to-all."""
    mesh = get_active_mesh()
    if mesh is None or "model" not in mesh.axis_names or t.ndim != 5:
        return t
    sizes = _mesh_sizes(mesh)
    e_ax = "model" if t.shape[2] % sizes["model"] == 0 else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(_dp_axis(mesh, t.shape[0]), None, e_ax)))


# runtime knob (§Perf): sequence-parallel residual stream on/off.  Models
# whose head count cannot shard over 'model' (gemma-2b: 8 heads vs TP=16)
# pay attention-resharding churn under SP; batch-only sharding wins there.
SEQ_SHARD = True


def constrain_seq(x):
    """Megatron-style sequence parallelism: shard the residual stream's
    sequence dim over 'model' between layers so the remat-saved per-layer
    carries are 1/TP the size.  No-op without an active mesh or when the
    shape doesn't divide — safe to call unconditionally from model code.
    """
    mesh = get_active_mesh()
    if not SEQ_SHARD or mesh is None or "model" not in mesh.axis_names:
        return x
    sizes = _mesh_sizes(mesh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_total = int(np.prod([sizes[a] for a in dp])) if dp else 1
    if x.ndim < 3 or x.shape[1] % sizes["model"] != 0 or x.shape[1] <= 1:
        return x
    b_ax = (dp if len(dp) > 1 else dp[0]) if (
        dp and x.shape[0] % dp_total == 0) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, "model")))
