"""Optimized-HLO parsing: loop-aware collective byte accounting.

Collectives inside while-loop bodies (layer scans, grad-accumulation loops,
flash kv-chunk loops) appear once in the HLO text but execute trip-count
times.  This parser splits the module into computations, reads each while
loop's trip count from the constant in its condition computation, and
multiplies body collective bytes accordingly (recursively for nested
loops).
"""

from __future__ import annotations

import re
from typing import Dict, Tuple

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, list]:
    """Computation headers look like
    ``%region_0.1_spmd (param: (s32[], ...)) -> (...) {`` — possibly with
    nested parens — or ``ENTRY %main.4_spmd (...) -> f32[] {``."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None or s.rstrip().endswith("{"):
            if s.rstrip().endswith("{") and ("->" in s or
                                             s.startswith("ENTRY")):
                m = _COMP_RE.match(s)
                if m:
                    cur = m.group(1).split("(")[0]
                    comps[cur] = []
                continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def collective_bytes_loop_aware(hlo: str) -> Tuple[Dict[str, int],
                                                   Dict[str, int]]:
    """Returns (per-collective bytes, per-collective op counts), scaled by
    while-loop trip counts.  Bytes are per-device (the module is the SPMD
    per-device program)."""
    comps = _split_computations(hlo)

    # per-computation direct collectives and while-calls
    direct = {}
    calls = {}
    for name, lines in comps.items():
        d = {c: 0 for c in COLLECTIVES}
        cnt = {c: 0 for c in COLLECTIVES}
        wh = []
        for s in lines:
            if "=" not in s:
                continue
            rhs = s.split("=", 1)[1]
            for coll in COLLECTIVES:
                mm = re.search(rf"\s{coll}(?:-start)?\(", rhs)
                if mm:
                    d[coll] += _shape_bytes(rhs[:mm.start()])
                    cnt[coll] += 1
                    break
            mw = _WHILE_RE.search(rhs)
            if mw:
                wh.append((mw.group(1), mw.group(2)))
        direct[name] = (d, cnt)
        calls[name] = wh

    def trip_count(cond_name: str) -> int:
        best = 1
        for s in comps.get(cond_name, []):
            for m in _CONST_RE.finditer(s):
                best = max(best, int(m.group(1)))
        return best

    memo = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        if depth > 32 or name not in direct:
            return ({c: 0 for c in COLLECTIVES},
                    {c: 0 for c in COLLECTIVES})
        d, cnt = direct[name]
        d, cnt = dict(d), dict(cnt)
        for cond, body in calls[name]:
            trips = trip_count(cond)
            bd, bc = total(body, depth + 1)
            for c in COLLECTIVES:
                d[c] += bd[c] * trips
                cnt[c] += bc[c] * trips
        memo[name] = (d, cnt)
        return memo[name]

    # entry computation: the one containing whiles at top level, or the one
    # named like the jit'd function; fall back to summing roots not called
    # by anyone.
    called_bodies = {b for ws in calls.values() for _, b in ws}
    called_conds = {c for ws in calls.values() for c, _ in ws}
    roots = [n for n in comps
             if n not in called_bodies and n not in called_conds
             and not n.startswith(("fused", "region", "wide."))]
    agg = {c: 0 for c in COLLECTIVES}
    cntagg = {c: 0 for c in COLLECTIVES}
    # prefer a main/entry computation if identifiable
    mains = [n for n in roots if "main" in n or "entry" in n.lower()]
    for n in (mains or roots):
        d, cnt = total(n)
        for c in COLLECTIVES:
            agg[c] += d[c]
            cntagg[c] += cnt[c]
    return agg, cntagg
