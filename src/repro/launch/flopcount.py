"""Loop-aware FLOP/byte counting from jaxprs.

``compiled.cost_analysis()`` counts each while-loop body ONCE (scan bodies,
grad-accumulation loops, flash chunks), which undercounts layer-scanned
models by ~n_layers.  This module walks the jaxpr instead, multiplying
scan bodies by their trip count, giving exact global HLO-level FLOPs
(including remat recompute — the backward jaxpr contains the replayed
forward) and a fusion-aware byte estimate:

  - dot_general:  2*B*M*N*K flops; reads both operands + writes output
  - elementwise:  1 flop per output element; bytes counted for the output
                  only (inputs assumed fused into the producer)
  - reduce/scatter/gather/dus: bytes for operands + output
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import numpy as np
from jax import core

ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "sign", "floor", "ceil", "abs",
    "pow", "integer_pow", "erf", "cbrt", "select_n", "clamp", "rem",
    "and", "or", "xor", "not", "atan2", "expm1", "log1p", "cos", "sin",
    "nextafter",
}
BYTES_HEAVY = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
               "argmax", "argmin", "cumsum", "cumprod", "cumlogsumexp",
               "gather", "scatter", "scatter-add", "scatter_add",
               "dynamic_slice", "dynamic_update_slice", "concatenate",
               "transpose", "reshape", "rev", "sort", "iota", "copy",
               "convert_element_type", "broadcast_in_dim", "pad", "slice",
               "squeeze", "reduce_precision", "select_and_scatter_add"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = int(np.prod([lhs[i] for i in lb])) if lb else 1
    k = int(np.prod([lhs[i] for i in lc])) if lc else 1
    m = int(np.prod([d for i, d in enumerate(lhs)
                     if i not in set(lc) | set(lb)]))
    n = int(np.prod([d for i, d in enumerate(rhs)
                     if i not in set(rc) | set(rb)]))
    return 2 * batch * m * n * k


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for call-like primitives."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(p["jaxpr"], int(p["length"]))]
    if name == "while":
        # bounded fori_loops carry their trip count via cond constants; we
        # don't emit raw unbounded whiles in model code
        return [(p["body_jaxpr"], 1)]
    if name == "cond":
        return [(br, 1) for br in p["branches"]]
    # generic call-like primitives: recurse into every jaxpr-valued param
    def is_jaxpr(v):
        return hasattr(v, "eqns") or hasattr(getattr(v, "jaxpr", None),
                                             "eqns")

    return [(v, 1) for v in p.values() if is_jaxpr(v)]


def count(jaxpr) -> tuple[int, int]:
    """Returns (flops, bytes) for a (Closed)Jaxpr, loop-aware."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    flops = 0
    byts = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        subs = _sub_jaxprs(eqn)
        if subs:
            for sub, mult in subs:
                f, b = count(sub)
                flops += f * mult
                byts += b * mult
            continue
        out_b = sum(_bytes(v.aval) for v in eqn.outvars)
        if name == "dot_general":
            flops += _dot_flops(eqn)
            byts += out_b + sum(_bytes(v.aval) for v in eqn.invars)
        elif name in ELEMENTWISE:
            flops += sum(_size(v.aval) for v in eqn.outvars)
            byts += out_b
        elif name in BYTES_HEAVY:
            byts += out_b + sum(_bytes(v.aval) for v in eqn.invars)
        else:
            byts += out_b
    return flops, byts


def analyze(fn, *args) -> dict:
    """Trace ``fn`` with ShapeDtypeStruct args and count flops/bytes."""
    closed = jax.make_jaxpr(fn)(*args)
    flops, byts = count(closed)
    return {"flops_global": int(flops), "bytes_global": int(byts)}
