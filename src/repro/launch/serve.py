"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Brings up a reduced-config replica of the selected architecture and serves
a batch of synthetic requests through the SynergAI scheduler (worker
selection via Eq. 1-4 against the offline Configuration Dictionary).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, get_config
from repro.core.engines import default_engines
from repro.core.estimator import candidate_order, estimate_matrix
from repro.core.job import Job
from repro.core.offline import characterize
from repro.models.registry import build_model
from repro.serving.engine import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--requests", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params,
                          max_len=args.prompt_len + args.gen + 8)
    cd = characterize()
    workers = ["cloud-pod", "edge-large", "edge-small"]
    engine_name = next((n for n, e in default_engines().items()
                        if e.arch == args.arch), None)

    key = jax.random.PRNGKey(1)
    for rid in range(args.requests):
        if engine_name:
            job = Job(rid, engine_name, queries=100, t_qos=120.0,
                      arrival=0.0)
            score = estimate_matrix(cd, [job], workers, now=0.0)
            order = candidate_order(score, 0)
            worker = workers[order[0]] if order else "cloud-pod"
            ent = cd.optimal(engine_name, worker)
            plan = f"{worker} (c*={ent.mode}/r{ent.chips_per_replica})"
        else:
            plan = "local"
        key, sub = jax.random.split(key)
        toks = jax.random.randint(sub, (args.batch, args.prompt_len), 0,
                                  cfg.vocab)
        batch = {"tokens": toks}
        if cfg.family == "vlm":
            batch["vision_embeds"] = 0.02 * jax.random.normal(
                sub, (args.batch, cfg.vision.n_vision_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["audio_embeds"] = 0.02 * jax.random.normal(
                sub, (args.batch, args.prompt_len, cfg.d_model))
        t0 = time.perf_counter()
        out = eng.generate(batch, args.gen)
        print(f"req {rid} -> {plan}: generated {out.shape[1]} tokens "
              f"x batch {out.shape[0]} in {time.perf_counter() - t0:.2f}s")
    s = eng.stats
    print(f"stats: prefill {s.prefill_tokens} tok ({s.prefill_s:.2f}s), "
          f"decode {s.decoded_tokens} tok ({s.decode_s:.2f}s)")


if __name__ == "__main__":
    main()
