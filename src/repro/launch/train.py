"""Training launcher: ``python -m repro.launch.train --arch <id>``.

On CPU (this container) it trains the reduced config of the selected
architecture with checkpoint/restart; on a TPU fleet the same step function
is what the dry-run lowers against the production mesh (--production shows
the lowering without executing).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import ARCHS, get_config
from repro.models.registry import build_model
from repro.training import checkpoint
from repro.training.data import DataLoader
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    start = 0
    if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        import numpy as np
        start = checkpoint.latest_step(args.ckpt_dir)
        state = checkpoint.restore(args.ckpt_dir,
                                   jax.tree.map(np.asarray, state))
        print(f"resumed from step {start}")

    def extra_fn(batch, seq):
        import numpy as np
        out = {}
        if cfg.family == "vlm":
            out["vision_embeds"] = 0.02 * np.random.default_rng(0).standard_normal(
                (batch, cfg.vision.n_vision_tokens, cfg.d_model)).astype("float32")
        if cfg.family == "audio":
            out["audio_embeds"] = 0.02 * np.random.default_rng(0).standard_normal(
                (batch, seq, cfg.d_model)).astype("float32")
        return out

    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    dl = DataLoader(cfg.vocab, args.batch, args.seq, seed=start,
                    extra_fn=extra_fn if cfg.family in ("vlm", "audio")
                    else None)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(dl).items()}
        state, metrics = step_fn(state, batch)
        if (step + 1) % 10 == 0:
            print(f"[{args.arch}] step {step + 1:4d} "
                  f"loss {float(metrics['loss']):.3f} "
                  f"({(step + 1 - start) / (time.time() - t0):.2f} it/s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step + 1, state)
    dl.close()


if __name__ == "__main__":
    main()
