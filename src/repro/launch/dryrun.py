import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell, AOT-lower and compile the cell's
step function (train_step / prefill_step / serve_step) against the production
mesh — 16x16 single-pod and 2x16x16 multi-pod — with ShapeDtypeStruct inputs
(no allocation), then record:

  - memory_analysis()            (proves the per-device program fits)
  - cost_analysis()              (per-device HLO FLOPs / bytes)
  - collective bytes             (parsed from the optimized HLO text)

into a JSON artifact per cell under artifacts/dryrun/.  benchmarks/roofline.py
turns these into the EXPERIMENTS.md roofline table.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES, all_cells, cell_applicable, get_config
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
               "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
               "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in optimized HLO."""
    per_op = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        for coll in COLLECTIVES:
            # match ` = <type> all-reduce(` and `-start(` variants
            m = re.match(rf"^(\(?[\w\[\],\s{{}}:#*]*?)\s{coll}(-start)?\(",
                         rhs)
            if not m:
                continue
            tybytes = 0
            for dt, dims in _SHAPE_RE.findall(m.group(1)):
                if dt not in DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                tybytes += n * DTYPE_BYTES[dt]
            per_op[coll] += tybytes
            counts[coll] += 1
            break
    return per_op, counts


def build_cell_fn(arch: str, shape_name: str, mesh, absorb_mla=False,
                  extra_tags=()):
    """Returns (fn, example_args, in_shardings, donate) for one cell."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    batch_specs = model.input_specs(shape)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init_params, key)
    rules = sh.TRAIN_RULES if shape.kind == "train" else sh.PARAM_RULES
    p_spec = sh.param_pspecs(params_shapes, mesh, rules)
    b_spec = sh.batch_pspecs(batch_specs, mesh)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
        state_shapes = {"params": params_shapes, "opt": opt_shapes}
        state_spec = {"params": p_spec,
                      "opt": {"m": sh.opt_pspecs(params_shapes, mesh),
                              "v": sh.opt_pspecs(params_shapes, mesh),
                              "step": jax.sharding.PartitionSpec()}}
        grad_shardings = sh.to_shardings(
            sh.opt_pspecs(params_shapes, mesh), mesh)
        # v5e 16 GB/chip: the largest models microbatch the global batch
        accum = {"deepseek-v2-236b": 8, "phi3.5-moe-42b-a6.6b": 2,
                 "llama-3.2-vision-11b": 2}.get(arch, 1)
        step = make_train_step(model, AdamWConfig(),
                               grad_shardings=grad_shardings,
                               accum_steps=accum)
        args = (state_shapes, batch_specs)
        in_specs = (state_spec, b_spec)
        donate = (0,)
        return step, args, in_specs, donate

    if shape.kind == "prefill":
        def step(params, batch):
            return model.prefill(params, batch)
        args = (params_shapes, batch_specs)
        in_specs = (p_spec, b_spec)
        return step, args, in_specs, ()

    # decode: one new token against a seq_len-deep cache
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_spec = sh.cache_pspecs(cache_shapes, mesh)
    if absorb_mla:
        def step(params, caches, batch):
            return model.decode(params, caches, batch, absorb_mla=True)
    else:
        def step(params, caches, batch):
            return model.decode(params, caches, batch)
    args = (params_shapes, cache_shapes, batch_specs)
    in_specs = (p_spec, c_spec, b_spec)
    return step, args, in_specs, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             absorb_mla=False, tag="") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "tag": tag, "status": "skip", "skip_reason": why}
    if ok:
        t0 = time.time()
        try:
            mesh = make_production_mesh(multi_pod=multi_pod)
            fn, args, in_specs, donate = build_cell_fn(
                arch, shape_name, mesh, absorb_mla=absorb_mla)
            sh.set_active_mesh(mesh)
            try:
                with mesh:
                    jitted = jax.jit(
                        fn,
                        in_shardings=sh.to_shardings(in_specs, mesh),
                        donate_argnums=donate)
                    lowered = jitted.lower(*args)
                    t_lower = time.time() - t0
                    compiled = lowered.compile()
                    t_compile = time.time() - t0 - t_lower
            finally:
                sh.set_active_mesh(None)
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):     # jax <= 0.4.x: [dict]
                ca = ca[0] if ca else {}
            hlo = compiled.as_text()
            from repro.launch.hloparse import collective_bytes_loop_aware
            coll, counts = collective_bytes_loop_aware(hlo)
            coll_flat, _ = collective_bytes(hlo)  # unscaled, for reference
            # loop-aware jaxpr FLOP/byte counts (cost_analysis counts scan
            # bodies once; see launch/flopcount.py)
            from repro.launch import flopcount
            jx = flopcount.analyze(fn, *args)
            record.update(
                status="ok",
                n_devices=mesh.devices.size,
                lower_s=round(t_lower, 2),
                compile_s=round(t_compile, 2),
                memory={
                    "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                    "output_bytes": getattr(ma, "output_size_in_bytes", None),
                    "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                    "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
                    "code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                          None),
                },
                cost={k: v for k, v in ca.items()
                      if "flops" in k or "bytes" in k or "utilization" in k},
                jaxpr_flops_global=jx["flops_global"],
                jaxpr_bytes_global=jx["bytes_global"],
                collective_bytes_per_device=coll,
                collective_bytes_unscaled=coll_flat,
                collective_counts=counts,
                hlo_bytes=len(hlo),
            )
            print(f"[ok] {arch} x {shape_name} x {mesh_name}"
                  f"{(' (' + tag + ')') if tag else ''}: "
                  f"compile {t_compile:.1f}s, "
                  f"flops/dev {ca.get('flops', 0):.3g}, "
                  f"coll/dev {sum(coll.values()):.3g}B")
            # the assignment's required outputs:
            print("  memory_analysis:", record["memory"])
        except Exception as e:  # noqa: BLE001 — record and continue
            record.update(status="error", error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-2000:])
            print(f"[ERROR] {arch} x {shape_name} x {mesh_name}: {e}")
    else:
        print(f"[skip] {arch} x {shape_name}: {why}")

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}{('__' + tag) if tag else ''}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--absorb-mla", action="store_true",
                    help="decode with the absorbed-MLA optimization")
    ap.add_argument("--opt", action="store_true",
                    help="enable the beyond-paper §Perf optimizations "
                         "(shard_map flash-decode, tuned attn chunks)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.opt:
        import dataclasses as _dc

        from repro.configs import registry as _creg
        from repro.models import layers as _layers
        _layers.SHARDED_DECODE_ATTN = True
        _creg.ARCHS["gemma-2b"] = _dc.replace(_creg.ARCHS["gemma-2b"],
                                              attn_chunk=4096)
        if not args.tag:
            args.tag = "opt"

    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    cells = []
    if args.all:
        for arch, cfg, shape, ok, why in all_cells():
            cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape_name in cells:
        for mesh_name in meshes:
            fname = (f"{arch}__{shape_name}__{mesh_name}"
                     f"{('__' + args.tag) if args.tag else ''}.json")
            path = os.path.join(args.out, fname)
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") in ("ok", "skip"):
                        continue
            rec = run_cell(arch, shape_name, mesh_name == "multi", args.out,
                           absorb_mla=args.absorb_mla, tag=args.tag)
            if rec["status"] == "error":
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
