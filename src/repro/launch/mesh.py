"""Production mesh construction (assignment contract).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def _auto_kwargs(n):
    # jax >= 0.5 wants explicit axis_types; older releases have neither
    # jax.sharding.AxisType nor the make_mesh kwarg — omit it there
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1, 2) on CPU)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_auto_kwargs(len(axes)))
