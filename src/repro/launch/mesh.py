"""Production mesh construction (assignment contract).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1, 2) on CPU)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=_auto(len(axes)))
