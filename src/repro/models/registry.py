"""Unified Model API over all assigned architectures.

``build_model(arch_or_cfg)`` returns a ``Model`` whose pure functions are the
things the launcher lowers:

    train_loss(params, batch)            -> scalar loss
    prefill(params, batch)               -> (last_logits [B,V], caches)
    decode(params, caches, batch)        -> (logits [B,V], caches)
    init_params(key)                     -> pytree
    init_cache(batch, buf_len)           -> caches pytree
    input_specs(shape_cell)              -> batch pytree of ShapeDtypeStruct

The modality frontends ([vlm]/[audio]) are stubs by assignment: the batch
carries precomputed ``vision_embeds`` / ``audio_embeds``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.configs.registry import get_config
from repro.models import common, decoder
from repro.models.common import dtype_of


def cross_entropy(logits, labels):
    """logits: [B, S, V] (any float dtype), labels: [B, S] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


CE_CHUNK = 512  # sequence tokens per loss chunk


def chunked_ce_loss(params, cfg, x, labels):
    """Cross-entropy without materializing full [B, S, V] logits.

    The unembed + logsumexp runs per sequence chunk under remat, so the
    peak logits buffer is S/CE_CHUNK times smaller — this is what lets the
    256k-vocab train cells fit 16 GB/chip.
    """
    B, S, D = x.shape
    n = S // CE_CHUNK if (S % CE_CHUNK == 0 and S > CE_CHUNK) else 1
    if n == 1:
        logits = common.unembed(params["embed"], cfg, x)
        return cross_entropy(logits, labels)
    xc = x.reshape(B, n, S // n, D).transpose(1, 0, 2, 3)
    yc = labels.reshape(B, n, S // n).transpose(1, 0, 2)

    def body(tot, inp):
        xi, yi = inp
        logits = common.unembed(params["embed"], cfg, xi).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return total / (B * S)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable[[Any], Any]
    train_loss: Callable[[Any, Any], Any]
    prefill: Callable[[Any, Any], Any]
    decode: Callable[[Any, Any, Any], Any]
    init_cache: Callable[[int, int], Any]
    input_specs: Callable[[ShapeCell], Any]


# ----------------------------------------------------------------------------
# decoder-only families (dense / moe / ssm / hybrid / vlm)


def _ctx_of(cfg, batch):
    if cfg.family == "vlm":
        return batch["vision_embeds"]
    return None


def _build_decoder_model(cfg: ModelConfig) -> Model:
    def init_params(key):
        return decoder.init_decoder(key, cfg)

    def train_loss(params, batch):
        x = common.embed(params["embed"], cfg, batch["tokens"])
        x, _ = decoder.decoder_stack(params, cfg, x, mode="train",
                                     ctx=_ctx_of(cfg, batch))
        return chunked_ce_loss(params, cfg, x, batch["labels"])

    def prefill(params, batch, absorb_mla=False):
        x = common.embed(params["embed"], cfg, batch["tokens"])
        x, caches = decoder.decoder_stack(params, cfg, x, mode="prefill",
                                          ctx=_ctx_of(cfg, batch),
                                          absorb_mla=absorb_mla)
        logits = common.unembed(params["embed"], cfg, x[:, -1:, :])
        return logits[:, 0, :], caches

    def decode(params, caches, batch, absorb_mla=False):
        x = common.embed(params["embed"], cfg, batch["token"])
        x, caches = decoder.decoder_stack(params, cfg, x, mode="decode",
                                          caches=caches, pos=batch["pos"],
                                          ctx=None, absorb_mla=absorb_mla)
        logits = common.unembed(params["embed"], cfg, x)
        return logits[:, 0, :], caches

    def init_cache(batch_size, buf_len, ctx_len=None):
        del ctx_len  # vlm ctx length is fixed by the vision stub
        n_ctx = cfg.vision.n_vision_tokens if cfg.vision else 0
        return decoder.init_decoder_cache(cfg, batch_size, buf_len, n_ctx)

    def input_specs(shape: ShapeCell):
        B, S = shape.global_batch, shape.seq_len
        dt = dtype_of(cfg)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            batch = {"tokens": tok, "labels": tok}
        elif shape.kind == "prefill":
            batch = {"tokens": tok}
        else:
            batch = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                     "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision.n_vision_tokens, cfg.d_model), dt)
        return batch

    return Model(cfg, init_params, train_loss, prefill, decode, init_cache,
                 input_specs)


# ----------------------------------------------------------------------------
# encoder-decoder family (seamless-m4t) — stubbed audio frontend


def _build_encdec_model(cfg: ModelConfig) -> Model:
    def init_params(key):
        k1, k2 = jax.random.split(key)
        params = decoder.init_decoder(k1, cfg)
        params["encoder"] = decoder.init_encoder(k2, cfg)
        return params

    def _encode(params, batch):
        return decoder.encoder_stack(params["encoder"], cfg,
                                     batch["audio_embeds"], remat=cfg.remat)

    def train_loss(params, batch):
        enc = _encode(params, batch)
        x = common.embed(params["embed"], cfg, batch["tokens"])
        x, _ = decoder.decoder_stack(params, cfg, x, mode="train", ctx=enc)
        return chunked_ce_loss(params, cfg, x, batch["labels"])

    def prefill(params, batch):
        enc = _encode(params, batch)
        x = common.embed(params["embed"], cfg, batch["tokens"])
        x, caches = decoder.decoder_stack(params, cfg, x, mode="prefill",
                                          ctx=enc)
        logits = common.unembed(params["embed"], cfg, x[:, -1:, :])
        return logits[:, 0, :], caches

    def decode(params, caches, batch):
        x = common.embed(params["embed"], cfg, batch["token"])
        x, caches = decoder.decoder_stack(params, cfg, x, mode="decode",
                                          caches=caches, pos=batch["pos"],
                                          ctx=None)
        logits = common.unembed(params["embed"], cfg, x)
        return logits[:, 0, :], caches

    def init_cache(batch_size, buf_len, ctx_len=None):
        # ctx_len = encoded source length (== buf_len in the shape cells)
        return decoder.init_decoder_cache(
            cfg, batch_size, buf_len,
            ctx_len=ctx_len if ctx_len is not None else buf_len)

    def input_specs(shape: ShapeCell):
        B, S = shape.global_batch, shape.seq_len
        dt = dtype_of(cfg)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        audio = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        if shape.kind == "train":
            return {"tokens": tok, "labels": tok, "audio_embeds": audio}
        if shape.kind == "prefill":
            return {"tokens": tok, "audio_embeds": audio}
        return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    return Model(cfg, init_params, train_loss, prefill, decode, init_cache,
                 input_specs)


def build_model(arch_or_cfg) -> Model:
    cfg = (arch_or_cfg if isinstance(arch_or_cfg, ModelConfig)
           else get_config(arch_or_cfg))
    if cfg.family == "audio":
        return _build_encdec_model(cfg)
    return _build_decoder_model(cfg)
