"""Shared model primitives: norms, RoPE, attention (all variants), MLPs.

All attention here is the pure-JAX (XLA) path used for the multi-device
dry-run and CPU smoke tests.  The TPU hot-path Pallas kernels in
``repro.kernels`` implement the same math (validated against ``kernels.ref``)
and are swapped in on real hardware via ``cfg.use_pallas`` at the ops layer.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ----------------------------------------------------------------------------
# initializers


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """LeCun-normal-ish init with fan-in on ``in_axis``."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ----------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def head_rms_norm(x, scale, eps=1e-6):
    """qk-norm: RMSNorm over the head_dim of [B, S, H, hd]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


# ----------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float, positions):
    """positions: [...]; returns cos/sin of shape [..., head_dim/2]."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [B, S, H, hd]; cos/sin: [B?, S, hd/2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # [S, hd/2] -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    elif cos.ndim == 3:  # [B, S, hd/2]
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------------
# attention (XLA paths)


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive mask bias [*, Sq, Sk] from query/key absolute positions."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def naive_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    k_valid=None):
    """Reference attention.  q: [B,Sq,H,hd], k/v: [B,Sk,K,hd] (GQA K|H).

    ``q_offset``: absolute position of q[0] (decode).  ``k_valid``: number of
    valid kv entries (decode with a partially filled cache).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    hd_v = v.shape[-1]  # may differ from hd (MLA)
    G = H // K
    q = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    bias = _mask_bias(q_pos, k_pos, causal, window)
    if k_valid is not None:
        bias = bias + jnp.where(k_pos[None, :] < k_valid, 0.0, -1e30)
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


def chunked_flash_attention(q, k, v, *, causal=True, window=None,
                            chunk=1024, q_offset=0, k_valid=None):
    """Online-softmax attention, scanning KV chunks — the XLA 'flash' path.

    Memory is O(Sq * chunk) instead of O(Sq * Sk); numerics match
    ``naive_attention`` to ~1e-3 in bf16 (f32 accumulation throughout).

    Head-major layout: GQA k/v chunks are repeated to the full H query
    heads *inside* the scan (cheap — one chunk at a time), so the score and
    accumulator tensors keep a contiguous H dimension that GSPMD shards
    over the ``model`` axis (inherited from the wq sharding).
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    if Sk % chunk != 0:
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, k_valid=k_valid)
    G = H // K
    qf = q.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Sq)

    n_chunks = Sk // chunk
    ks = k.reshape(B, n_chunks, chunk, K, k.shape[-1])
    vs = v.reshape(B, n_chunks, chunk, K, hd_v)

    def step(carry, inp):
        m, l, acc = carry
        idx, kc, vc = inp
        if G > 1:  # expand grouped kv heads to the full query-head axis
            kc = jnp.repeat(kc, G, axis=2)
            vc = jnp.repeat(vc, G, axis=2)
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bshd->bhqs", qf,
                       kc.astype(jnp.float32)) * scale
        ok = jnp.ones((Sq, chunk), dtype=bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            ok &= q_pos[:, None] - k_pos[None, :] < window
        if k_valid is not None:
            ok &= (k_pos < k_valid)[None, :]
        s = s + jnp.where(ok, 0.0, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd_v), jnp.float32)
    # remat the chunk body: scan-bwd then recomputes the [B,H,Sq,chunk]
    # score/prob intermediates instead of stacking them across chunks
    step = jax.checkpoint(step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), ks.transpose(1, 0, 2, 3, 4),
         vs.transpose(1, 0, 2, 3, 4)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(cfg: ModelConfig, q, k, v, *, causal=True, window=None,
              q_offset=0, k_valid=None):
    """Dispatch: chunked flash for long sequences, naive for short ones."""
    if k.shape[1] >= cfg.flash_threshold:
        return chunked_flash_attention(q, k, v, causal=causal, window=window,
                                       chunk=cfg.attn_chunk, q_offset=q_offset,
                                       k_valid=k_valid)
    return naive_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, k_valid=k_valid)


# ----------------------------------------------------------------------------
# gated MLPs


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "wg": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp(params, x, act: str):
    a = jnp.einsum("bsd,df->bsf", x, params["wi"])
    g = jnp.einsum("bsd,df->bsf", x, params["wg"])
    gate = jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)
    return jnp.einsum("bsf,fd->bsd", a * gate, params["wo"])


# ----------------------------------------------------------------------------
# embedding / head


def init_embedding(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "tok": embed_init(k1, (cfg.vocab, cfg.d_model), dtype=dtype),
        "head": dense_init(k2, (cfg.d_model, cfg.vocab), dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype=dtype),
    }


def embed(params, cfg: ModelConfig, tokens):
    x = params["tok"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["head"])


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def chunked_time_scan(step, init, xs, length: int, chunk: int = 64):
    """Two-level time scan for recurrences (RWKV/Mamba training).

    A flat ``lax.scan`` over S steps saves its carry (the recurrent state)
    at *every* step for the backward pass — O(S * state) memory, which is
    tens of GB for the 4k-token train cells.  Chunking saves the carry only
    at chunk boundaries (O(S/chunk * state)) and remats the inner scan, so
    the inner per-step residuals live only transiently during that chunk's
    backward.

    ``xs``: pytree of [S, ...] arrays scanned over the leading axis.
    Returns (final_carry, ys stacked to [S, ...]).
    """
    if length % chunk != 0 or length <= chunk:
        return jax.lax.scan(step, init, xs)
    n = length // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def inner(carry, xc):
        return jax.lax.scan(step, carry, xc)

    inner = jax.checkpoint(inner,
                           policy=jax.checkpoint_policies.nothing_saveable)
    carry, ys = jax.lax.scan(inner, init, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((length,) + a.shape[2:]), ys)
    return carry, ys
