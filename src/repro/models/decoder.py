"""Generic grouped decoder stack.

Layers are described by a per-layer ``LayerSpec``; consecutive identical
specs are stacked (leading ``n`` dim) and executed with ``lax.scan`` so the
HLO stays one-layer-sized regardless of depth (compile-time critical for the
512-device dry-run).  Heterogeneous stacks (VLM cross-attn every 5th layer,
Hymba's 3 global-attention layers) become multiple scan groups.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, layers
from repro.models.common import rms_norm

# kinds: dense | moe | rwkv | hymba | cross | encdec_dec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str
    window: Optional[int] = None
    mla: bool = False


@dataclasses.dataclass(frozen=True)
class Group:
    spec: LayerSpec
    n: int


def build_layout(cfg: ModelConfig) -> list[Group]:
    specs: list[LayerSpec] = []
    for i in range(cfg.n_layers):
        window = cfg.sliding_window
        if cfg.global_layers and i in cfg.global_layers:
            window = None
        if cfg.family == "ssm":
            specs.append(LayerSpec("rwkv"))
        elif cfg.family == "hybrid":
            specs.append(LayerSpec("hymba", window=window))
        elif cfg.family == "audio":
            specs.append(LayerSpec("encdec_dec"))
        elif cfg.family == "vlm" and cfg.vision and (
                i % cfg.vision.cross_attn_every == cfg.vision.cross_attn_every - 2):
            # cross layers at 3, 8, 13, ... for every=5
            specs.append(LayerSpec("cross"))
        elif cfg.moe is not None:
            specs.append(LayerSpec("moe", window=window, mla=cfg.mla is not None))
        else:
            specs.append(LayerSpec("dense", window=window))
    groups: list[Group] = []
    for s in specs:
        if groups and groups[-1].spec == s:
            groups[-1] = Group(s, groups[-1].n + 1)
        else:
            groups.append(Group(s, 1))
    return groups


# ----------------------------------------------------------------------------
# per-layer init / forward by kind


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p = {"ln1": jnp.zeros((D,), dtype=dtype)}
    if spec.kind == "rwkv":
        p.update(layers.init_rwkv_layer(ks[0], cfg, dtype))
        p["ln2"] = jnp.zeros((D,), dtype=dtype)
        return p
    if spec.kind == "hymba":
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
        p["mamba"] = layers.init_mamba(ks[1], cfg, dtype)
        p["norm_attn"] = jnp.zeros((D,), dtype=dtype)
        p["norm_ssm"] = jnp.zeros((D,), dtype=dtype)
        p["ln2"] = jnp.zeros((D,), dtype=dtype)
        p["mlp"] = common.init_mlp(ks[2], D, cfg.d_ff, dtype)
        return p
    if spec.kind == "cross":
        p["attn"] = layers.init_cross_attention(ks[0], cfg, dtype, gated=True)
        p["ln2"] = jnp.zeros((D,), dtype=dtype)
        p["mlp"] = common.init_mlp(ks[1], D, cfg.d_ff, dtype)
        return p
    if spec.kind == "encdec_dec":
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
        p["ln_cross"] = jnp.zeros((D,), dtype=dtype)
        p["cross"] = layers.init_cross_attention(ks[1], cfg, dtype, gated=False)
        p["ln2"] = jnp.zeros((D,), dtype=dtype)
        p["mlp"] = common.init_mlp(ks[2], D, cfg.d_ff, dtype)
        return p
    # dense / moe
    if spec.mla:
        p["attn"] = layers.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
    p["ln2"] = jnp.zeros((D,), dtype=dtype)
    if spec.kind == "moe":
        p["moe"] = layers.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = common.init_mlp(ks[1], D, cfg.d_ff, dtype)
    return p


def _init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch, buf_len,
                      ctx_len, dtype):
    buf = min(buf_len, spec.window) if spec.window else buf_len
    if spec.kind == "rwkv":
        return layers.init_rwkv_cache(cfg, batch, dtype)
    if spec.kind == "hymba":
        return {"attn": layers.init_attn_cache(cfg, batch, buf, dtype),
                "mamba": layers.init_mamba_cache(cfg, batch, dtype)}
    if spec.kind == "cross":
        K, hd = cfg.n_kv_heads, cfg.head_dim
        return {"ck": jnp.zeros((batch, ctx_len, K, hd), dtype=dtype),
                "cv": jnp.zeros((batch, ctx_len, K, hd), dtype=dtype)}
    if spec.kind == "encdec_dec":
        K, hd = cfg.n_kv_heads, cfg.head_dim
        return {"attn": layers.init_attn_cache(cfg, batch, buf, dtype),
                "cross": {"ck": jnp.zeros((batch, ctx_len, K, hd), dtype=dtype),
                          "cv": jnp.zeros((batch, ctx_len, K, hd), dtype=dtype)}}
    if spec.mla:
        return layers.init_mla_cache(cfg, batch, buf, dtype)
    return layers.init_attn_cache(cfg, batch, buf, dtype)


def _layer_forward(p, cfg: ModelConfig, spec: LayerSpec, x, *, mode, cache,
                   pos, ctx, absorb_mla=False):
    cache = cache or {}
    if spec.kind == "rwkv":
        h, tm_cache = layers.rwkv_time_mix(
            p, cfg, rms_norm(x, p["ln1"], cfg.norm_eps), mode=mode,
            cache=cache)
        x = x + h
        h, cm_shift = layers.rwkv_channel_mix(
            p, cfg, rms_norm(x, p["ln2"], cfg.norm_eps), mode=mode,
            cache=cache.get("cm_shift"))
        x = x + h
        new_cache = None
        if mode != "train":
            new_cache = dict(tm_cache, cm_shift=cm_shift)
        return x, new_cache

    if spec.kind == "hymba":
        xin = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, a_cache = layers.attn_sublayer(
            p["attn"], cfg, xin, mode=mode, cache=cache.get("attn"),
            pos=pos, window=spec.window)
        s, s_cache = layers.mamba_branch(
            p["mamba"], cfg, xin, mode=mode, cache=cache.get("mamba"))
        h = 0.5 * (rms_norm(a, p["norm_attn"], cfg.norm_eps)
                   + rms_norm(s, p["norm_ssm"], cfg.norm_eps))
        x = x + h
        h = common.mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        x = x + h
        new_cache = None
        if mode != "train":
            new_cache = {"attn": a_cache, "mamba": s_cache}
        return x, new_cache

    if spec.kind == "cross":
        h, c_cache = layers.cross_sublayer(
            p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), mode=mode,
            cache=cache or None, ctx=ctx)
        x = x + jnp.tanh(p["attn"]["gate_attn"]) * h
        h = common.mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        x = x + jnp.tanh(p["attn"]["gate_ffn"]) * h
        return x, (c_cache if mode != "train" else None)

    if spec.kind == "encdec_dec":
        h, a_cache = layers.attn_sublayer(
            p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), mode=mode,
            cache=cache.get("attn"), pos=pos, window=None)
        x = x + h
        h, c_cache = layers.cross_sublayer(
            p["cross"], cfg, rms_norm(x, p["ln_cross"], cfg.norm_eps),
            mode=mode, cache=cache.get("cross"), ctx=ctx)
        x = x + h
        h = common.mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
        x = x + h
        new_cache = None
        if mode != "train":
            new_cache = {"attn": a_cache, "cross": c_cache}
        return x, new_cache

    # dense / moe
    xin = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mla:
        h, a_cache = layers.mla_sublayer(p["attn"], cfg, xin, mode=mode,
                                         cache=cache or None, pos=pos,
                                         absorb=absorb_mla)
    else:
        h, a_cache = layers.attn_sublayer(p["attn"], cfg, xin, mode=mode,
                                          cache=cache or None, pos=pos,
                                          window=spec.window)
    x = x + h
    xin = rms_norm(x, p["ln2"], cfg.norm_eps)
    if spec.kind == "moe":
        h = layers.moe_ffn(p["moe"], cfg, xin)
    else:
        h = common.mlp(p["mlp"], xin, cfg.act)
    x = x + h
    return x, (a_cache if mode != "train" else None)


# ----------------------------------------------------------------------------
# decoder-level init / forward


def init_decoder(key, cfg: ModelConfig):
    dtype = common.dtype_of(cfg)
    groups = build_layout(cfg)
    k_embed, *gkeys = jax.random.split(key, len(groups) + 1)
    params = {"embed": common.init_embedding(k_embed, cfg, dtype),
              "groups": []}
    for g, gk in zip(groups, gkeys):
        lks = jax.random.split(gk, g.n)
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_init_layer(lks[i], cfg, g.spec, dtype) for i in range(g.n)])
        params["groups"].append(stacked)
    return params


def init_decoder_cache(cfg: ModelConfig, batch, buf_len, ctx_len=0):
    dtype = common.dtype_of(cfg)
    groups = build_layout(cfg)
    caches = []
    for g in groups:
        one = _init_layer_cache(cfg, g.spec, batch, buf_len, ctx_len, dtype)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g.n,) + x.shape), one))
    return caches


def decoder_stack(params, cfg: ModelConfig, x, *, mode, caches=None, pos=None,
                  ctx=None, absorb_mla=False):
    """Run all layer groups.  x: [B, S, D] -> ([B, S, D], new_caches)."""
    from repro.distributed.sharding import constrain_seq
    groups = build_layout(cfg)
    caches = caches if caches is not None else [None] * len(groups)
    new_caches = []
    for g, gparams, gcache in zip(groups, params["groups"], caches):
        def body(xc, layer_in, _spec=g.spec):
            lp, lcache = layer_in
            if mode != "decode":
                # sequence-parallel residual stream (no-op off-mesh)
                xc = constrain_seq(xc)
            y, new_c = _layer_forward(lp, cfg, _spec, xc, mode=mode,
                                      cache=lcache, pos=pos, ctx=ctx,
                                      absorb_mla=absorb_mla)
            return y, new_c

        if mode == "train" and cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        if mode == "train":
            x, _ = jax.lax.scan(
                lambda xc, lp: (body(xc, (lp, None))[0], None), x, gparams)
            new_caches.append(None)
        elif gcache is None:  # prefill: caches are produced, not consumed
            x, new_c = jax.lax.scan(
                lambda xc, lp: body(xc, (lp, None)), x, gparams)
            new_caches.append(new_c)
        else:  # decode: caches are consumed and re-emitted
            x, new_c = jax.lax.scan(body, x, (gparams, gcache))
            new_caches.append(new_c)
    return x, new_caches


# ----------------------------------------------------------------------------
# encoder stack (seamless-m4t) — bidirectional, scannable, no cache


def init_encoder(key, cfg: ModelConfig):
    dtype = common.dtype_of(cfg)
    n = cfg.encdec.n_enc_layers
    lks = jax.random.split(key, n)
    spec = LayerSpec("dense")

    def one(k):
        p = _init_layer(k, cfg, spec, dtype)
        return p

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[one(lks[i]) for i in range(n)])
    return {"layers": stacked,
            "final_norm": jnp.zeros((cfg.d_model,), dtype=dtype)}


def encoder_stack(params, cfg: ModelConfig, x, *, remat=False):
    """Bidirectional encoder over stubbed frame embeddings [B, S, D].

    ``attn_sublayer`` is causal, so a non-causal variant is inlined here.
    """

    def body2(xc, lp):
        xin = rms_norm(xc, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xin, lp["attn"]["wv"])
        positions = jnp.arange(x.shape[1])
        cos, sin = rope_freqs_cached(cfg, positions)
        q = common.apply_rope(q, cos, sin)
        k = common.apply_rope(k, cos, sin)
        out = common.attention(cfg, q, k, v, causal=False)
        xc = xc + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        xin = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + common.mlp(lp["mlp"], xin, cfg.act)
        return xc, None

    if remat:
        body2 = jax.checkpoint(
            body2, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body2, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def rope_freqs_cached(cfg, positions):
    return common.rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
