"""Layer types for every assigned architecture family.

Uniform sublayer interface so the generic decoder can scan stacked layers:

    init_<kind>_layer(key, cfg) -> params (single layer)
    <kind>_layer(params, cfg, x, *, mode, cache, pos, ctx) -> (y, new_cache)

``mode``  : "train" | "prefill" | "decode"
``cache`` : per-layer cache pytree (None in train mode)
``pos``   : scalar int32 — absolute position of the incoming token (decode)
``ctx``   : encoder/vision context [B, S_ctx, D] for cross-attention layers
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import (apply_rope, attention, dense_init,
                                 head_rms_norm, rms_norm, rope_freqs)

# When True, decode-cache writes use a one-hot masked update instead of
# dynamic_update_slice.  DUS at a dynamic index on a sequence-sharded cache
# makes GSPMD gather the full cache per layer ("involuntary full remat");
# the one-hot formulation is purely elementwise and stays shard-local.
# (§Perf optimization — the paper-faithful baseline uses DUS.)
ONEHOT_CACHE_UPDATE = False

# When True, full-cache decode attention runs as an explicit shard_map
# flash-decode (local online-softmax stats merged with pmax/psum) instead
# of letting GSPMD all-gather the sequence-sharded cache (§Perf).
SHARDED_DECODE_ATTN = False


def _cache_write(buf, update, idx):
    """Write ``update`` [B, 1, ...] into ``buf`` [B, S, ...] at ``idx``."""
    if not ONEHOT_CACHE_UPDATE:
        return jax.lax.dynamic_update_slice_in_dim(buf, update, idx, axis=1)
    S = buf.shape[1]
    onehot = (jnp.arange(S, dtype=jnp.int32) == idx).astype(buf.dtype)
    shape = (1, S) + (1,) * (buf.ndim - 2)
    onehot = onehot.reshape(shape)
    return buf * (1 - onehot) + update.astype(buf.dtype) * onehot


# =============================================================================
# GQA self-attention sublayer (dense / moe / vlm-self / hymba-attn-branch)
# =============================================================================


def init_attention(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    H, K, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": dense_init(ks[0], (D, H, hd), in_axis=0, dtype=dtype),
        "wk": dense_init(ks[1], (D, K, hd), in_axis=0, dtype=dtype),
        "wv": dense_init(ks[2], (D, K, hd), in_axis=0, dtype=dtype),
        "wo": dense_init(ks[3], (H, hd, D), in_axis=-1, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype=dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype=dtype)
    return p


def init_attn_cache(cfg: ModelConfig, batch, buf_len, dtype):
    K, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, buf_len, K, hd), dtype=dtype),
        "v": jnp.zeros((batch, buf_len, K, hd), dtype=dtype),
    }


def attn_sublayer(p, cfg: ModelConfig, x, *, mode, cache, pos, window):
    """x: [B, S, D].  Ring-buffer cache when ``window`` is set."""
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)

    if mode == "decode":
        positions = jnp.full((S,), 0, jnp.int32) + pos  # S == 1
    else:
        positions = jnp.arange(S)
    cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if mode == "train":
        out = attention(cfg, q, k, v, causal=True, window=window)
        new_cache = None
    elif mode == "prefill":
        out = attention(cfg, q, k, v, causal=True, window=window)
        if window is not None:
            # ring buffer holding the last `window` tokens (S % window == 0
            # guaranteed by the shape cells; see DESIGN.md)
            new_cache = {"k": k[:, -window:], "v": v[:, -window:]}
        else:
            new_cache = {"k": k, "v": v}
    else:  # decode: write one token, attend over the cache
        buf = cache["k"].shape[1]
        if window is not None:
            idx = jax.lax.rem(pos, jnp.int32(window))
        else:
            idx = pos
        ck = _cache_write(cache["k"], k, idx)
        cv = _cache_write(cache["v"], v, idx)
        new_cache = {"k": ck, "v": cv}
        if window is not None:
            # every slot in the ring is within the window once warm; a
            # validity bound covers the cold start.
            k_valid = jnp.minimum(pos + 1, buf)
            out = attention(cfg, q, ck, cv, causal=False, window=None,
                            k_valid=k_valid)
        else:
            out = None
            if SHARDED_DECODE_ATTN:
                from repro.distributed.sharding import \
                    sharded_decode_attention
                out = sharded_decode_attention(q, ck, cv, pos + 1)
            if out is None:
                out = attention(cfg, q, ck, cv, causal=False, window=None,
                                k_valid=pos + 1)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# =============================================================================
# Cross-attention sublayer (VLM image layers, enc-dec decoder)
# =============================================================================


def init_cross_attention(key, cfg: ModelConfig, dtype, gated: bool):
    p = init_attention(key, cfg, dtype)
    if gated:  # llama-3.2-vision style tanh gates
        p["gate_attn"] = jnp.zeros((), dtype=dtype)
        p["gate_ffn"] = jnp.zeros((), dtype=dtype)
    return p


def cross_sublayer(p, cfg: ModelConfig, x, *, mode, cache, ctx):
    """Cross-attn: queries from x, keys/values from ctx (cached after first
    computation — ctx is static across decode steps)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
    if mode == "decode" and cache is not None:
        ck, cv = cache["ck"], cache["cv"]
        new_cache = cache
    else:
        ck = jnp.einsum("bsd,dhk->bshk", ctx, p["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", ctx, p["wv"])
        if cfg.qk_norm:
            ck = head_rms_norm(ck, p["k_norm"], cfg.norm_eps)
        new_cache = {"ck": ck, "cv": cv} if mode != "train" else None
    out = attention(cfg, q, ck, cv, causal=False, window=None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# =============================================================================
# MLA — multi-head latent attention (deepseek-v2)
# =============================================================================


def init_mla(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    ks = jax.random.split(key, 6)
    D, H = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (D, m.q_lora_rank), in_axis=0, dtype=dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype=dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, H, qk_hd), in_axis=0,
                           dtype=dtype),
        "wkv_a": dense_init(ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim),
                            in_axis=0, dtype=dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype=dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                           in_axis=0, dtype=dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                           in_axis=0, dtype=dtype),
        "wo": dense_init(ks[5], (H, m.v_head_dim, D), in_axis=-1, dtype=dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch, buf_len, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, buf_len, m.kv_lora_rank), dtype=dtype),
        "krope": jnp.zeros((batch, buf_len, m.qk_rope_head_dim), dtype=dtype),
    }


def mla_sublayer(p, cfg: ModelConfig, x, *, mode, cache, pos,
                 absorb: bool = False):
    """MLA with a compressed latent cache.

    ``absorb=False`` (paper-faithful baseline): decode re-expands k/v from the
    latent via wk_b/wv_b each step.  ``absorb=True`` (§Perf optimization):
    wk_b is absorbed into the query and wv_b into the output projection so
    decode attends directly in the rank-512 latent space.
    """
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = rms_norm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:]

    if mode == "decode":
        positions = jnp.zeros((S,), jnp.int32) + pos
    else:
        positions = jnp.arange(S)
    cos, sin = rope_freqs(rope_d, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]

    if mode == "decode":
        ckv_full = _cache_write(cache["ckv"], ckv, pos)
        krope_full = _cache_write(cache["krope"], k_rope, pos)
        new_cache = {"ckv": ckv_full, "krope": krope_full}
        k_valid = pos + 1
        causal = False
    else:
        ckv_full, krope_full = ckv, k_rope
        new_cache = ({"ckv": ckv, "krope": k_rope}
                     if mode == "prefill" else None)
        k_valid = None
        causal = True

    if absorb and mode == "decode":
        # fold wk_b into q: q_lat [B,1,H,R]; attend in latent space.
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
        q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [ckv_full, krope_full], axis=-1)[:, :, None, :]  # MQA: 1 kv head
        out_lat = None
        if SHARDED_DECODE_ATTN:
            from repro.distributed.sharding import sharded_decode_attention
            out_lat = sharded_decode_attention(
                q_cat, k_cat, ckv_full[:, :, None, :], k_valid)
        if out_lat is None:
            out_lat = attention(cfg, q_cat, k_cat, ckv_full[:, :, None, :],
                                causal=False, k_valid=k_valid)
        # out in latent space -> expand through wv_b folded with wo
        y = jnp.einsum("bshr,rhv,hvd->bsd", out_lat, p["wv_b"], p["wo"])
        return y, new_cache

    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_full, p["wk_b"])
    v = jnp.einsum("bsr,rhv->bshv", ckv_full, p["wv_b"])
    k_rope_h = jnp.broadcast_to(krope_full[:, :, None, :],
                                k_nope.shape[:3] + (rope_d,))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = attention(cfg, q_cat, k, v, causal=causal, k_valid=k_valid)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache


# =============================================================================
# MoE FFN — GShard-style capacity dispatch, chunked over tokens
# =============================================================================

MOE_CHUNK = 256  # tokens per dispatch group (baseline; §Perf iterates on this)


def init_moe(key, cfg: ModelConfig, dtype):
    e = cfg.moe
    ff = e.d_ff_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    D = cfg.d_model
    p = {
        "router": dense_init(ks[0], (D, e.n_experts), in_axis=0,
                             dtype=jnp.float32),
        "wi": dense_init(ks[1], (e.n_experts, D, ff), in_axis=1, dtype=dtype),
        "wg": dense_init(ks[2], (e.n_experts, D, ff), in_axis=1, dtype=dtype),
        "wo": dense_init(ks[3], (e.n_experts, ff, D), in_axis=-1, dtype=dtype),
    }
    if e.n_shared:
        p["shared"] = common.init_mlp(ks[4], D, e.n_shared * ff, dtype)
    return p


def _route(p, cfg: ModelConfig, xf):
    """xf: [T, D] -> (gates [T, E] f32 with zeros off top-k, mask [T, E])."""
    e = cfg.moe
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, e.top_k)
    mask = jax.nn.one_hot(top_idx, e.n_experts, dtype=jnp.float32).sum(axis=1)
    gates = probs * mask
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, mask


def _route_grouped(p, cfg: ModelConfig, xg):
    """xg: [B, G, T, D] -> (gates, mask) [B, G, T, E] f32."""
    e = cfg.moe
    logits = jnp.einsum("bgtd,de->bgte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, e.top_k)
    mask = jax.nn.one_hot(top_idx, e.n_experts, dtype=jnp.float32).sum(axis=-2)
    gates = probs * mask
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, mask


def moe_ffn(p, cfg: ModelConfig, x):
    """GShard grouped capacity dispatch (Mesh-TF/GSPMD formulation).

    Tokens keep their [B, S] layout: the sequence is split into groups of
    MOE_CHUNK tokens and each group dispatches into per-expert capacity
    buffers via dense one-hot einsums.  The group structure (instead of a
    flat token lax.map) is what keeps every tensor shardable: batch stays
    on the DP axes and the expert axis is constrained onto 'model'.
    """
    from repro.distributed.sharding import (constrain_moe_expert,
                                            constrain_moe_groups)
    e = cfg.moe
    B, S, D = x.shape
    g = min(MOE_CHUNK, S)
    if S % g:
        g = S
    G = S // g
    capacity = max(e.top_k, int(g / e.n_experts * e.top_k
                                * e.capacity_factor))
    xg = constrain_moe_groups(x.reshape(B, G, g, D))
    gates, mask = _route_grouped(p, cfg, xg)
    # position of each token within its expert's capacity buffer (per group)
    pos_in_exp = jnp.cumsum(mask, axis=2) - 1.0
    keep = mask * (pos_in_exp < capacity)
    dispatch = keep[..., None] * jax.nn.one_hot(
        pos_in_exp.astype(jnp.int32), capacity,
        dtype=jnp.float32)                                # [B,G,T,E,C]
    combine = dispatch * gates[..., None]
    dt = x.dtype
    # dispatch: tokens leave their (group-sharded) layout for the
    # expert-sharded layout -> all-to-all over 'model' (classic MoE EP)
    exp_in = constrain_moe_expert(
        jnp.einsum("bgtec,bgtd->bgecd", dispatch.astype(dt), xg))
    a = jnp.einsum("bgecd,edf->bgecf", exp_in, p["wi"])
    h = jnp.einsum("bgecd,edf->bgecf", exp_in, p["wg"])
    act = jax.nn.gelu(h) if cfg.act == "gelu" else jax.nn.silu(h)
    exp_out = constrain_moe_expert(
        jnp.einsum("bgecf,efd->bgecd", a * act, p["wo"]))
    out = jnp.einsum("bgtec,bgecd->bgtd", combine.astype(dt), exp_out)
    out = constrain_moe_groups(out.reshape(B, G, g, D)).reshape(B, S, D)
    if e.n_shared:
        out = out + common.mlp(p["shared"], x, cfg.act)
    return out


# =============================================================================
# RWKV6 (Finch) — time-mix with data-dependent decay + channel-mix
# =============================================================================


def init_rwkv_layer(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    D, R = cfg.d_model, s.lora_rank
    H = D // s.rwkv_head_dim
    ks = jax.random.split(key, 16)
    p = {"tm": {}, "cm": {}}
    tm = p["tm"]
    for i, nm in enumerate(["x", "r", "k", "v", "w", "g"]):
        tm[f"mu_{nm}"] = jnp.zeros((D,), dtype=dtype)
    for i, nm in enumerate(["r", "k", "v", "w", "g"]):
        tm[f"lora_{nm}_a"] = dense_init(ks[i], (D, R), in_axis=0, dtype=dtype)
        tm[f"lora_{nm}_b"] = (jnp.zeros((R, D), dtype=dtype))
    tm["w0"] = jnp.full((D,), -1.0, dtype=dtype)  # decay base
    tm["u"] = dense_init(ks[10], (D,), dtype=dtype)  # per-channel bonus
    tm["wr"] = dense_init(ks[11], (D, D), in_axis=0, dtype=dtype)
    tm["wk"] = dense_init(ks[12], (D, D), in_axis=0, dtype=dtype)
    tm["wv"] = dense_init(ks[13], (D, D), in_axis=0, dtype=dtype)
    tm["wg"] = dense_init(ks[14], (D, D), in_axis=0, dtype=dtype)
    tm["wo"] = dense_init(ks[15], (D, D), in_axis=0, dtype=dtype)
    tm["ln_x"] = jnp.zeros((D,), dtype=dtype)
    k2 = jax.random.split(ks[0], 4)
    cm = p["cm"]
    cm["mu_k"] = jnp.zeros((D,), dtype=dtype)
    cm["mu_r"] = jnp.zeros((D,), dtype=dtype)
    cm["wk"] = dense_init(k2[0], (D, cfg.d_ff), in_axis=0, dtype=dtype)
    cm["wv"] = dense_init(k2[1], (cfg.d_ff, D), in_axis=0, dtype=dtype)
    cm["wr"] = dense_init(k2[2], (D, D), in_axis=0, dtype=dtype)
    return p


def init_rwkv_cache(cfg: ModelConfig, batch, dtype):
    D = cfg.d_model
    hd = cfg.ssm.rwkv_head_dim
    H = D // hd
    return {
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "tm_shift": jnp.zeros((batch, D), dtype=dtype),
        "cm_shift": jnp.zeros((batch, D), dtype=dtype),
    }


def _rwkv_mix(tm, x, x_prev):
    """ddlerp token mixing. x, x_prev: [B, S, D] (x_prev = token-shifted x)."""
    dx = x_prev - x
    xx = x + dx * tm["mu_x"]
    outs = {}
    for nm in ["r", "k", "v", "w", "g"]:
        lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xx, tm[f"lora_{nm}_a"]))
        lo = jnp.einsum("bsr,rd->bsd", lo, tm[f"lora_{nm}_b"])
        outs[nm] = x + dx * (tm[f"mu_{nm}"] + lo)
    return outs


def rwkv_time_mix(p, cfg: ModelConfig, x, *, mode, cache):
    """RWKV6 WKV recurrence.  Sequential lax.scan over time (the Pallas
    ``rwkv_scan`` kernel implements the chunked TPU version of this math)."""
    tm = p["tm"]
    B, S, D = x.shape
    hd = cfg.ssm.rwkv_head_dim
    H = D // hd

    if mode == "decode":
        x_prev = cache["tm_shift"][:, None, :]
    else:
        x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    m = _rwkv_mix(tm, x, x_prev)

    r = jnp.einsum("bsd,de->bse", m["r"], tm["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", m["k"], tm["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", m["v"], tm["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", m["g"], tm["wg"]))
    # data-dependent decay w_t in (0, 1), computed in f32 for stability
    w = jnp.exp(-jnp.exp((tm["w0"] + m["w"]).astype(jnp.float32)))
    w = w.reshape(B, S, H, hd)
    u = tm["u"].reshape(H, hd).astype(jnp.float32)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    state0 = (cache["state"] if mode == "decode"
              else jnp.zeros((B, H, hd, hd), jnp.float32))

    def step(state, inp):
        rt, kt, vt, wt = inp  # [B, H, hd]
        kv = kt[..., :, None] * vt[..., None, :]        # [B, H, hdk, hdv]
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, out

    xs = (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state_end, outs = common.chunked_time_scan(step, state0, xs, S)
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, D)

    # per-head group-norm, then gate and output-project
    out = out.reshape(B, S, H, hd)
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 64e-5)
    out = out.reshape(B, S, D) * (1.0 + p["tm"]["ln_x"].astype(jnp.float32))
    out = (out.astype(x.dtype) * g.astype(x.dtype))
    y = jnp.einsum("bsd,de->bse", out, tm["wo"])

    new_cache = None
    if mode != "train":
        new_cache = {"state": state_end, "tm_shift": x[:, -1, :]}
    return y, new_cache


def rwkv_channel_mix(p, cfg: ModelConfig, x, *, mode, cache):
    cm = p["cm"]
    if mode == "decode":
        x_prev = cache[:, None, :]
    else:
        x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    dx = x_prev - x
    xk = x + dx * cm["mu_k"]
    xr = x + dx * cm["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, cm["wk"])
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("bsf,fd->bsd", k, cm["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, cm["wr"]))
    y = r * v
    new_shift = x[:, -1, :] if mode != "train" else None
    return y, new_shift


# =============================================================================
# Mamba selective-SSM branch (hymba hybrid heads)
# =============================================================================


def init_mamba(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner_mult * D
    dt_rank = s.dt_rank or max(1, -(-D // 16))
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di), in_axis=0, dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, di), in_axis=0, dtype=dtype),
        "x_proj": dense_init(ks[2], (di, dt_rank + 2 * s.state_dim),
                             in_axis=0, dtype=dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, di), in_axis=0, dtype=dtype),
        "dt_bias": jnp.full((di,), -4.0, dtype=dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (di, s.state_dim))
        ).astype(jnp.float32),
        "Dskip": jnp.ones((di,), dtype=dtype),
        "out_proj": dense_init(ks[4], (di, D), in_axis=0, dtype=dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch, dtype):
    s = cfg.ssm
    di = s.d_inner_mult * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), dtype=dtype),
        "ssm": jnp.zeros((batch, di, s.state_dim), jnp.float32),
    }


def mamba_branch(p, cfg: ModelConfig, x, *, mode, cache):
    """Selective scan.  x: [B, S, D] -> [B, S, D]."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner_mult * D
    N = s.state_dim

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]

    # causal depthwise conv, width d_conv
    if mode == "decode":
        hist = jnp.concatenate([cache["conv"], xi], axis=1)  # [B, d_conv, di]
        conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"])[:, None, :]
        new_conv = hist[:, 1:, :]
    else:
        pad = jnp.zeros((B, s.d_conv - 1, di), xi.dtype)
        hist = jnp.concatenate([pad, xi], axis=1)
        conv_out = sum(
            hist[:, i:i + S, :] * p["conv_w"][i][None, None, :]
            for i in range(s.d_conv))
        new_conv = hist[:, S:, :] if mode == "prefill" else None
    xc = jax.nn.silu(conv_out)

    proj = jnp.einsum("bsc,ce->bse", xc, p["x_proj"])
    dt_rank = p["dt_proj"].shape[0]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", proj[..., :dt_rank], p["dt_proj"])
        + p["dt_bias"]).astype(jnp.float32)                  # [B,S,di]
    Bt = proj[..., dt_rank:dt_rank + N].astype(jnp.float32)  # [B,S,N]
    Ct = proj[..., dt_rank + N:].astype(jnp.float32)         # [B,S,N]
    A = -jnp.exp(p["A_log"])                                 # [di,N]
    xcf = xc.astype(jnp.float32)

    h0 = (cache["ssm"] if mode == "decode"
          else jnp.zeros((B, di, N), jnp.float32))

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp  # [B,di],[B,N],[B,N],[B,di]
        dA = jnp.exp(dt_t[..., None] * A[None])              # [B,di,N]
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bcn,bn->bc", h, C_t)
        return h, y

    xs = (dt.transpose(1, 0, 2), Bt.transpose(1, 0, 2),
          Ct.transpose(1, 0, 2), xcf.transpose(1, 0, 2))
    h_end, ys = common.chunked_time_scan(step, h0, xs, S)
    y = ys.transpose(1, 0, 2)                                # [B,S,di]
    y = y + xcf * p["Dskip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"])

    new_cache = None
    if mode != "train":
        new_cache = {"conv": new_conv if new_conv is not None
                     else cache["conv"], "ssm": h_end}
    return out, new_cache
