"""SynergAI Eq. 2-4 scoring Pallas TPU kernel.

At fleet scale (thousands of queued jobs x hundreds of worker pools) the
scheduler's scoring step is itself a dense [J, W] compute:

    T_est[j, w]   = preproc[j, w] + q[j] / qps[j, w]          (Eq. 2)
    acceptable    = T_rem[j] >= T_est[j, w]                   (Eq. 3)
    best[j]       = argmin_w T_est[j, w] over acceptable      (Eq. 4)
    urgency[j]    = T_rem[j] - min_w T_est[j, w]

Grid walks J-blocks with the full worker axis resident in VMEM; infeasible
(j, w) pairs carry qps <= 0 and are excluded via masking.  Validated against
``repro.core.estimator.estimate_matrix`` (the numpy oracle) in the tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _score_kernel(qps_ref, pre_ref, q_ref, rem_ref,
                  est_ref, best_ref, urg_ref, acc_ref):
    qps = qps_ref[...]                  # [BJ, W]
    pre = pre_ref[...]
    q = q_ref[...]                      # [BJ, 1]
    rem = rem_ref[...]                  # [BJ, 1]

    feas = qps > 0.0
    est = jnp.where(feas, pre + q / jnp.where(feas, qps, 1.0), BIG)
    acc = feas & (rem >= est)
    est_masked = jnp.where(acc, est, BIG)
    # argmin over acceptable; fall back to argmin over feasible
    any_acc = jnp.any(acc, axis=1, keepdims=True)
    pick_from = jnp.where(any_acc, est_masked, est)
    best = jnp.argmin(pick_from, axis=1)
    any_feas = jnp.any(feas, axis=1)
    best = jnp.where(any_feas, best, -1)
    urgency = rem[:, 0] - jnp.min(est, axis=1)

    est_ref[...] = est
    best_ref[...] = best.astype(jnp.int32)
    urg_ref[...] = urgency
    acc_ref[...] = acc.astype(jnp.int8)


def scheduler_score(qps, preproc, queries, t_remaining, *, bj=128,
                    interpret=False):
    """qps, preproc: [J, W] f32 (qps <= 0 marks infeasible); queries,
    t_remaining: [J] f32.  Returns (t_est [J,W], best [J], urgency [J],
    acceptable [J,W] int8)."""
    J, W = qps.shape
    bj = min(bj, J)
    pad = (-J) % bj
    if pad:
        z = lambda a, fill: jnp.pad(a, [(0, pad)] + [(0, 0)] *
                                    (a.ndim - 1), constant_values=fill)
        qps, preproc = z(qps, 0.0), z(preproc, 0.0)
        queries, t_remaining = z(queries, 1.0), z(t_remaining, -1.0)
        J = J + pad
    out = pl.pallas_call(
        _score_kernel,
        grid=(J // bj,),
        in_specs=[
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj,), lambda i: (i,)),
            pl.BlockSpec((bj,), lambda i: (i,)),
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((J, W), jnp.float32),
            jax.ShapeDtypeStruct((J,), jnp.int32),
            jax.ShapeDtypeStruct((J,), jnp.float32),
            jax.ShapeDtypeStruct((J, W), jnp.int8),
        ],
        interpret=interpret,
    )(qps.astype(jnp.float32), preproc.astype(jnp.float32),
      queries.astype(jnp.float32)[:, None],
      t_remaining.astype(jnp.float32)[:, None])
    est, best, urg, acc = out
    n = J - pad
    return est[:n], best[:n], urg[:n], acc[:n]
