"""SynergAI Eq. 2-4 scoring Pallas TPU kernels (v1 + fused v2).

At fleet scale (thousands of queued jobs x hundreds of worker pools) the
scheduler's scoring step is itself a dense [J, W] compute:

    T_est[j, w]   = preproc[j, w] + q[j] / qps[j, w]          (Eq. 2)
    acceptable    = T_rem[j] >= T_est[j, w]                   (Eq. 3)
    best[j]       = argmin_w T_est[j, w] over acceptable      (Eq. 4)
    urgency[j]    = T_rem[j] - min_w T_est[j, w]

Grid walks J-blocks with the full worker axis resident in VMEM; infeasible
(j, w) pairs carry qps <= 0 and are excluded via masking.  Validated against
``repro.core.estimator.estimate_matrix`` (the numpy oracle) in the tests.

``scheduler_score_v2`` fuses the whole batched-serving scoring pipeline —
the queue-depth penalty (``1 + alpha * b`` per worker), the
prefill/decode phase slicing of disaggregated pools, and the TTFT/TPOT
streaming-deadline gates — into a single pass, so the Pallas path covers
``serving="batched"`` + streaming scoring end-to-end instead of falling
back to numpy post-processing.  It consumes the *precomputed* solo
matrices (full service, prefill prefix, decode remainder, ``inf`` marking
infeasible pairs — exactly what ``repro.core.scorecache`` persists across
ticks) and emits the effective times, the gated acceptability, the
TTFT-tightened urgency, and doom.  The numpy oracle is the batched +
streaming block of ``repro.core.scheduler.SynergAI``; parity, including
the padding edges, is pinned in ``tests/test_pallas_parity.py``."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _score_kernel(qps_ref, pre_ref, q_ref, rem_ref,
                  est_ref, best_ref, urg_ref, acc_ref):
    qps = qps_ref[...]                  # [BJ, W]
    pre = pre_ref[...]
    q = q_ref[...]                      # [BJ, 1]
    rem = rem_ref[...]                  # [BJ, 1]

    feas = qps > 0.0
    est = jnp.where(feas, pre + q / jnp.where(feas, qps, 1.0), BIG)
    acc = feas & (rem >= est)
    est_masked = jnp.where(acc, est, BIG)
    # argmin over acceptable; fall back to argmin over feasible
    any_acc = jnp.any(acc, axis=1, keepdims=True)
    pick_from = jnp.where(any_acc, est_masked, est)
    best = jnp.argmin(pick_from, axis=1)
    any_feas = jnp.any(feas, axis=1)
    best = jnp.where(any_feas, best, -1)
    urgency = rem[:, 0] - jnp.min(est, axis=1)

    est_ref[...] = est
    best_ref[...] = best.astype(jnp.int32)
    urg_ref[...] = urgency
    acc_ref[...] = acc.astype(jnp.int8)


def scheduler_score(qps, preproc, queries, t_remaining, *, bj=128,
                    interpret=False):
    """qps, preproc: [J, W] f32 (qps <= 0 marks infeasible); queries,
    t_remaining: [J] f32.  Returns (t_est [J,W], best [J], urgency [J],
    acceptable [J,W] int8)."""
    J, W = qps.shape
    bj = min(bj, J)
    pad = (-J) % bj
    if pad:
        z = lambda a, fill: jnp.pad(a, [(0, pad)] + [(0, 0)] *
                                    (a.ndim - 1), constant_values=fill)
        qps, preproc = z(qps, 0.0), z(preproc, 0.0)
        queries, t_remaining = z(queries, 1.0), z(t_remaining, -1.0)
        J = J + pad
    out = pl.pallas_call(
        _score_kernel,
        grid=(J // bj,),
        in_specs=[
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj,), lambda i: (i,)),
            pl.BlockSpec((bj,), lambda i: (i,)),
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((J, W), jnp.float32),
            jax.ShapeDtypeStruct((J,), jnp.int32),
            jax.ShapeDtypeStruct((J,), jnp.float32),
            jax.ShapeDtypeStruct((J, W), jnp.int8),
        ],
        interpret=interpret,
    )(qps.astype(jnp.float32), preproc.astype(jnp.float32),
      queries.astype(jnp.float32)[:, None],
      t_remaining.astype(jnp.float32)[:, None])
    est, best, urg, acc = out
    n = J - pad
    return est[:n], best[:n], urg[:n], acc[:n]


def _score_v2_kernel(t_ref, pre_ref, dec_ref, rem_ref, pen_ref, phase_ref,
                     hft_ref, hpt_ref, trem_ref, tq_ref, dtok_ref,
                     est_ref, acc_ref, urg_ref, doom_ref):
    t = t_ref[...]                      # [BJ, W] solo full service (inf=infeasible)
    pre = pre_ref[...]                  # [BJ, W] prefill prefix
    dec = dec_ref[...]                  # [BJ, W] decode remainder
    rem = rem_ref[...]                  # [BJ, 1] Eq. 1 remaining budget
    pen = pen_ref[...]                  # [1, W] queue-depth penalty
    phase = phase_ref[...]              # [BJ, 1] 0 full / 1 prefill / 2 decode
    has_ttft = hft_ref[...] != 0        # [BJ, 1]
    has_tpot = hpt_ref[...] != 0
    ttft_rem = trem_ref[...]            # [BJ, 1] TTFT budget minus waiting
    tpot_qos = tq_ref[...]              # [BJ, 1] (inf = no deadline)
    dtok = dtok_ref[...]                # [BJ, 1] decoded tokens (inf = n/a)

    # phase-sliced, depth-penalized effective service time
    t_eff = jnp.where(phase == 1, pre, jnp.where(phase == 2, dec, t))
    t_eff = t_eff * pen
    acc = rem >= t_eff                                        # Eq. 3
    # streaming gates on the penalized phase split: a decode-phase job's
    # TTFT is history, a prefill-phase job's TPOT belongs to its decode
    ttft_est = pre * pen
    tpot_est = dec * pen / dtok
    acc &= (~has_ttft) | (phase == 2) | (ttft_est <= ttft_rem)
    acc &= (~has_tpot) | (phase == 1) | (tpot_est <= tpot_qos)
    # urgency decays from the *solo* estimate (matching the numpy path);
    # a scarce TTFT budget can become the binding urgency
    urg = rem[:, 0] - jnp.min(t, axis=1)
    ttft_slack = ttft_rem[:, 0] - jnp.min(ttft_est, axis=1)
    urg = jnp.where(has_ttft[:, 0] & (phase[:, 0] != 2),
                    jnp.minimum(urg, ttft_slack), urg)
    doom = ~jnp.any(acc, axis=1)

    est_ref[...] = t_eff
    acc_ref[...] = acc.astype(jnp.int8)
    urg_ref[...] = urg
    doom_ref[...] = doom.astype(jnp.int8)


def scheduler_score_v2(t_solo, prefill, decode, t_remaining, pen, phase,
                       has_ttft, has_tpot, ttft_rem, tpot_qos, dtok, *,
                       bj=128, interpret=False):
    """Fused batched + streaming + disaggregated scoring.

    t_solo, prefill, decode: [J, W] f32 solo-service matrices (``inf``
    marks infeasible pairs); pen: [W] f32 depth penalty; t_remaining,
    ttft_rem, tpot_qos, dtok: [J] f32; phase: [J] int (0/1/2); has_ttft,
    has_tpot: [J] int (0/1).  Returns (t_eff [J,W], acceptable [J,W]
    int8, urgency [J], doomed [J] int8)."""
    J, W = t_solo.shape
    bj = min(bj, J)
    pad = (-J) % bj
    if pad:
        z = lambda a, fill: jnp.pad(a, [(0, pad)] + [(0, 0)] *
                                    (a.ndim - 1), constant_values=fill)
        inf = jnp.inf
        t_solo, prefill, decode = (z(t_solo, inf), z(prefill, inf),
                                   z(decode, inf))
        t_remaining, ttft_rem = z(t_remaining, -1.0), z(ttft_rem, -1.0)
        tpot_qos, dtok = z(tpot_qos, 1.0), z(dtok, 1.0)
        phase, has_ttft, has_tpot = (z(phase, 0), z(has_ttft, 0),
                                     z(has_tpot, 0))
        J = J + pad
    col = lambda a, dt: a.astype(dt)[:, None]
    jw = pl.BlockSpec((bj, W), lambda i: (i, 0))
    j1 = pl.BlockSpec((bj, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        _score_v2_kernel,
        grid=(J // bj,),
        in_specs=[jw, jw, jw, j1,
                  pl.BlockSpec((1, W), lambda i: (0, 0)),   # pen: resident
                  j1, j1, j1, j1, j1, j1],
        out_specs=[jw,
                   jw,
                   pl.BlockSpec((bj,), lambda i: (i,)),
                   pl.BlockSpec((bj,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((J, W), jnp.float32),
            jax.ShapeDtypeStruct((J, W), jnp.int8),
            jax.ShapeDtypeStruct((J,), jnp.float32),
            jax.ShapeDtypeStruct((J,), jnp.int8),
        ],
        interpret=interpret,
    )(t_solo.astype(jnp.float32), prefill.astype(jnp.float32),
      decode.astype(jnp.float32), col(t_remaining, jnp.float32),
      pen.astype(jnp.float32)[None, :], col(phase, jnp.int32),
      col(has_ttft, jnp.int32), col(has_tpot, jnp.int32),
      col(ttft_rem, jnp.float32), col(tpot_qos, jnp.float32),
      col(dtok, jnp.float32))
    est, acc, urg, doom = out
    n = J - pad
    return est[:n], acc[:n], urg[:n], doom[:n]
