"""SynergAI Eq. 2-4 scoring Pallas TPU kernels (v1 + fused v2).

At fleet scale (thousands of queued jobs x hundreds of worker pools) the
scheduler's scoring step is itself a dense [J, W] compute:

    T_est[j, w]   = preproc[j, w] + q[j] / qps[j, w]          (Eq. 2)
    acceptable    = T_rem[j] >= T_est[j, w]                   (Eq. 3)
    best[j]       = argmin_w T_est[j, w] over acceptable      (Eq. 4)
    urgency[j]    = T_rem[j] - min_w T_est[j, w]

Grid walks J-blocks with the full worker axis resident in VMEM; infeasible
(j, w) pairs carry qps <= 0 and are excluded via masking.  Validated against
``repro.core.estimator.estimate_matrix`` (the numpy oracle) in the tests.

``scheduler_score_v2`` fuses the whole batched-serving scoring pipeline —
the queue-depth penalty (``1 + alpha * b`` per worker), the
prefill/decode phase slicing of disaggregated pools, and the TTFT/TPOT
streaming-deadline gates — into a single pass, so the Pallas path covers
``serving="batched"`` + streaming scoring end-to-end instead of falling
back to numpy post-processing.  It consumes the *precomputed* solo
matrices (full service, prefill prefix, decode remainder, ``inf`` marking
infeasible pairs — exactly what ``repro.core.scorecache`` persists across
ticks) and emits the effective times, the gated acceptability, the
TTFT-tightened urgency, and doom.  The numpy oracle is the batched +
streaming block of ``repro.core.scheduler.SynergAI``; parity, including
the padding edges, is pinned in ``tests/test_pallas_parity.py``."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.0e38


def _score_kernel(qps_ref, pre_ref, q_ref, rem_ref,
                  est_ref, best_ref, urg_ref, acc_ref):
    qps = qps_ref[...]                  # [BJ, W]
    pre = pre_ref[...]
    q = q_ref[...]                      # [BJ, 1]
    rem = rem_ref[...]                  # [BJ, 1]

    feas = qps > 0.0
    est = jnp.where(feas, pre + q / jnp.where(feas, qps, 1.0), BIG)
    acc = feas & (rem >= est)
    est_masked = jnp.where(acc, est, BIG)
    # argmin over acceptable; fall back to argmin over feasible
    any_acc = jnp.any(acc, axis=1, keepdims=True)
    pick_from = jnp.where(any_acc, est_masked, est)
    best = jnp.argmin(pick_from, axis=1)
    any_feas = jnp.any(feas, axis=1)
    best = jnp.where(any_feas, best, -1)
    urgency = rem[:, 0] - jnp.min(est, axis=1)

    est_ref[...] = est
    best_ref[...] = best.astype(jnp.int32)
    urg_ref[...] = urgency
    acc_ref[...] = acc.astype(jnp.int8)


def scheduler_score(qps, preproc, queries, t_remaining, *, bj=128,
                    interpret=False):
    """qps, preproc: [J, W] f32 (qps <= 0 marks infeasible); queries,
    t_remaining: [J] f32.  Returns (t_est [J,W], best [J], urgency [J],
    acceptable [J,W] int8)."""
    J, W = qps.shape
    bj = min(bj, J)
    pad = (-J) % bj
    if pad:
        z = lambda a, fill: jnp.pad(a, [(0, pad)] + [(0, 0)] *
                                    (a.ndim - 1), constant_values=fill)
        qps, preproc = z(qps, 0.0), z(preproc, 0.0)
        queries, t_remaining = z(queries, 1.0), z(t_remaining, -1.0)
        J = J + pad
    out = pl.pallas_call(
        _score_kernel,
        grid=(J // bj,),
        in_specs=[
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
            pl.BlockSpec((bj,), lambda i: (i,)),
            pl.BlockSpec((bj,), lambda i: (i,)),
            pl.BlockSpec((bj, W), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((J, W), jnp.float32),
            jax.ShapeDtypeStruct((J,), jnp.int32),
            jax.ShapeDtypeStruct((J,), jnp.float32),
            jax.ShapeDtypeStruct((J, W), jnp.int8),
        ],
        interpret=interpret,
    )(qps.astype(jnp.float32), preproc.astype(jnp.float32),
      queries.astype(jnp.float32)[:, None],
      t_remaining.astype(jnp.float32)[:, None])
    est, best, urg, acc = out
    n = J - pad
    return est[:n], best[:n], urg[:n], acc[:n]


def _score_v2_kernel(t_ref, pre_ref, dec_ref, rem_ref, pen_ref, phase_ref,
                     hft_ref, hpt_ref, trem_ref, tq_ref, dtok_ref,
                     est_ref, acc_ref, urg_ref, doom_ref):
    t = t_ref[...]                      # [BJ, W] solo full service (inf=infeasible)
    pre = pre_ref[...]                  # [BJ, W] prefill prefix
    dec = dec_ref[...]                  # [BJ, W] decode remainder
    rem = rem_ref[...]                  # [BJ, 1] Eq. 1 remaining budget
    pen = pen_ref[...]                  # [1, W] queue-depth penalty
    phase = phase_ref[...]              # [BJ, 1] 0 full / 1 prefill / 2 decode
    has_ttft = hft_ref[...] != 0        # [BJ, 1]
    has_tpot = hpt_ref[...] != 0
    ttft_rem = trem_ref[...]            # [BJ, 1] TTFT budget minus waiting
    tpot_qos = tq_ref[...]              # [BJ, 1] (inf = no deadline)
    dtok = dtok_ref[...]                # [BJ, 1] decoded tokens (inf = n/a)

    # phase-sliced, depth-penalized effective service time
    t_eff = jnp.where(phase == 1, pre, jnp.where(phase == 2, dec, t))
    t_eff = t_eff * pen
    acc = rem >= t_eff                                        # Eq. 3
    # streaming gates on the penalized phase split: a decode-phase job's
    # TTFT is history, a prefill-phase job's TPOT belongs to its decode
    ttft_est = pre * pen
    tpot_est = dec * pen / dtok
    acc &= (~has_ttft) | (phase == 2) | (ttft_est <= ttft_rem)
    acc &= (~has_tpot) | (phase == 1) | (tpot_est <= tpot_qos)
    # urgency decays from the *solo* estimate (matching the numpy path);
    # a scarce TTFT budget can become the binding urgency
    urg = rem[:, 0] - jnp.min(t, axis=1)
    ttft_slack = ttft_rem[:, 0] - jnp.min(ttft_est, axis=1)
    urg = jnp.where(has_ttft[:, 0] & (phase[:, 0] != 2),
                    jnp.minimum(urg, ttft_slack), urg)
    doom = ~jnp.any(acc, axis=1)

    est_ref[...] = t_eff
    acc_ref[...] = acc.astype(jnp.int8)
    urg_ref[...] = urg
    doom_ref[...] = doom.astype(jnp.int8)


def scheduler_score_v2(t_solo, prefill, decode, t_remaining, pen, phase,
                       has_ttft, has_tpot, ttft_rem, tpot_qos, dtok, *,
                       bj=128, interpret=False):
    """Fused batched + streaming + disaggregated scoring.

    t_solo, prefill, decode: [J, W] f32 solo-service matrices (``inf``
    marks infeasible pairs); pen: [W] f32 depth penalty; t_remaining,
    ttft_rem, tpot_qos, dtok: [J] f32; phase: [J] int (0/1/2); has_ttft,
    has_tpot: [J] int (0/1).  Returns (t_eff [J,W], acceptable [J,W]
    int8, urgency [J], doomed [J] int8)."""
    J, W = t_solo.shape
    bj = min(bj, J)
    pad = (-J) % bj
    if pad:
        z = lambda a, fill: jnp.pad(a, [(0, pad)] + [(0, 0)] *
                                    (a.ndim - 1), constant_values=fill)
        inf = jnp.inf
        t_solo, prefill, decode = (z(t_solo, inf), z(prefill, inf),
                                   z(decode, inf))
        t_remaining, ttft_rem = z(t_remaining, -1.0), z(ttft_rem, -1.0)
        tpot_qos, dtok = z(tpot_qos, 1.0), z(dtok, 1.0)
        phase, has_ttft, has_tpot = (z(phase, 0), z(has_ttft, 0),
                                     z(has_tpot, 0))
        J = J + pad
    col = lambda a, dt: a.astype(dt)[:, None]
    jw = pl.BlockSpec((bj, W), lambda i: (i, 0))
    j1 = pl.BlockSpec((bj, 1), lambda i: (i, 0))
    out = pl.pallas_call(
        _score_v2_kernel,
        grid=(J // bj,),
        in_specs=[jw, jw, jw, j1,
                  pl.BlockSpec((1, W), lambda i: (0, 0)),   # pen: resident
                  j1, j1, j1, j1, j1, j1],
        out_specs=[jw,
                   jw,
                   pl.BlockSpec((bj,), lambda i: (i,)),
                   pl.BlockSpec((bj,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((J, W), jnp.float32),
            jax.ShapeDtypeStruct((J, W), jnp.int8),
            jax.ShapeDtypeStruct((J,), jnp.float32),
            jax.ShapeDtypeStruct((J,), jnp.int8),
        ],
        interpret=interpret,
    )(t_solo.astype(jnp.float32), prefill.astype(jnp.float32),
      decode.astype(jnp.float32), col(t_remaining, jnp.float32),
      pen.astype(jnp.float32)[None, :], col(phase, jnp.int32),
      col(has_ttft, jnp.int32), col(has_tpot, jnp.int32),
      col(ttft_rem, jnp.float32), col(tpot_qos, jnp.float32),
      col(dtok, jnp.float32))
    est, acc, urg, doom = out
    n = J - pad
    return est[:n], acc[:n], urg[:n], doom[:n]


# ---------------------------------------------------------------------------
# fused whole-tick kernel: scoring + placement in one device dispatch
#
# ``scheduler_tick`` is the device-resident path's entry point
# (``repro.core.devicecache.DeviceScoreCache``): the Eq. 2 row pools stay
# on-device across ticks, so a steady-state tick ships only O(churn * W)
# row bytes plus O(J + W) per-tick vectors, gathers the live rows by slot
# index on-device, runs the fused scoring kernel below, and finishes the
# whole Eq. 4 placement (urgency-ordered greedy masked argmin over open
# slots) inside the same jit dispatch — the host gets back just the
# (job, worker) assignment indices.


def _tick_kernel(t_ref, pre_ref, dec_ref, rem_ref, pen_ref, bw_ref,
                 phase_ref, hft_ref, hpt_ref, trem_ref, tq_ref, dtok_ref,
                 cost_ref, elig_ref, urg_ref, doom_ref):
    """The v2 scoring recipe extended through the placement-cost prep of
    ``SynergAI._place``: emits the ranking cost (doomed rows carry the
    busy-wait completion cost), the eligibility mask (doomed rows use the
    1.5x-of-best gate over feasible workers, everything else the gated
    acceptability), the TTFT-tightened urgency and doom."""
    t = t_ref[...]                      # [BJ, W] solo full service (inf=infeasible)
    pre = pre_ref[...]                  # [BJ, W] prefill prefix
    dec = dec_ref[...]                  # [BJ, W] decode remainder
    rem = rem_ref[...]                  # [BJ, 1] Eq. 1 remaining budget
    pen = pen_ref[...]                  # [1, W] queue-depth penalty
    bw = bw_ref[...]                    # [1, W] busy/failed wait
    phase = phase_ref[...]              # [BJ, 1] 0 full / 1 prefill / 2 decode
    has_ttft = hft_ref[...] != 0        # [BJ, 1]
    has_tpot = hpt_ref[...] != 0
    ttft_rem = trem_ref[...]            # [BJ, 1] TTFT budget minus waiting
    tpot_qos = tq_ref[...]              # [BJ, 1] (inf = no deadline)
    dtok = dtok_ref[...]                # [BJ, 1] decoded tokens (inf = n/a)

    t_eff = jnp.where(phase == 1, pre, jnp.where(phase == 2, dec, t))
    t_eff = t_eff * pen
    acc = rem >= t_eff                                        # Eq. 3
    ttft_est = pre * pen
    tpot_est = dec * pen / dtok
    acc &= (~has_ttft) | (phase == 2) | (ttft_est <= ttft_rem)
    acc &= (~has_tpot) | (phase == 1) | (tpot_est <= tpot_qos)
    urg = rem[:, 0] - jnp.min(t, axis=1)
    ttft_slack = ttft_rem[:, 0] - jnp.min(ttft_est, axis=1)
    urg = jnp.where(has_ttft[:, 0] & (phase[:, 0] != 2),
                    jnp.minimum(urg, ttft_slack), urg)
    doom_row = ~jnp.any(acc, axis=1, keepdims=True)           # [BJ, 1]
    # placement-cost prep (SynergAI._place): doomed jobs minimize
    # expected completion (wait + exec) within 1.5x of their best option;
    # everyone else walks their acceptable set by the effective time
    feas = jnp.isfinite(t_eff)
    costd = t_eff + bw
    best = jnp.min(jnp.where(feas, costd, jnp.inf), axis=1,
                   keepdims=True)
    eligd = feas & (t_eff <= 1.5 * best)
    cost_ref[...] = jnp.where(doom_row, costd, t_eff)
    elig_ref[...] = jnp.where(doom_row, eligd, acc).astype(jnp.int8)
    urg_ref[...] = urg
    doom_ref[...] = doom_row[:, 0].astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("use_energy", "bj", "interpret"))
def scheduler_tick(pool_t, pool_pre, pool_dec, pool_ene, slots, t_rem,
                   ttft_rem, tpot_qos, dtok, has_ttft, has_tpot, phase,
                   ekey, emask, pen, busy_wait, escale, open0, *,
                   use_energy=False, bj=128, interpret=False):
    """One whole scheduling decision as a single device dispatch.

    pool_t/pool_pre/pool_dec/pool_ene: [cap, W] f32 device-resident row
    pools (padded columns/unwritten slots hold inf/garbage — both are
    masked out); slots: [Jp] i32 row indices (-1 = padding); t_rem,
    ttft_rem, tpot_qos, dtok: [Jp] f32; has_ttft, has_tpot, phase, ekey:
    [Jp] i32; emask: [K, W] bool batch-admission masks (``ekey`` indexes
    rows; all-true single row when serving is job-level); pen, busy_wait,
    escale: [W] f32; open0: [W] bool open (idle) workers.

    Jp must be a multiple of ``bj``.  Returns (assign [Jp] i32 — worker
    index or -1, order [Jp] i32 — the urgency-sorted placement order),
    bit-matching ``SynergAI._place`` over the same float32 inputs:
    stable (urgency, doomed) lexsort, then a greedy masked argmin per job
    with lowest-index tie-breaks, stopping once every open slot is
    filled."""
    Jp = slots.shape[0]
    cap, W = pool_t.shape
    if Jp % bj:
        raise ValueError(f"Jp={Jp} must be a multiple of bj={bj}")
    idx = jnp.clip(slots, 0, max(cap - 1, 0))
    t0 = pool_t[idx]
    pre_m = pool_pre[idx]
    dec_m = pool_dec[idx]
    col = lambda a, dt: a.astype(dt)[:, None]
    row = lambda a: a.astype(jnp.float32)[None, :]
    jw = pl.BlockSpec((bj, W), lambda i: (i, 0))
    j1 = pl.BlockSpec((bj, 1), lambda i: (i, 0))
    w1 = pl.BlockSpec((1, W), lambda i: (0, 0))
    jv = pl.BlockSpec((bj,), lambda i: (i,))
    cost, elig, urg, doom = pl.pallas_call(
        _tick_kernel,
        grid=(Jp // bj,),
        in_specs=[jw, jw, jw, j1, w1, w1, j1, j1, j1, j1, j1, j1],
        out_specs=[jw, jw, jv, jv],
        out_shape=[
            jax.ShapeDtypeStruct((Jp, W), jnp.float32),
            jax.ShapeDtypeStruct((Jp, W), jnp.int8),
            jax.ShapeDtypeStruct((Jp,), jnp.float32),
            jax.ShapeDtypeStruct((Jp,), jnp.int8),
        ],
        interpret=interpret,
    )(t0, pre_m, dec_m, col(t_rem, jnp.float32), row(pen),
      row(busy_wait), col(phase, jnp.int32), col(has_ttft, jnp.int32),
      col(has_tpot, jnp.int32), col(ttft_rem, jnp.float32),
      col(tpot_qos, jnp.float32), col(dtok, jnp.float32))
    elig = elig.astype(bool)
    if use_energy:
        # the weighted energy/carbon term joins the *ranking* cost only;
        # eligible pairs always carry finite energy rows, so no masking
        cost = cost + pool_ene[idx] * escale[None, :]
    # batch-formation admission + padding masks
    jvalid = slots >= 0
    elig = elig & emask[ekey] & jvalid[:, None]
    ranked = jnp.where(elig, cost, jnp.inf)
    # 2D Ordered Job Queue: urgent first, doomed last, padding after
    # everything (stable sort keeps queue order on ties, like np.lexsort)
    doomkey = jnp.where(jvalid, doom.astype(jnp.int32), 2)
    urgkey = jnp.where(jvalid, urg, jnp.inf)
    order = jnp.lexsort((urgkey, doomkey))
    # greedy placement: walk jobs in order, each takes the masked argmin
    # over the still-open slots (argmin tie-breaks at the lowest worker
    # index, exactly like the numpy path's stable candidate walk)
    assign0 = jnp.full((Jp,), -1, jnp.int32)
    n_open0 = jnp.sum(open0.astype(jnp.int32))

    def body(i, carry):
        open_slots, assign, n_open = carry
        ji = order[i]
        cand = jnp.where(open_slots, ranked[ji], jnp.inf)
        wi = jnp.argmin(cand).astype(jnp.int32)
        ok = (n_open > 0) & jnp.isfinite(cand[wi])
        assign = assign.at[ji].set(jnp.where(ok, wi, assign[ji]))
        open_slots = open_slots.at[wi].set(open_slots[wi] & ~ok)
        return open_slots, assign, n_open - ok.astype(jnp.int32)

    _, assign, _ = jax.lax.fori_loop(0, Jp, body,
                                     (open0, assign0, n_open0))
    return assign, order.astype(jnp.int32)
