"""MoE top-k routing Pallas TPU kernel.

Grid walks token blocks; each block's router logits land in VMEM, the
softmax + iterative top-k (k sequential argmax passes — k is small) runs on
the VPU, and the kernel emits the renormalized gate matrix (zeros off the
top-k) that the dispatch einsum consumes.  Token-block tiling keeps the
[BT, E] working set in VMEM for E up to several hundred experts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _routing_kernel(x_ref, w_ref, gates_ref, *, top_k: int):
    x = x_ref[...]                      # [BT, D]
    w = w_ref[...]                      # [D, E]
    logits = jax.lax.dot_general(
        x.astype(jnp.float32), w.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [BT, E]
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)

    # iterative top-k: mask out the argmax k times
    remaining = probs
    sel = jnp.zeros_like(probs)
    for _ in range(top_k):
        mx = jnp.max(remaining, axis=-1, keepdims=True)
        pick = (remaining >= mx) & (remaining > 0)
        # break ties: keep only the first max per row
        first = jnp.cumsum(pick.astype(jnp.int32), axis=-1) == 1
        pick = pick & first
        sel = sel + jnp.where(pick, probs, 0.0)
        remaining = jnp.where(pick, -1.0, remaining)
    gates = sel / jnp.maximum(jnp.sum(sel, axis=-1, keepdims=True), 1e-9)
    gates_ref[...] = gates


def moe_routing(x, router_w, top_k: int, *, bt=128, interpret=False):
    """x: [T, D]; router_w: [D, E] -> gates [T, E] f32 (zeros off top-k,
    renormalized over the selected experts)."""
    T, D = x.shape
    E = router_w.shape[1]
    bt = min(bt, T)
    assert T % bt == 0
    kernel = functools.partial(_routing_kernel, top_k=top_k)
    return pl.pallas_call(
        kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((D, E), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, E), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, E), jnp.float32),
        interpret=interpret,
    )(x, router_w)
