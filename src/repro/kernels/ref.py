"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=None, k_valid=None):
    """q: [B, Sq, H, hd]; k, v: [B, Sk, K, hd]."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    if k_valid is not None:
        ok &= (k_pos < k_valid)[None, :]
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, k_valid):
    return flash_attention_ref(q, k, v, causal=False, k_valid=k_valid)


def moe_routing_ref(x, router_w, top_k):
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, top_k)
    mask = jax.nn.one_hot(idx, probs.shape[-1], dtype=jnp.float32).sum(1)
    gates = probs * mask
    return gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)


def rwkv_scan_ref(r, k, v, w, u):
    """Sequential WKV recurrence. r/k/v/w: [B, S, H, hd]; u: [H, hd]."""
    B, S, H, hd = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         state + uf[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, out

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    _, outs = jax.lax.scan(step, jnp.zeros((B, H, hd, hd), jnp.float32), xs)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype)


def scheduler_score_ref(qps, preproc, queries, t_remaining):
    """Numpy oracle of Eq. 2-4 (mirrors core.estimator.estimate_matrix)."""
    qps = np.asarray(qps, np.float32)
    preproc = np.asarray(preproc, np.float32)
    queries = np.asarray(queries, np.float32)
    t_rem = np.asarray(t_remaining, np.float32)
    feas = qps > 0
    est = np.where(feas, preproc + queries[:, None] / np.where(feas, qps, 1),
                   3.0e38).astype(np.float32)
    acc = feas & (t_rem[:, None] >= est)
    est_m = np.where(acc, est, 3.0e38)
    best = np.where(acc.any(1), est_m.argmin(1),
                    np.where(feas.any(1), est.argmin(1), -1))
    urgency = t_rem - est.min(1)
    return est, best.astype(np.int32), urgency, acc.astype(np.int8)
