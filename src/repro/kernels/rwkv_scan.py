"""RWKV6 WKV recurrence Pallas TPU kernel (chunked linear attention).

The TPU-native rethink of the CUDA wkv6 kernel: instead of one thread per
channel, the sequence is processed in VMEM-resident time chunks per
(batch, head) grid cell.  Within a chunk the recurrence is an in-register
loop of rank-1 updates (VPU outer products); the [hd, hd] state is carried
in VMEM scratch across chunks, so HBM traffic is O(S*hd) instead of
O(S*hd^2) — this is what makes the ssm/hybrid ``long_500k`` cells
memory-feasible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)  # [C, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)  # decay in (0, 1)
    u = u_ref[0].astype(jnp.float32)     # [1, hd] bonus

    def step(t, carry):
        state, out = carry
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]     # [hd]
        kv = kt[:, None] * vt[None, :]              # [hd_k, hd_v]
        yt = jnp.sum((state + u[0][:, None] * kv) * rt[:, None], axis=0)
        state = wt[:, None] * state + kv
        out = out.at[t].set(yt)
        return state, out

    out0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    state, out = jax.lax.fori_loop(0, chunk, step, (state_scr[...], out0))
    state_scr[...] = state
    o_ref[0, 0] = out.astype(o_ref.dtype)


def rwkv_scan(r, k, v, w, u, *, chunk=64, interpret=False):
    """r/k/v/w: [B, S, H, hd]; u: [H, hd].  Returns [B, S, H, hd].

    w is the per-token decay factor in (0, 1) (already exp(-exp(.))).
    """
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    fold = lambda x: (x.transpose(0, 2, 1, 3)
                      .reshape(B * H, S // chunk, chunk, hd))
    rr, kk, vv, ww = fold(r), fold(k), fold(v), fold(w)
    uu = jnp.broadcast_to(u.reshape(H, 1, hd), (H, 1, hd))
    uu = jnp.tile(uu, (B, 1, 1))                    # [B*H, 1, hd]

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // chunk),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S // chunk, chunk, hd),
                                       r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)
    return (out.reshape(B, H, S, hd).transpose(0, 2, 1, 3))
