"""Decode attention Pallas TPU kernel: one query token vs a long KV cache.

Grid walks (batch*kv_head, kv_block); the single query row per (batch, kv
head) is staged once, KV cache blocks stream through VMEM, and the online
softmax state is a VMEM scratch.  A validity bound (``k_valid``) masks the
unwritten tail of the cache buffer (the decode cell's pos+1).

This is the memory-bound hot loop of the decode_32k / long_500k cells: the
kernel reads each cache block exactly once (roofline-optimal bytes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(kv_valid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bk: int, scale: float):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                       # [G, hd]
    k = k_ref[0]                       # [BK, hd]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [G, BK]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_valid_ref[0], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(1) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention(q, k, v, k_valid, *, bk=512, interpret=False):
    """q: [B, 1, H, hd]; k, v: [B, S, K, hd]; k_valid: scalar int32.

    Returns [B, 1, H, hd].
    """
    B, Sq, H, hd = q.shape
    assert Sq == 1
    S, K = k.shape[1], k.shape[2]
    G = H // K
    bk = min(bk, S)
    assert S % bk == 0
    scale = 1.0 / (hd ** 0.5)

    qr = q[:, 0].reshape(B, K, G, hd).reshape(B * K, G, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    valid = jnp.broadcast_to(jnp.asarray(k_valid, jnp.int32)[None], (1,))

    kernel = functools.partial(_decode_kernel, bk=bk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * K, S // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(valid, qr, kr, vr)
    return out.reshape(B, K, G, hd).reshape(B, 1, H, hd)
