"""jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU so the kernels execute (and are
validated) on CPU; on a real TPU backend the lowered Mosaic kernels run.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import (decode_attention as _da, flash_attention as _fa,
                           moe_routing as _mr, rwkv_scan as _rs,
                           scheduler_score as _ss)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128,
                    interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interpret)


@partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, k_valid, *, bk=512, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _da.decode_attention(q, k, v, k_valid, bk=bk,
                                interpret=interpret)


@partial(jax.jit, static_argnames=("top_k", "bt", "interpret"))
def moe_routing(x, router_w, top_k, *, bt=128, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _mr.moe_routing(x, router_w, top_k, bt=bt, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan(r, k, v, w, u, *, chunk=64, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _rs.rwkv_scan(r, k, v, w, u, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("bj", "interpret"))
def scheduler_score(qps, preproc, queries, t_remaining, *, bj=128,
                    interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _ss.scheduler_score(qps, preproc, queries, t_remaining, bj=bj,
                               interpret=interpret)
