"""Flash attention (prefill) Pallas TPU kernel.

Blockwise online-softmax attention with explicit VMEM tiling: the grid walks
(batch*kv_head, q_block, kv_block); q/k/v blocks are staged into VMEM via
BlockSpec, scores are computed on the MXU with f32 accumulation, and the
running (m, l, acc) state lives in VMEM scratch across the kv_block axis.

Supports causal masking, sliding-window masking, and GQA (grouped query
heads are folded into the q-block row dimension so the MXU sees a
(G*BQ, hd) x (hd, BK) matmul — hardware-aligned when BQ, BK are multiples
of 128 and hd >= 64).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window, bq: int, bk: int, seq_len: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                    # [G*BQ, hd]
    k = k_ref[0]                       # [BK, hd]
    v = v_ref[0]                       # [BK, hd]
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [G*BQ, BK]

    g = q.shape[0] // bq
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 0) % bq
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (g * bq, bk), 1)
    ok = jnp.ones((g * bq, bk), dtype=jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window is not None:
        ok &= q_pos - k_pos < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p, v.astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128,
                    interpret=False):
    """q: [B, Sq, H, hd]; k, v: [B, Sk, K, hd] with H % K == 0.

    Returns [B, Sq, H, hd].
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = 1.0 / (hd ** 0.5)

    # [B, Sq, K, G, hd] -> [B*K, Sq*G... ] fold GQA groups into q rows:
    # layout [B*K, n_q_blocks, G*bq, hd] so one grid row covers one kv head.
    qr = (q.reshape(B, Sq // bq, bq, K, G, hd)
           .transpose(0, 3, 1, 4, 2, 5)
           .reshape(B * K, Sq // bq, G * bq, hd))
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)

    grid = (B * K, Sq // bq, Sk // bk)
    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, seq_len=Sk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G * bq, hd), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * bq, hd),
                               lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, Sq // bq, G * bq, hd),
                                       q.dtype),
        scratch_shapes=[
            # (m, l, acc) running softmax state in VMEM scratch
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return (out.reshape(B, K, Sq // bq, G, bq, hd)
              .transpose(0, 2, 4, 1, 3, 5)
              .reshape(B, Sq, H, hd))
