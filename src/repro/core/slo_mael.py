"""SLO-MAEL — SotA baseline reimplemented from Seo et al., TACO'21 (paper
[35]), without model slicing, as the paper's §5.3 comparison.

On each arrival it scores all job->worker mappings by *expected latency*
(current worker backlog + execution time with the worker's default
configuration) and commits the job to the worker minimizing expected latency
subject to the SLO when possible.  Decision-making happens at arrival
(a preprocessing step — zero runtime scheduling overhead, paper §5.4);
there is no adaptive re-scheduling and no per-engine configuration tuning —
the two capabilities SynergAI adds.

The arrival scoring is vectorized over the fleet: the engine's profiled
(qps, preproc, decode_frac) row comes from the shared
``estimator.engine_rows`` cache (one fancy index instead of W ConfigDict
lookups) and the depth penalty / role gates read the ``Cluster``
struct-of-arrays mirror, so a decision is a handful of O(W) vector ops.
The winner is the first index minimizing expected latency among
SLO-satisfying pools (falling back to all feasible pools) — exactly the
original scan's ``(ok and not best_ok) or (ok == best_ok and score <
best)`` tie-breaking, bit-for-bit.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.engines import engine_catalogue
from repro.core.estimator import engine_rows
from repro.core.simulator import PHASE_CODE, Assignment, Cluster, Policy


class SloMael(Policy):
    name = "SLO-MAEL"

    def __init__(self, recharacterizer=None):
        self.backlog: Dict[str, float] = {}      # committed busy time
        self.mapping: Dict[int, str] = {}        # job id -> worker
        self.worker_fifo: Dict[str, List[int]] = {}
        # optional online re-characterization: the arrival plan reads the
        # overlay's belief-scaled default-config rows once it triggers
        self.recharacterizer = recharacterizer
        self.profile = recharacterizer.profile if recharacterizer else 0

    def on_complete(self, result, cluster, now):
        if self.recharacterizer is not None:
            self.recharacterizer.observe_complete(
                result, cluster, now,
                use_default=self.use_default_config)

    def on_arrival(self, job, cluster: Cluster, now: float):
        if self.recharacterizer is not None:
            self.recharacterizer.observe_arrival(job, cluster, now)
        self._plan(job, cluster, now)

    def _plan(self, job, cluster: Cluster, now: float):
        a = cluster.arrays
        names = a.names
        qps, pre, frac = engine_rows(cluster.cd, job.engine, names,
                                     use_default=True,
                                     token=cluster.worker_token,
                                     profile=self.profile)
        phase = cluster.phase_of(job)
        q = float(job.queries)
        with np.errstate(divide="ignore", invalid="ignore"):
            # full default-config service and its prefill prefix
            # (``serving_bridge.prefill_prefix``, vectorized)
            exec_q = q / qps
            full = pre + exec_q
            prefill = np.minimum(full, pre + exec_q * (1.0 - frac))
            if phase == "prefill":
                exec_s, prefill_s = prefill, prefill
            elif phase == "decode":
                exec_s, prefill_s = full - prefill, np.zeros(len(names))
            else:
                exec_s, prefill_s = full, prefill
            cand = qps > 0
            if cluster.disaggregated:
                cand &= (a.role == 0) | (a.role == PHASE_CODE[phase])
            if not cand.any():
                return
            # expected backlog from its *own* model-based bookkeeping
            # (the preprocessing-time plan) — it does not re-observe the
            # cluster, which is exactly the "no adaptive rescheduling"
            # limitation the paper calls out.  Under the batched serving
            # bridge the execution estimate is queue-depth-adjusted
            # (joining a live batch runs 1 + alpha*b slower); 1 in job
            # mode.
            wait = np.maximum(0.0, np.fromiter(
                (self.backlog.get(w, 0.0) for w in names),
                dtype=np.float64, count=len(names)) - now)
            pen = cluster.depth_penalty_array(now)
            exp_latency = wait + pen * exec_s
            ok = cand & (exp_latency <= job.t_qos)
            # streaming SLOs: the plan must clear every deadline the job
            # carries — the tighter of (latency, TTFT, TPOT) headroom
            req = job.request
            if (req is not None and req.ttft_qos is not None
                    and phase != "decode"):
                exp_ttft = (now - job.arrival) + wait + pen * prefill_s
                ok &= exp_ttft <= req.ttft_qos
            if (req is not None and req.tpot_qos is not None
                    and phase != "prefill"):
                # per-token rate over the engine-default token count: the
                # profile-shape decode seconds and the sampled Request
                # length would otherwise disagree on what "per token" means
                spec = engine_catalogue().get(job.engine)
                dtok = (job.queries * spec.decode_len if spec is not None
                        else req.decode_tokens)
                if dtok > 0:
                    decode_s = exec_s - (prefill_s if phase != "decode"
                                         else 0.0)
                    ok &= pen * decode_s / dtok <= req.tpot_qos
        # prefer SLO-satisfying mappings; break ties by expected latency
        # at the lowest index — argmin over the masked scores reproduces
        # the original first-strict-improvement scan exactly
        pick = ok if ok.any() else cand
        scores = np.where(pick, exp_latency, np.inf)
        wi = int(scores.argmin())
        best_w = names[wi]
        self.mapping[job.id] = best_w
        base = max(cluster.workers[best_w].busy_until,
                   self.backlog.get(best_w, now), now)
        self.backlog[best_w] = base + float(exec_s[wi])
        self.worker_fifo.setdefault(best_w, []).append(job.id)

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        # failure recovery: a job killed mid-run is re-queued by the
        # simulator without a new arrival event, so it sits in no per-worker
        # FIFO and would never dispatch again — re-commit it as if it had
        # just arrived (its old backlog entry is a sunk cost the model-based
        # plan never revisits; that lack of re-observation is the paper's
        # §5.3 criticism of this baseline).  No-op without failures.
        committed = set()
        for fifo in self.worker_fifo.values():
            committed.update(fifo)
        for job in queue:
            if job.id not in committed:
                # re-commit without re-observing: a failure requeue is
                # not a new arrival, so the drift detector's mix window
                # never double-counts it
                self._plan(job, cluster, now)
        out = []
        by_id = {j.id: j for j in queue}
        for w, fifo in self.worker_fifo.items():
            if not fifo or not cluster.workers[w].idle(now):
                continue
            jid = fifo[0]
            if jid not in by_id:
                continue
            job = by_id[jid]
            if not cluster.admit_ok(job, w, now):
                continue    # batched: the live batch serves another engine
            ent = cluster.cd.default_entry(job.engine, w)
            out.append(Assignment(job, w, ent))
            fifo.pop(0)
        return out
