"""SLO-MAEL — SotA baseline reimplemented from Seo et al., TACO'21 (paper
[35]), without model slicing, as the paper's §5.3 comparison.

On each arrival it scores all job->worker mappings by *expected latency*
(current worker backlog + execution time with the worker's default
configuration) and commits the job to the worker minimizing expected latency
subject to the SLO when possible.  Decision-making happens at arrival
(a preprocessing step — zero runtime scheduling overhead, paper §5.4);
there is no adaptive re-scheduling and no per-engine configuration tuning —
the two capabilities SynergAI adds.
"""

from __future__ import annotations

import math
from typing import Dict, List

from repro.core.simulator import Assignment, Cluster, Policy


class SloMael(Policy):
    name = "SLO-MAEL"

    def __init__(self):
        self.backlog: Dict[str, float] = {}      # committed busy time
        self.mapping: Dict[int, str] = {}        # job id -> worker
        self.worker_fifo: Dict[str, List[int]] = {}

    @staticmethod
    def _phase_exec(ent, job, phase: str):
        """(exec_s, prefill_s) of the phase being placed, with the
        worker's default configuration: the full service outside
        disaggregated clusters, the prefill prefix or decode remainder of
        it inside one."""
        from repro.core.serving_bridge import prefill_prefix
        full = ent.preproc_s + job.queries / ent.qps
        prefill = prefill_prefix(ent, job.queries)
        if phase == "prefill":
            return prefill, prefill
        if phase == "decode":
            return full - prefill, 0.0
        return full, prefill

    def on_arrival(self, job, cluster: Cluster, now: float):
        best_w, best_score, best_ok = None, math.inf, False
        t_rem = job.t_qos
        req = job.request
        phase = cluster.phase_of(job)
        if req is not None and req.tpot_qos is not None:
            # per-token rate over the engine-default token count: the
            # profile-shape decode seconds and the sampled Request length
            # would otherwise disagree on what "per token" means
            from repro.core.engines import engine_catalogue
            spec = engine_catalogue().get(job.engine)
            dtok = (job.queries * spec.decode_len if spec is not None
                    else req.decode_tokens)
        for w, ws in cluster.workers.items():
            if not cluster.role_ok(job, w):
                continue    # disaggregated: wrong-phase pool
            ent = cluster.cd.default_entry(job.engine, w)
            if ent is None or ent.qps <= 0:
                continue
            # expected backlog from its *own* model-based bookkeeping (the
            # preprocessing-time plan) — it does not re-observe the cluster,
            # which is exactly the "no adaptive rescheduling" limitation the
            # paper calls out.  Under the batched serving bridge the
            # execution estimate is queue-depth-adjusted (joining a live
            # batch runs 1 + alpha*b slower); 1.0 in job mode.
            wait = max(0.0, self.backlog.get(w, 0.0) - now)
            pen = cluster.depth_penalty(w, now)
            exec_s, prefill_s = self._phase_exec(ent, job, phase)
            exp_latency = wait + pen * exec_s
            ok = exp_latency <= t_rem
            # streaming SLOs: the plan must clear every deadline the job
            # carries — the tighter of (latency, TTFT, TPOT) headroom
            if req is not None and req.ttft_qos is not None \
                    and phase != "decode":
                exp_ttft = (now - job.arrival) + wait + pen * prefill_s
                ok = ok and exp_ttft <= req.ttft_qos
            if (req is not None and req.tpot_qos is not None
                    and phase != "prefill" and dtok > 0):
                decode_s = exec_s - (prefill_s if phase != "decode"
                                     else 0.0)
                ok = ok and pen * decode_s / dtok <= req.tpot_qos
            # prefer SLO-satisfying mappings; break ties by expected latency
            if (ok and not best_ok) or (
                    ok == best_ok and exp_latency < best_score):
                best_w, best_score, best_ok = w, exp_latency, ok
        if best_w is None:
            return
        self.mapping[job.id] = best_w
        ent = cluster.cd.default_entry(job.engine, best_w)
        exec_s, _ = self._phase_exec(ent, job, phase)
        base = max(cluster.workers[best_w].busy_until,
                   self.backlog.get(best_w, now), now)
        self.backlog[best_w] = base + exec_s
        self.worker_fifo.setdefault(best_w, []).append(job.id)

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        # failure recovery: a job killed mid-run is re-queued by the
        # simulator without a new arrival event, so it sits in no per-worker
        # FIFO and would never dispatch again — re-commit it as if it had
        # just arrived (its old backlog entry is a sunk cost the model-based
        # plan never revisits; that lack of re-observation is the paper's
        # §5.3 criticism of this baseline).  No-op without failures.
        committed = set()
        for fifo in self.worker_fifo.values():
            committed.update(fifo)
        for job in queue:
            if job.id not in committed:
                self.on_arrival(job, cluster, now)
        out = []
        by_id = {j.id: j for j in queue}
        for w, fifo in self.worker_fifo.items():
            if not fifo or not cluster.workers[w].idle(now):
                continue
            jid = fifo[0]
            if jid not in by_id:
                continue
            job = by_id[jid]
            if not cluster.admit_ok(job, w, now):
                continue    # batched: the live batch serves another engine
            ent = cluster.cd.default_entry(job.engine, w)
            out.append(Assignment(job, w, ent))
            fifo.pop(0)
        return out
