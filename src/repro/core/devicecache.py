"""Device-resident mirror of the cross-tick score cache
(docs/performance.md, "Device-resident scoring").

``ScoreCache`` already makes the numpy hot path sublinear per tick; the
Pallas backends, however, re-shipped the full ``[J, W]`` matrices from
host to device on *every* tick, which is why ``BENCH_SCHED.json``'s
``pallas``/``pallas-v2`` variants trail the cached numpy path by two
orders of magnitude — the PerLLM (arXiv:2405.14636) per-decision-
overhead argument, lost at the host/device boundary instead of in the
scoring math.

``DeviceScoreCache`` keeps the host ``ScoreCache`` as the row oracle
(row *values* are host computations over the Configuration Dictionary)
and mirrors every written row into float32 device pools that persist
across ticks, applying the same invalidation rules incrementally
on-device:

* **arrivals** append: the newly inserted rows ship as one batched
  scatter (``pool.at[idx].set(rows)`` under a donated jit, so the pool
  buffer is updated in place) — O(churn * W) bytes;
* **placements / finishes** reclaim lazily exactly like the host cache:
  a departed row simply stops being gathered (validity is the slot
  vector itself), zero device traffic;
* **elastic clones** extend the worker axis in padded column blocks:
  the old block moves device-to-device, only the new columns of live
  rows are uploaded;
* **failure generations mask instead of re-uploading**: the host cache
  flushes on any ``fail_gen`` bump out of pure conservatism — failure
  state never enters the Eq. 2 rows — so the device mirror adopts the
  new generation and keeps every resident row (recomputing them would
  reproduce the same bits).  ``profile_gen`` bumps reclaim exactly the
  refreshed engines' slots (the PR 7 rule), so only those rows re-ship;
  non-append membership changes genuinely change the row shape and
  still flush.

``device_tick`` then runs the whole decision — row gather by slot
index, the fused Eq. 2-4 scoring kernel, and the urgency-ordered greedy
placement — as one ``repro.kernels.scheduler_score.scheduler_tick``
dispatch; the host ships only O(J + W) per-tick vectors and receives
the (job, worker) assignment indices.  Parity with the cached numpy
path and the O(churn * W) transfer bound are pinned by
``tests/test_devicecache.py``.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.estimator import profile_gen
from repro.core.scorecache import ScoreCache

_COL_BLOCK = 128      # worker-axis padding block (f32 TPU lane width)
_ROW_BLOCK = 256      # slot-pool row padding block (matches _GROW)
_UP_BLOCK = 8         # upload-batch padding block (recompile guard)


def _bucket(n: int, block: int) -> int:
    """Smallest power-of-two multiple of ``block`` >= n — shapes stay in
    a tiny set so the jitted upload/tick dispatches never recompile in
    steady state."""
    b = block
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool, idx, rows):
    return pool.at[idx].set(rows)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("start", "width"))
def _scatter_cols(pool, idx, block, *, start, width):
    return pool.at[idx, start:start + width].set(block)


class DeviceScoreCache(ScoreCache):
    """A ``ScoreCache`` whose Eq. 2 rows are additionally resident on
    the jax device, plus the fused one-dispatch tick entry point."""

    def __init__(self, use_default: bool = False, profile: int = 0,
                 bj: int = 128, interpret=None):
        # device pools (created lazily on first upload)
        self._dt = self._dpre = self._ddec = self._dene = None
        self._d_cap = 0
        self._d_Wp = 0
        super().__init__(use_default, profile)
        self.bj = int(bj)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)
        # transfer accounting (tests assert the O(churn * W) bound)
        self.fail_masks = 0          # fail_gen bumps absorbed by masking
        self.rows_uploaded = 0       # matrix rows shipped host -> device
        self.bytes_to_device = 0     # every host -> device payload byte
        self.ticks = 0

    # ------------------------------------------------------------------
    # invalidation overrides

    def sync(self, cd, queue, cluster) -> np.ndarray:
        key = (cluster.serial, cluster.worker_token, cluster.fail_gen,
               profile_gen(cd, self.profile))
        old = self._key
        if (old is not None and key != old and old[0] == key[0]
                and old[1] == key[1] and old[3] == key[3]):
            # pure failure-generation bump: the host rule flushes out of
            # conservatism, but failure state never enters the rows —
            # same cluster, same worker tuple, same profile means a
            # recompute would reproduce every row bit-for-bit.  Mask:
            # adopt the new generation, keep host + device rows.
            self._key = key
            self.fail_masks += 1
        return super().sync(cd, queue, cluster)

    def _flush(self, W: int):
        super()._flush(W)
        self._dt = self._dpre = self._ddec = self._dene = None
        self._d_cap = 0
        self._d_Wp = 0

    def _insert(self, jobs, cd, cluster, slots, miss):
        super()._insert(jobs, cd, cluster, slots, miss)
        self._upload_rows(np.asarray(slots[miss], dtype=np.int64))

    def _extend_columns(self, cd, queue, cluster, names):
        old_W = self._W
        super()._extend_columns(cd, queue, cluster, names)
        if self._dt is None:
            return
        # widen the pools (old block moves device-to-device), then ship
        # only the new columns of the live rows
        Wp = _bucket(self._W, _COL_BLOCK)
        if Wp > self._d_Wp:
            self._d_Wp = Wp
            regrow = lambda p: (None if p is None else
                                jnp.full((self._d_cap, Wp), jnp.inf,
                                         jnp.float32)
                                .at[:, :p.shape[1]].set(p))
            self._dt = regrow(self._dt)
            self._dpre = regrow(self._dpre)
            self._ddec = regrow(self._ddec)
            self._dene = regrow(self._dene)
        sl = np.fromiter(self._slot.values(), np.int64, len(self._slot))
        n, width = len(sl), self._W - old_W
        if not n or not width:
            return
        nb = _bucket(n, _UP_BLOCK)
        idx = np.empty(nb, np.int32)
        idx[:n] = sl
        idx[n:] = sl[-1]

        def ship_cols(pool, host):
            block = np.empty((nb, width), np.float32)
            block[:n] = host[sl, old_W:self._W]
            block[n:] = block[n - 1]
            self.bytes_to_device += block.nbytes + idx.nbytes
            return _scatter_cols(pool, jnp.asarray(idx),
                                 jnp.asarray(block), start=old_W,
                                 width=width)

        self._dt = ship_cols(self._dt, self._t)
        if self._have_phase:
            self._dpre = ship_cols(self._dpre, self._pre)
            self._ddec = ship_cols(self._ddec, self._dec)
        if self._have_energy:
            self._dene = ship_cols(self._dene, self._ene)

    def ensure_phase_rows(self, cd, queue, slots, cluster):
        fresh = not self._have_phase
        super().ensure_phase_rows(cd, queue, slots, cluster)
        if fresh and len(queue):
            # one-time materialization: ship the live prefill/decode
            # rows; later inserts keep them current
            live = np.fromiter(self._slot.values(), np.int64,
                               len(self._slot))
            self._upload_rows(live, which=("pre", "dec"))

    def ensure_energy_rows(self, cd, queue, slots, cluster):
        fresh = not self._have_energy
        super().ensure_energy_rows(cd, queue, slots, cluster)
        if fresh and len(queue):
            live = np.fromiter(self._slot.values(), np.int64,
                               len(self._slot))
            self._upload_rows(live, which=("ene",))

    # ------------------------------------------------------------------
    # device pool maintenance

    def _ensure_pools(self):
        """Size every active pool to (padded cap, padded W); freshly
        exposed regions hold inf and are only ever read after an upload
        writes them (stale slots are never gathered)."""
        cap = max(self._d_cap, _bucket(max(self._cap, 1), _ROW_BLOCK))
        Wp = max(self._d_Wp, _bucket(max(self._W, 1), _COL_BLOCK))

        def fit(p):
            if p is not None and p.shape == (cap, Wp):
                return p
            fresh = jnp.full((cap, Wp), jnp.inf, jnp.float32)
            if p is None:
                return fresh
            return fresh.at[:p.shape[0], :p.shape[1]].set(p)

        self._dt = fit(self._dt)
        if self._have_phase:
            self._dpre = fit(self._dpre)
            self._ddec = fit(self._ddec)
        if self._have_energy:
            self._dene = fit(self._dene)
        self._d_cap, self._d_Wp = cap, Wp

    def _upload_rows(self, dest: np.ndarray, which=("t", "pre", "dec",
                                                    "ene")):
        """Batched dynamic-update-slice of freshly written host rows into
        the device pools: O(rows * W) bytes, the only matrix traffic a
        steady-state tick pays."""
        n = len(dest)
        if not n:
            return
        self._ensure_pools()
        Wp = self._d_Wp
        nb = _bucket(n, _UP_BLOCK)
        idx = np.empty(nb, np.int32)
        idx[:n] = dest
        idx[n:] = dest[-1]      # padding re-writes the last row's values
        jidx = jnp.asarray(idx)
        self.bytes_to_device += idx.nbytes

        def ship(pool, host):
            rows = np.full((nb, Wp), np.inf, np.float32)
            rows[:n, :self._W] = host[dest]
            rows[n:] = rows[n - 1]
            self.bytes_to_device += rows.nbytes
            return _scatter_rows(pool, jidx, jnp.asarray(rows))

        if "t" in which:
            self._dt = ship(self._dt, self._t)
        if self._have_phase and "pre" in which:
            self._dpre = ship(self._dpre, self._pre)
        if self._have_phase and "dec" in which:
            self._ddec = ship(self._ddec, self._dec)
        if self._have_energy and "ene" in which:
            self._dene = ship(self._dene, self._ene)
        if "t" in which:
            self.rows_uploaded += n

    # ------------------------------------------------------------------
    # the fused one-dispatch tick

    def device_tick(self, slots, t_rem, ttft_rem, tpot_qos, dtok,
                    has_ttft, has_tpot, phase, ekey, emask, pen,
                    busy_wait, avail, escale=None):
        """Run one whole scheduling decision on-device.  All inputs are
        host vectors over the live queue ([J]) or the fleet ([W] /
        [K, W]); Eq. 1 decay (t_rem, ttft_rem) is computed on host in
        float64 from the cached scalars — an O(J) vector op whose f32
        cast matches the fused v2 contract bit-for-bit.  Returns
        (assign [Jp], order [Jp]) as numpy int32."""
        from repro.kernels.scheduler_score import scheduler_tick

        self._ensure_pools()
        J, W = len(slots), self._W
        Wp = self._d_Wp
        bj = self.bj
        Jp = _bucket(max(J, 1), bj)
        use_energy = escale is not None

        def padj(a, fill, dt):
            out = np.full(Jp, fill, dt)
            out[:J] = a
            return out

        def padw(a, fill, dt):
            out = np.full(Wp, fill, dt)
            out[:W] = a
            return out

        slots_p = padj(slots, -1, np.int32)
        K = emask.shape[0]
        Kp = _bucket(K, 1)
        em = np.zeros((Kp, Wp), bool)
        em[:K, :W] = emask
        args = (slots_p,
                padj(t_rem, -1.0, np.float32),
                padj(ttft_rem, -1.0, np.float32),
                padj(tpot_qos, 1.0, np.float32),
                padj(dtok, 1.0, np.float32),
                padj(has_ttft, 0, np.int32),
                padj(has_tpot, 0, np.int32),
                padj(phase, 0, np.int32),
                padj(ekey, 0, np.int32),
                em,
                padw(pen, 1.0, np.float32),
                padw(busy_wait, 0.0, np.float32),
                padw(escale if use_energy else np.zeros(W), 0.0,
                     np.float32),
                padw(avail, False, bool))
        self.bytes_to_device += sum(a.nbytes for a in args)
        self.ticks += 1
        pool_pre = self._dpre if self._have_phase else self._dt
        pool_dec = self._ddec if self._have_phase else self._dt
        pool_ene = (self._dene if use_energy
                    else jnp.zeros((1, Wp), jnp.float32))
        assign, order = scheduler_tick(
            self._dt, pool_pre, pool_dec, pool_ene,
            *(jnp.asarray(a) for a in args),
            use_energy=use_energy, bj=min(bj, Jp),
            interpret=self.interpret)
        return np.asarray(assign), np.asarray(order)
