"""SynergAI online scheduler (paper §4.2).

QoS-aware run-time scheduling: the queue is continuously re-scored with the
vectorized Eq. 1-4 estimator, ordered by urgency (descending risk), doomed
jobs are de-prioritized to the tail, and each dequeued job walks its sorted
(worker, c*) candidate list to the first available worker.  A periodic
update (simulator tick) reassesses all waiting jobs.

Unlike every baseline, assignments use the *optimal* per-(engine, worker)
configuration c*_{j,w} from the offline Configuration Dictionary.

The placement pass is fully vectorized for fleet scale (thousands of queued
jobs x hundreds of pools): per-job candidate walks become masked argmins
over a shared cost matrix — provably the same assignment as walking the
stable-sorted candidate list, since ``argmin`` breaks ties at the lowest
worker index exactly like a stable sort does.  ``score_fn`` swaps the
scoring backend: the numpy estimator by default, or the Pallas kernel via
``repro.core.pallas_scoring.make_pallas_score_fn``.

Under the batched serving bridge (``Simulator(..., serving="batched")``)
the estimates become *queue-depth-aware*: every worker's column is scaled
by ``Cluster.depth_penalty`` (joining a batch of ``b`` members runs
``1 + alpha * b`` slower than solo), acceptability and doom are
re-derived from the adjusted times, and eligibility is intersected with
the bridge's batch-formation rules (same-engine batches under slot/KV
budgets) via ``Cluster.admit_engine_ok``.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.estimator import estimate_matrix
from repro.core.simulator import Assignment, Cluster, Policy


class SynergAI(Policy):
    name = "SynergAI"
    use_default_config = False

    def __init__(self, score_fn=None):
        # score_fn: optional accelerated scorer (Pallas kernel at fleet
        # scale); defaults to the numpy estimator.
        self.score_fn = score_fn or estimate_matrix

    def schedule(self, now, queue, cluster: Cluster) -> List[Assignment]:
        if not queue:
            return []
        workers = list(cluster.workers)
        avail = np.array([cluster.workers[w].idle(now) for w in workers])
        if not avail.any():
            # nothing can start this tick; scoring the whole queue would
            # change no assignment (the placement below only dispatches
            # onto idle workers), so skip the [J, W] pass — the dominant
            # cost under fleet-scale backlog.
            return []
        score = self.score_fn(cluster.cd, queue, workers, now,
                              use_default=False)
        busy_wait = np.array([max(0.0, cluster.workers[w].busy_until - now,
                                  cluster.workers[w].failed_until - now)
                              for w in workers])
        t = score.t_estimated
        doomed = score.doomed
        acceptable = score.acceptable
        batched = getattr(cluster, "serving", "job") == "batched"
        if batched:
            # queue-depth-adjusted latency: joining a live batch divides
            # the job's service rate; re-derive Eq. 3/4 from the
            # penalized estimates (identical to the plain path whenever
            # every batch is empty, e.g. max_batch=1 with free workers)
            pen = np.array([cluster.depth_penalty(w, now)
                            for w in workers])
            if (pen != 1.0).any():
                t = t * pen[None, :]
                acceptable = score.t_remaining[:, None] >= t
                doomed = ~acceptable.any(axis=1)
        # order: urgent first (2D Ordered Job Queue); doomed jobs last.
        # lexsort is stable, so ties keep queue order like sorted() did.
        order = np.lexsort((score.urgency, doomed))
        # per-job candidate cost + eligibility (the sorted (w, c*) list):
        # non-doomed jobs walk their *acceptable* workers by T_estimated;
        # doomed jobs minimize expected completion (wait + exec) over all
        # feasible workers, restricted to options within 1.5x of the best
        # so a doomed job waits for a fast worker instead of seizing a far
        # slower idle one and blocking it for everyone else.
        feasible = np.isfinite(t)
        if doomed.any():
            cost = np.where(doomed[:, None], t + busy_wait[None, :], t)
            best_cost = np.where(feasible, cost, np.inf).min(axis=1)
            elig = np.where(doomed[:, None],
                            feasible & (t <= 1.5 * best_cost[:, None]),
                            acceptable)
        else:
            cost = t
            elig = acceptable
        if batched:
            # batch-formation rules: a live batch only admits its own
            # engine, under the slot and KV-cache budgets
            emask = {e: np.fromiter((cluster.admit_engine_ok(e, w, now)
                                     for w in workers), dtype=bool,
                                    count=len(workers))
                     for e in {j.engine for j in queue}}
            elig = elig & np.stack([emask[j.engine] for j in queue])
        ranked = np.where(elig, cost, np.inf)
        # jobs with no eligible idle worker can never place this round
        live = np.isfinite(ranked[:, avail]).any(axis=1)

        out: List[Assignment] = []
        open_slots = avail.copy()
        n_open = int(open_slots.sum())
        for ji in order:
            if not live[ji]:
                continue
            cand = np.where(open_slots, ranked[ji], np.inf)
            wi = int(cand.argmin())
            if np.isfinite(cand[wi]):
                w = workers[wi]
                job = queue[ji]
                out.append(Assignment(job, w, cluster.cd.optimal(job.engine,
                                                                 w)))
                open_slots[wi] = False
                n_open -= 1
                if n_open == 0:
                    break
        return out
