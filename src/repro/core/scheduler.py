"""SynergAI online scheduler (paper §4.2).

QoS-aware run-time scheduling: the queue is continuously re-scored with the
vectorized Eq. 1-4 estimator, ordered by urgency (descending risk), doomed
jobs are de-prioritized to the tail, and each dequeued job walks its sorted
(worker, c*) candidate list to the first available worker.  A periodic
update (simulator tick) reassesses all waiting jobs.

Unlike every baseline, assignments use the *optimal* per-(engine, worker)
configuration c*_{j,w} from the offline Configuration Dictionary.

The hot path is **incremental across ticks** (docs/performance.md): a
``repro.core.scorecache.ScoreCache`` persists each job's Eq. 2 row —
``t_estimated`` is time-invariant per (job, worker-set) — so a tick only
recomputes the time-decaying quantities (``t_remaining``, urgency, doom)
as O(J) vector ops, appends rows for arrivals, extends columns on elastic
provisioning, and flushes on fleet-generation changes.  Per-worker state
(availability, backlog, batch depth, admission) reads the ``Cluster``
struct-of-arrays mirror as O(W) vector ops instead of Python loops.  On
the plain path placement is *lazy*: candidate rows are evaluated in
urgency order only until the open slots are filled, so the per-tick cost
stays sublinear in queue depth (the PerLLM deployability argument,
arXiv:2405.14636).  ``SynergAI(incremental=False)`` preserves the
full-matrix path; both produce bit-for-bit identical schedules
(``tests/test_scorecache.py``, plus the pinned golden digests).

The placement pass is fully vectorized for fleet scale (thousands of queued
jobs x hundreds of pools): per-job candidate walks become masked argmins
over a shared cost matrix — provably the same assignment as walking the
stable-sorted candidate list, since ``argmin`` breaks ties at the lowest
worker index exactly like a stable sort does.  ``score_fn`` swaps the
scoring backend: the numpy estimator by default, the Eq. 2-4 Pallas kernel
via ``repro.core.pallas_scoring.make_pallas_score_fn()``, or the fused v2
kernel (``make_pallas_score_fn(v2=True)``) that additionally folds the
batched depth penalty, the prefill/decode phase split and the TTFT/TPOT
streaming gates into one on-accelerator pass.

Under the batched serving bridge (``Simulator(..., serving="batched")``)
the estimates become *queue-depth-aware*: every worker's column is scaled
by ``Cluster.depth_penalty`` (joining a batch of ``b`` members runs
``1 + alpha * b`` slower than solo), acceptability and doom are
re-derived from the adjusted times, and eligibility is intersected with
the bridge's batch-formation rules (same-engine batches under slot/KV
budgets) via ``Cluster.admit_engine_mask``.

Streaming QoS (``Request.ttft_qos`` / ``tpot_qos``) tightens the gate
further: acceptability requires the *tighter* of the end-to-end, TTFT and
TPOT headrooms to survive (``estimator.phase_split_matrices`` supplies the
prefill/decode split of Eq. 2), and a scarce TTFT budget can become the
binding urgency.  Under prefill/decode-disaggregated pools
(``WorkerPool.role``) each phase is placed independently: phase-sliced
service times, role-gated eligibility.  With no deadlines and no role
tags every addition is inert and the schedule is unchanged bit-for-bit.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.engines import engine_catalogue
from repro.core.estimator import (energy_matrix, estimate_matrix,
                                  phase_split_matrices)
from repro.core.scorecache import ScoreCache
from repro.core.simulator import (PHASE_CODE, PHASE_NAME, Assignment,
                                  Cluster, Policy)


class SynergAI(Policy):
    name = "SynergAI"
    use_default_config = False

    def __init__(self, score_fn=None, incremental: bool = True,
                 recharacterizer=None, energy_weight: float = 0.0,
                 carbon=None, overload=None):
        # score_fn: optional accelerated scorer — the Eq. 2-4 Pallas
        # kernel, or the fused v2 kernel (``fused`` attribute) which also
        # consumes the depth penalty / phase split / streaming gates.
        # incremental=False disables the cross-tick score cache (the
        # uncached reference path, e.g. for the perf bench baseline).
        # recharacterizer: an ``OnlineRecharacterizer`` closing the
        # offline/online loop — arrivals and completions feed its drift
        # detector, and scoring reads its belief-scaled profile overlay
        # (``estimator.ProfileOverlay``); inert until it triggers.
        # energy_weight: seconds of estimated latency traded per joule of
        # estimated job energy — the weighted energy/carbon term added to
        # Eq. 4's placement cost (``docs/performance.md``).  Acceptability
        # and doom stay purely time-derived (Eq. 1-3 untouched), so the
        # term steers choices *among* a job's acceptable open workers and
        # never parks a job to save energy.  0.0 (default) is bit-for-bit
        # the energy-blind scheduler: no energy rows are ever built.
        # carbon: optional ``workload.CarbonTrace`` — scales each worker's
        # energy term by its region's *relative* grid intensity at
        # decision time, making the term a carbon term.
        # overload: an ``overload.OverloadController`` — deadline-aware
        # load shedding (the cached certain-doom predicate) + queue-depth
        # admission backpressure, consulted on every scoring pass; the
        # simulator drains its marks into terminal ``outcome="shed"``
        # results.  None (default) is bit-for-bit the shed-free scheduler.
        if energy_weight < 0:
            raise ValueError("energy_weight must be >= 0")
        self.energy_weight = float(energy_weight)
        self.carbon = carbon
        self.overload = overload
        self._regions_key = None
        self._regions: tuple = ()
        self.score_fn = score_fn or estimate_matrix
        self._fused = bool(getattr(score_fn, "fused", False))
        self._device = bool(getattr(score_fn, "device_cache", False))
        self._takes_token = bool(getattr(self.score_fn, "takes_token",
                                         False))
        self._takes_profile = bool(getattr(self.score_fn, "takes_profile",
                                           False))
        self.recharacterizer = recharacterizer
        self.profile = recharacterizer.profile if recharacterizer else 0
        if (recharacterizer is not None and score_fn is not None
                and not (self._fused or self._takes_profile)):
            raise ValueError(
                "recharacterizer needs a score_fn that reads the profile "
                "overlay: the default numpy estimator, the fused v2 "
                "kernel, or a backend advertising takes_profile")
        # a conventional custom score_fn builds its own matrices, so the
        # row cache would be dead weight; the fused kernel reads its
        # matrices *from* the cache, so it always carries one; the
        # device-resident backend carries the device-mirrored subclass
        if self._device:
            from repro.core.devicecache import DeviceScoreCache
            self.cache: Optional[ScoreCache] = DeviceScoreCache(
                profile=self.profile,
                bj=getattr(score_fn, "bj", 128),
                interpret=getattr(score_fn, "interpret", None))
        else:
            self.cache = (
                ScoreCache(profile=self.profile) if self._fused
                or (incremental and score_fn is None) else None)

    # -- online re-characterization hooks (inert without one) ----------

    def on_arrival(self, job, cluster, now):
        if self.recharacterizer is not None:
            self.recharacterizer.observe_arrival(job, cluster, now)

    def on_complete(self, result, cluster, now):
        if self.recharacterizer is not None:
            self.recharacterizer.observe_complete(
                result, cluster, now,
                use_default=self.use_default_config)

    def on_terminal(self, job, cluster, now):
        # reclaim-on-shed: the job never returns, free its cached row now
        if self.cache is not None:
            self.cache.release(job.id)

    def schedule(self, now, queue, cluster: Cluster) -> List[Assignment]:
        if not queue:
            return []
        avail = cluster.avail_array(now)
        if not avail.any():
            # nothing can start this tick; scoring the whole queue would
            # change no assignment (the placement below only dispatches
            # onto idle workers), so skip the scoring pass — the dominant
            # cost under fleet-scale backlog.  Overload control must keep
            # shedding here, though: a fully-busy fleet is exactly when
            # the queue grows, so run the O(J) doom/backpressure pass
            # against the cached minima without placing anything.
            if self.overload is not None and self.cache is not None:
                self._shed_only(now, queue, cluster)
            return []
        if self.cache is not None:
            return self._schedule_cached(now, queue, cluster, avail)
        return self._schedule_full(now, queue, cluster, avail)

    # ------------------------------------------------------------------
    # incremental path (default): cached rows + O(J) time decay

    def _schedule_cached(self, now, queue, cluster, avail):
        cd = cluster.cd
        cache = self.cache
        slots = cache.sync(cd, queue, cluster)
        t_rem = cache.t_remaining(slots, now)
        batched = getattr(cluster, "serving", "job") == "batched"
        disagg = getattr(cluster, "disaggregated", False)
        has_ttft = cache.has_ttft(slots)
        has_tpot = cache.has_tpot(slots)
        streaming = bool(has_ttft.any() or has_tpot.any())
        pen = (cluster.depth_penalty_array(now) if batched
               else np.ones(len(avail)))
        penalized = batched and bool((pen != 1.0).any())
        if streaming or disagg:
            cache.ensure_phase_rows(cd, queue, slots, cluster)
        ew = self.energy_weight
        if ew:
            cache.ensure_energy_rows(cd, queue, slots, cluster)
        if self._device:
            return self._schedule_device(now, queue, cluster, avail, slots,
                                         t_rem, pen, has_ttft, has_tpot,
                                         batched, disagg)
        if self._fused:
            return self._schedule_fused(now, queue, cluster, avail, slots,
                                        t_rem, pen, has_ttft, has_tpot,
                                        batched, disagg, streaming)
        if not (disagg or streaming):
            # the plain tick: every cached row is still exact, so only
            # Eq. 1's decay moves — urgency and doom are O(J) vector ops
            # (doomed == "no acceptable worker" == t_rem < min_w t_est)
            # and placement walks rows lazily until the slots are filled.
            # The batched depth penalty only *scales* columns (pen >= 1),
            # so doom stays decidable from the cached row minima for
            # almost every job: t_rem < min_est dooms certainly, and a
            # penalty-free argmin column acquits certainly; only jobs
            # whose cheapest worker currently runs a live batch gather
            # their row — incremental depth-penalty columns, never the
            # full [J, W] rebuild.
            min_est = cache.min_estimate(slots)
            urgency = t_rem - min_est
            doomed = t_rem < min_est
            # the shed consult uses exactly this pre-refinement mask:
            # pen >= 1 only inflates estimates, so t_rem < min_est is
            # certain doom under any batch depth — O(1) per shed against
            # the cached minima
            shed = (self.overload.consult(now, queue, doomed, urgency)
                    if self.overload is not None else None)
            if penalized:
                unsure = ~doomed & (pen[cache.argmin_estimate(slots)]
                                    != 1.0)
                if unsure.any():
                    ui = np.nonzero(unsure)[0]
                    rows = cache.t_matrix(slots[ui]) * pen[None, :]
                    doomed[ui] = ~(t_rem[ui, None] >= rows).any(axis=1)
            return self._place_lazy(now, queue, cluster, avail, cache,
                                    slots, t_rem, urgency, doomed, batched,
                                    pen if penalized else None,
                                    self._carbon_scale(cluster, now)
                                    if ew else None, skip=shed)
        # phases / deadlines re-derive the whole matrix from the cached
        # rows (still no ConfigDict gathers, no per-job Python)
        t = cache.t_matrix(slots)
        phase = np.zeros(len(queue), dtype=np.int8)
        if streaming or disagg:
            pre_m, dec_m = cache.phase_matrices(slots)
        if disagg:
            phase = np.fromiter(
                (PHASE_CODE[cluster.phase_of(j)] for j in queue),
                dtype=np.int8, count=len(queue))
            t = np.where((phase == 1)[:, None], pre_m,
                         np.where((phase == 2)[:, None], dec_m, t))
        if penalized:
            t = t * pen[None, :]
        acceptable = t_rem[:, None] >= t
        urgency = t_rem - cache.min_estimate(slots)
        if streaming:
            wait = cache.waiting(slots, now)
            ttft_qos = cache.ttft_qos(slots)
            tpot_qos = cache.tpot_qos(slots)
            dtok = cache.dtok(slots)
            ttft_rem = ttft_qos - wait
            ttft_est = pre_m * pen[None, :]
            tpot_est = dec_m * pen[None, :] / dtok[:, None]
            ok_ttft = ((~has_ttft | (phase == 2))[:, None]
                       | (ttft_est <= ttft_rem[:, None]))
            ok_tpot = ((~has_tpot | (phase == 1))[:, None]
                       | (tpot_est <= tpot_qos[:, None]))
            acceptable = acceptable & ok_ttft & ok_tpot
            with np.errstate(invalid="ignore"):
                ttft_slack = ttft_rem - np.min(ttft_est, axis=1)
            urgency = np.where(has_ttft & (phase != 2),
                               np.minimum(urgency, ttft_slack), urgency)
        doomed = ~acceptable.any(axis=1)
        # streaming/disaggregated shed predicate: "no acceptable worker
        # at all" (deadline gates folded in) — the path's own doom mask
        shed = (self.overload.consult(now, queue, doomed, urgency)
                if self.overload is not None else None)
        return self._place(now, queue, cluster, avail, t, acceptable,
                           urgency, doomed, batched, phase,
                           self._energy_cost(cache, slots, cluster, now)
                           if ew else None, skip=shed)

    def _shed_only(self, now, queue, cluster):
        """No open slot this tick, but the controller still sheds: decay
        the cached estimates and consult with the certain-doom mask (the
        same O(J) quantities the plain tick uses)."""
        cache = self.cache
        slots = cache.sync(cluster.cd, queue, cluster)
        t_rem = cache.t_remaining(slots, now)
        min_est = cache.min_estimate(slots)
        self.overload.consult(now, queue, t_rem < min_est, t_rem - min_est)

    # -- the weighted energy/carbon term -------------------------------

    def _carbon_scale(self, cluster, now):
        """[W] relative grid carbon intensity of each worker's region at
        ``now`` (None without a CarbonTrace — the term is pure energy)."""
        if self.carbon is None:
            return None
        region = getattr(cluster, "region", None)
        if region is not None:          # a hierarchy RegionView: uniform
            return np.full(len(cluster.arrays.names),
                           self.carbon.relative(region, now))
        key = (cluster.serial, cluster.worker_token)
        if key != self._regions_key:
            self._regions = tuple(
                cluster.workers[n].pool.region
                for n in cluster.arrays.names)
            self._regions_key = key
        return self.carbon.relative_for(self._regions, now)

    def _energy_cost(self, cache, slots, cluster, now):
        """[J, W] additive placement-cost term: weight x estimated job
        joules (x relative region carbon when a trace is attached)."""
        ecost = self.energy_weight * cache.energy_matrix(slots)
        scale = self._carbon_scale(cluster, now)
        if scale is not None:
            ecost = ecost * scale[None, :]
        return ecost

    def _place_lazy(self, now, queue, cluster, avail, cache, slots, t_rem,
                    urgency, doomed, batched, pen=None, cscale=None,
                    skip=None):
        """Order by (urgency, doomed) and evaluate candidate rows one at
        a time, stopping once every open slot is filled — identical
        assignments to the full masked-argmin pass (same per-row
        expressions, same tie-breaks), without materializing [J, W].
        ``pen`` (batched depth penalties, or None when every batch is
        empty) scales each row exactly like the full path's
        ``t * pen[None, :]``.  With ``energy_weight`` set, each row's
        ranking cost additionally carries the job's cached energy row
        (``cscale``: per-worker relative carbon, or None) — eligibility
        and doom stay time-derived."""
        order = np.lexsort((urgency, doomed))
        ew = self.energy_weight
        busy_wait = (cluster.busy_wait_array(now) if doomed.any()
                     else None)
        emask = {} if batched else None
        names = cluster.arrays.names
        cd = cluster.cd
        out: List[Assignment] = []
        open_slots = avail.copy()
        n_open = int(open_slots.sum())
        for ji in order:
            if skip is not None and skip[ji]:
                continue        # marked shed: the simulator drains it
            row = cache.row(slots[ji])
            if pen is not None:
                row = row * pen
            if doomed[ji]:
                feas = np.isfinite(row)
                cost = row + busy_wait
                best = np.where(feas, cost, np.inf).min()
                elig = feas & (row <= 1.5 * best)
            else:
                cost = row
                elig = t_rem[ji] >= row
            if ew:
                erow = cache.energy_row(slots[ji])
                cost = cost + (ew * erow if cscale is None
                               else ew * erow * cscale)
            open_row = open_slots
            if batched:
                eng = queue[ji].engine       # phase is "full" on this path
                m = emask.get(eng)
                if m is None:
                    m = emask[eng] = cluster.admit_engine_mask(eng, now)
                open_row = open_slots & m
            cand = np.where(open_row & elig, cost, np.inf)
            wi = int(cand.argmin())
            if np.isfinite(cand[wi]):
                w = names[wi]
                job = queue[ji]
                out.append(Assignment(job, w,
                                      cd.optimal(job.engine, w)))
                open_slots[wi] = False
                n_open -= 1
                if n_open == 0:
                    break
        return out

    # ------------------------------------------------------------------
    # device-resident path: the cache's row pools already live on the
    # accelerator, so the whole decision — gather by slot, the fused
    # scoring kernel, the urgency-ordered greedy placement — runs as one
    # ``scheduler_tick`` dispatch; the host ships only O(J + W) vectors
    # and reads back (job, worker) indices

    def _schedule_device(self, now, queue, cluster, avail, slots, t_rem,
                         pen, has_ttft, has_tpot, batched, disagg):
        cache = self.cache
        phase = np.zeros(len(queue), dtype=np.int8)
        if disagg:
            phase = np.fromiter(
                (PHASE_CODE[cluster.phase_of(j)] for j in queue),
                dtype=np.int8, count=len(queue))
        # Eq. 1 decay stays a float64 host op over the cached scalars
        # (the f32 cast of `now` itself would lose precision long before
        # the budgets do); everything [J, W]-shaped stays on-device
        ttft_rem = cache.ttft_qos(slots) - cache.waiting(slots, now)
        if batched:
            keys = {}
            masks = []
            ekey = np.empty(len(queue), np.int32)
            for qi, j in enumerate(queue):
                k = (j.engine, int(phase[qi]))
                ki = keys.get(k)
                if ki is None:
                    ki = keys[k] = len(masks)
                    masks.append(cluster.admit_engine_mask(
                        j.engine, now, PHASE_NAME[k[1]]))
                ekey[qi] = ki
            emask = np.stack(masks)
        else:
            ekey = np.zeros(len(queue), np.int32)
            emask = np.ones((1, len(avail)), bool)
        escale = None
        if self.energy_weight:
            cscale = self._carbon_scale(cluster, now)
            escale = self.energy_weight * (
                cscale if cscale is not None else np.ones(len(avail)))
        assign, order = cache.device_tick(
            slots, t_rem, ttft_rem, cache.tpot_qos(slots),
            cache.dtok(slots), has_ttft, has_tpot, phase, ekey, emask,
            pen, cluster.busy_wait_array(now), avail, escale)
        # overload control on the device path: the kernel has already
        # placed, so the host-side consult (cached certain-doom mask)
        # only filters the emitted assignments — a shed job's slot idles
        # one tick, which is the price of keeping the kernel unchanged
        shed = None
        if self.overload is not None:
            min_est = cache.min_estimate(slots)
            shed = self.overload.consult(now, queue, t_rem < min_est,
                                         t_rem - min_est)
        names = cluster.arrays.names
        cd = cluster.cd
        J = len(queue)
        out: List[Assignment] = []
        for ji in order:        # same emit order as _place's sorted walk
            if ji >= J:
                continue
            if shed is not None and shed[ji]:
                continue
            wi = int(assign[ji])
            if wi >= 0:
                job = queue[ji]
                out.append(Assignment(job, names[wi],
                                      cd.optimal(job.engine, names[wi])))
        return out

    # ------------------------------------------------------------------
    # fused Pallas path: depth penalty + phase split + streaming gates
    # run inside the kernel; the cache supplies its input matrices

    def _schedule_fused(self, now, queue, cluster, avail, slots, t_rem,
                        pen, has_ttft, has_tpot, batched, disagg,
                        streaming):
        cache = self.cache
        t0 = cache.t_matrix(slots)
        if streaming or disagg:
            pre_m, dec_m = cache.phase_matrices(slots)
        else:
            pre_m = dec_m = t0      # gates are off: placeholders
        phase = np.zeros(len(queue), dtype=np.int8)
        if disagg:
            phase = np.fromiter(
                (PHASE_CODE[cluster.phase_of(j)] for j in queue),
                dtype=np.int8, count=len(queue))
        ttft_rem = cache.ttft_qos(slots) - cache.waiting(slots, now)
        t, acceptable, urgency, doomed = self.score_fn(
            t0, pre_m, dec_m, t_rem, pen, phase, has_ttft, has_tpot,
            ttft_rem, cache.tpot_qos(slots), cache.dtok(slots))
        shed = (self.overload.consult(now, queue, doomed, urgency)
                if self.overload is not None else None)
        return self._place(now, queue, cluster, avail, t, acceptable,
                           urgency, doomed, batched, phase,
                           self._energy_cost(cache, slots, cluster, now)
                           if self.energy_weight else None, skip=shed)

    # ------------------------------------------------------------------
    # reference path: full [J, W] rebuild every tick (incremental=False,
    # or a conventional custom score_fn)

    def _schedule_full(self, now, queue, cluster, avail):
        workers = cluster.arrays.names
        kw = {}
        if self._takes_token:
            kw["token"] = cluster.worker_token
        if self._takes_profile and self.profile:
            kw["profile"] = self.profile
        score = self.score_fn(cluster.cd, queue, workers, now,
                              use_default=False, **kw)
        t = score.t_estimated
        doomed = score.doomed
        acceptable = score.acceptable
        urgency = score.urgency
        t_rem = score.t_remaining
        batched = getattr(cluster, "serving", "job") == "batched"
        disagg = getattr(cluster, "disaggregated", False)
        reqs = [j.request for j in queue]
        has_ttft = np.fromiter((r is not None and r.ttft_qos is not None
                                for r in reqs), dtype=bool, count=len(reqs))
        has_tpot = np.fromiter((r is not None and r.tpot_qos is not None
                                for r in reqs), dtype=bool, count=len(reqs))
        streaming = bool(has_ttft.any() or has_tpot.any())
        changed = False
        pen = np.ones(len(workers))
        phase = np.zeros(len(queue), dtype=np.int8)   # PHASE_CODE values
        if disagg or streaming:
            pre_m, dec_m = phase_split_matrices(cluster.cd, queue, workers,
                                                use_default=False,
                                                token=cluster.worker_token,
                                                profile=self.profile)
        if disagg:
            # phase-aware service times: a prefill-phase job costs a
            # worker only its prefill prefix, a decode-phase job only the
            # decode remainder (the handoff already happened)
            phase = np.fromiter(
                (PHASE_CODE[cluster.phase_of(j)] for j in queue),
                dtype=np.int8, count=len(queue))
            t = np.where((phase == 1)[:, None], pre_m,
                         np.where((phase == 2)[:, None], dec_m, t))
            changed = True
        if batched:
            # queue-depth-adjusted latency: joining a live batch divides
            # the job's service rate; re-derive Eq. 3/4 from the
            # penalized estimates (identical to the plain path whenever
            # every batch is empty, e.g. max_batch=1 with free workers)
            pen = cluster.depth_penalty_array(now)
            if (pen != 1.0).any():
                t = t * pen[None, :]
                changed = True
        if changed:
            acceptable = t_rem[:, None] >= t
        if streaming:
            # gate on the tighter of (latency, TTFT, TPOT) headroom: a
            # worker is acceptable only if every deadline the job carries
            # survives its estimates.  The TTFT budget decays with waiting
            # like t_remaining; TPOT is a pure rate constraint.  A decode-
            # phase job's TTFT is already history, a prefill-phase job's
            # TPOT belongs to its later decode placement.
            engines = engine_catalogue()
            wait = np.fromiter((now - j.arrival for j in queue),
                               dtype=np.float64, count=len(queue))
            ttft_qos = np.array([r.ttft_qos if r is not None and
                                 r.ttft_qos is not None else np.inf
                                 for r in reqs])
            tpot_qos = np.array([r.tpot_qos if r is not None and
                                 r.tpot_qos is not None else np.inf
                                 for r in reqs])
            # per-token rate uses the engine-default token count (dec_m
            # is the profile-shape decode time, so the ratio is exactly
            # the simulator's solo decode_frac/(qps*decode_len) — the
            # sampled Request length cancels out of a per-token metric)
            dtok = np.array([float(j.queries * engines[j.engine].decode_len)
                             if j.engine in engines
                             else (float(r.decode_tokens)
                                   if r is not None and r.decode_tokens > 0
                                   else np.inf)
                             for j, r in zip(queue, reqs)])
            ttft_rem = ttft_qos - wait
            ttft_est = pre_m * pen[None, :]
            tpot_est = dec_m * pen[None, :] / dtok[:, None]
            ok_ttft = ((~has_ttft | (phase == 2))[:, None]
                       | (ttft_est <= ttft_rem[:, None]))
            ok_tpot = ((~has_tpot | (phase == 1))[:, None]
                       | (tpot_est <= tpot_qos[:, None]))
            acceptable = acceptable & ok_ttft & ok_tpot
            # a tight TTFT can be the binding urgency even when the e2e
            # budget is comfortable
            with np.errstate(invalid="ignore"):
                ttft_slack = ttft_rem - np.min(ttft_est, axis=1)
            urgency = np.where(has_ttft & (phase != 2),
                               np.minimum(urgency, ttft_slack), urgency)
            changed = True
        if changed:
            doomed = ~acceptable.any(axis=1)
        ecost = None
        if self.energy_weight:
            ecost = self.energy_weight * energy_matrix(
                cluster.cd, queue, workers, use_default=False,
                token=cluster.worker_token, profile=self.profile)
            scale = self._carbon_scale(cluster, now)
            if scale is not None:
                ecost = ecost * scale[None, :]
        shed = (self.overload.consult(now, queue, doomed, urgency)
                if self.overload is not None else None)
        return self._place(now, queue, cluster, avail, t, acceptable,
                           urgency, doomed, batched, phase, ecost,
                           skip=shed)

    # ------------------------------------------------------------------
    # shared placement tail (full-matrix variant)

    def _place(self, now, queue, cluster, avail, t, acceptable, urgency,
               doomed, batched, phase, ecost=None, skip=None):
        # order: urgent first (2D Ordered Job Queue); doomed jobs last.
        # lexsort is stable, so ties keep queue order like sorted() did.
        order = np.lexsort((urgency, doomed))
        # per-job candidate cost + eligibility (the sorted (w, c*) list):
        # non-doomed jobs walk their *acceptable* workers by T_estimated;
        # doomed jobs minimize expected completion (wait + exec) over all
        # feasible workers, restricted to options within 1.5x of the best
        # so a doomed job waits for a fast worker instead of seizing a far
        # slower idle one and blocking it for everyone else.
        feasible = np.isfinite(t)
        if doomed.any():
            busy_wait = cluster.busy_wait_array(now)
            cost = np.where(doomed[:, None], t + busy_wait[None, :], t)
            best_cost = np.where(feasible, cost, np.inf).min(axis=1)
            elig = np.where(doomed[:, None],
                            feasible & (t <= 1.5 * best_cost[:, None]),
                            acceptable)
        else:
            cost = t
            elig = acceptable
        if ecost is not None:
            # the weighted energy/carbon term joins the *ranking* cost
            # only — eligibility, doom and the doomed 1.5x gate above are
            # already fixed from the time estimates
            cost = cost + ecost
        if batched:
            # batch-formation rules: a live batch only admits its own
            # engine, under the slot and KV budgets — and, under
            # disaggregated pools, the phase-role match (one O(W) vector
            # mask per distinct (engine, phase) key, reusing the phase
            # codes computed above instead of re-deriving them per job)
            emask = {}
            rows = []
            for qi, j in enumerate(queue):
                k = (j.engine, int(phase[qi]))
                m = emask.get(k)
                if m is None:
                    m = emask[k] = cluster.admit_engine_mask(
                        j.engine, now, PHASE_NAME[k[1]])
                rows.append(m)
            elig = elig & np.stack(rows)
        ranked = np.where(elig, cost, np.inf)
        # jobs with no eligible idle worker can never place this round
        live = np.isfinite(ranked[:, avail]).any(axis=1)

        names = cluster.arrays.names
        cd = cluster.cd
        out: List[Assignment] = []
        open_slots = avail.copy()
        n_open = int(open_slots.sum())
        for ji in order:
            if not live[ji] or (skip is not None and skip[ji]):
                continue
            cand = np.where(open_slots, ranked[ji], np.inf)
            wi = int(cand.argmin())
            if np.isfinite(cand[wi]):
                w = names[wi]
                job = queue[ji]
                out.append(Assignment(job, w, cd.optimal(job.engine, w)))
                open_slots[wi] = False
                n_open -= 1
                if n_open == 0:
                    break
        return out
