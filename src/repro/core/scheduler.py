"""SynergAI online scheduler (paper §4.2).

QoS-aware run-time scheduling: the queue is continuously re-scored with the
vectorized Eq. 1-4 estimator, ordered by urgency (descending risk), doomed
jobs are de-prioritized to the tail, and each dequeued job walks its sorted
(worker, c*) candidate list to the first available worker.  A periodic
update (simulator tick) reassesses all waiting jobs.

Unlike every baseline, assignments use the *optimal* per-(engine, worker)
configuration c*_{j,w} from the offline Configuration Dictionary.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.estimator import candidate_order, estimate_matrix
from repro.core.simulator import Assignment, Cluster, Policy


class SynergAI(Policy):
    name = "SynergAI"
    use_default_config = False

    def __init__(self, score_fn=None):
        # score_fn: optional accelerated scorer (Pallas kernel at fleet
        # scale); defaults to the numpy estimator.
        self.score_fn = score_fn or estimate_matrix

    def schedule(self, now, queue, cluster: Cluster) -> List[Assignment]:
        if not queue:
            return []
        workers = list(cluster.workers)
        score = self.score_fn(cluster.cd, queue, workers, now,
                              use_default=False)
        busy_wait = np.array([max(0.0, cluster.workers[w].busy_until - now,
                                  cluster.workers[w].failed_until - now)
                              for w in workers])
        # order: urgent first (2D Ordered Job Queue); doomed jobs last
        order = sorted(range(len(queue)),
                       key=lambda ji: (bool(score.doomed[ji]),
                                       float(score.urgency[ji])))
        out: List[Assignment] = []
        taken = set()
        any_idle = set(cluster.idle_workers(now))
        for ji in order:
            job = queue[ji]
            cands = candidate_order(score, ji, busy_wait)
            if score.doomed[ji] and cands:
                # a doomed job minimizes expected completion: it dispatches
                # to an idle worker only if that is within 1.5x of the best
                # (wait + exec) option; otherwise it waits for the fast one
                best_cost = (score.t_estimated[ji][cands[0]]
                             + busy_wait[cands[0]])
                cands = [w for w in cands
                         if score.t_estimated[ji][w] <= 1.5 * best_cost]
            for wi in cands:
                w = workers[wi]
                if w in taken or w not in any_idle:
                    continue
                ent = cluster.cd.optimal(job.engine, w)
                out.append(Assignment(job, w, ent))
                taken.add(w)
                break
            if len(taken) == len(any_idle):
                break
        return out
