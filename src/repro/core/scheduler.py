"""SynergAI online scheduler (paper §4.2).

QoS-aware run-time scheduling: the queue is continuously re-scored with the
vectorized Eq. 1-4 estimator, ordered by urgency (descending risk), doomed
jobs are de-prioritized to the tail, and each dequeued job walks its sorted
(worker, c*) candidate list to the first available worker.  A periodic
update (simulator tick) reassesses all waiting jobs.

Unlike every baseline, assignments use the *optimal* per-(engine, worker)
configuration c*_{j,w} from the offline Configuration Dictionary.

The placement pass is fully vectorized for fleet scale (thousands of queued
jobs x hundreds of pools): per-job candidate walks become masked argmins
over a shared cost matrix — provably the same assignment as walking the
stable-sorted candidate list, since ``argmin`` breaks ties at the lowest
worker index exactly like a stable sort does.  ``score_fn`` swaps the
scoring backend: the numpy estimator by default, or the Pallas kernel via
``repro.core.pallas_scoring.make_pallas_score_fn``.

Under the batched serving bridge (``Simulator(..., serving="batched")``)
the estimates become *queue-depth-aware*: every worker's column is scaled
by ``Cluster.depth_penalty`` (joining a batch of ``b`` members runs
``1 + alpha * b`` slower than solo), acceptability and doom are
re-derived from the adjusted times, and eligibility is intersected with
the bridge's batch-formation rules (same-engine batches under slot/KV
budgets) via ``Cluster.admit_engine_ok``.

Streaming QoS (``Request.ttft_qos`` / ``tpot_qos``) tightens the gate
further: acceptability requires the *tighter* of the end-to-end, TTFT and
TPOT headrooms to survive (``estimator.phase_split_matrices`` supplies the
prefill/decode split of Eq. 2), and a scarce TTFT budget can become the
binding urgency.  Under prefill/decode-disaggregated pools
(``WorkerPool.role``) each phase is placed independently: phase-sliced
service times, role-gated eligibility.  With no deadlines and no role
tags every addition is inert and the schedule is unchanged bit-for-bit.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.estimator import estimate_matrix, phase_split_matrices
from repro.core.simulator import Assignment, Cluster, Policy


class SynergAI(Policy):
    name = "SynergAI"
    use_default_config = False

    def __init__(self, score_fn=None):
        # score_fn: optional accelerated scorer (Pallas kernel at fleet
        # scale); defaults to the numpy estimator.
        self.score_fn = score_fn or estimate_matrix

    def schedule(self, now, queue, cluster: Cluster) -> List[Assignment]:
        if not queue:
            return []
        workers = list(cluster.workers)
        avail = np.array([cluster.workers[w].idle(now) for w in workers])
        if not avail.any():
            # nothing can start this tick; scoring the whole queue would
            # change no assignment (the placement below only dispatches
            # onto idle workers), so skip the [J, W] pass — the dominant
            # cost under fleet-scale backlog.
            return []
        score = self.score_fn(cluster.cd, queue, workers, now,
                              use_default=False)
        busy_wait = np.array([max(0.0, cluster.workers[w].busy_until - now,
                                  cluster.workers[w].failed_until - now)
                              for w in workers])
        t = score.t_estimated
        doomed = score.doomed
        acceptable = score.acceptable
        urgency = score.urgency
        batched = getattr(cluster, "serving", "job") == "batched"
        disagg = getattr(cluster, "disaggregated", False)
        reqs = [j.request for j in queue]
        has_ttft = np.fromiter((r is not None and r.ttft_qos is not None
                                for r in reqs), dtype=bool, count=len(reqs))
        has_tpot = np.fromiter((r is not None and r.tpot_qos is not None
                                for r in reqs), dtype=bool, count=len(reqs))
        streaming = bool(has_ttft.any() or has_tpot.any())
        changed = False
        pen = np.ones(len(workers))
        phase = np.zeros(len(queue), dtype=np.int8)   # 0 full/1 prefill/2 decode
        if disagg or streaming:
            pre_m, dec_m = phase_split_matrices(cluster.cd, queue, workers,
                                                use_default=False)
        if disagg:
            # phase-aware service times: a prefill-phase job costs a
            # worker only its prefill prefix, a decode-phase job only the
            # decode remainder (the handoff already happened)
            phase = np.fromiter(
                ({"full": 0, "prefill": 1, "decode": 2}[
                    cluster.phase_of(j)] for j in queue),
                dtype=np.int8, count=len(queue))
            t = np.where((phase == 1)[:, None], pre_m,
                         np.where((phase == 2)[:, None], dec_m, t))
            changed = True
        if batched:
            # queue-depth-adjusted latency: joining a live batch divides
            # the job's service rate; re-derive Eq. 3/4 from the
            # penalized estimates (identical to the plain path whenever
            # every batch is empty, e.g. max_batch=1 with free workers)
            pen = np.array([cluster.depth_penalty(w, now)
                            for w in workers])
            if (pen != 1.0).any():
                t = t * pen[None, :]
                changed = True
        if changed:
            acceptable = score.t_remaining[:, None] >= t
        if streaming:
            # gate on the tighter of (latency, TTFT, TPOT) headroom: a
            # worker is acceptable only if every deadline the job carries
            # survives its estimates.  The TTFT budget decays with waiting
            # like t_remaining; TPOT is a pure rate constraint.  A decode-
            # phase job's TTFT is already history, a prefill-phase job's
            # TPOT belongs to its later decode placement.
            from repro.core.engines import engine_catalogue
            engines = engine_catalogue()
            wait = np.fromiter((now - j.arrival for j in queue),
                               dtype=np.float64, count=len(queue))
            ttft_qos = np.array([r.ttft_qos if r is not None and
                                 r.ttft_qos is not None else np.inf
                                 for r in reqs])
            tpot_qos = np.array([r.tpot_qos if r is not None and
                                 r.tpot_qos is not None else np.inf
                                 for r in reqs])
            # per-token rate uses the engine-default token count (dec_m
            # is the profile-shape decode time, so the ratio is exactly
            # the simulator's solo decode_frac/(qps*decode_len) — the
            # sampled Request length cancels out of a per-token metric)
            dtok = np.array([float(j.queries * engines[j.engine].decode_len)
                             if j.engine in engines
                             else (float(r.decode_tokens)
                                   if r is not None and r.decode_tokens > 0
                                   else np.inf)
                             for j, r in zip(queue, reqs)])
            ttft_rem = ttft_qos - wait
            ttft_est = pre_m * pen[None, :]
            tpot_est = dec_m * pen[None, :] / dtok[:, None]
            ok_ttft = ((~has_ttft | (phase == 2))[:, None]
                       | (ttft_est <= ttft_rem[:, None]))
            ok_tpot = ((~has_tpot | (phase == 1))[:, None]
                       | (tpot_est <= tpot_qos[:, None]))
            acceptable = acceptable & ok_ttft & ok_tpot
            # a tight TTFT can be the binding urgency even when the e2e
            # budget is comfortable
            with np.errstate(invalid="ignore"):
                ttft_slack = ttft_rem - np.min(ttft_est, axis=1)
            urgency = np.where(has_ttft & (phase != 2),
                               np.minimum(urgency, ttft_slack), urgency)
            changed = True
        if changed:
            doomed = ~acceptable.any(axis=1)
        # order: urgent first (2D Ordered Job Queue); doomed jobs last.
        # lexsort is stable, so ties keep queue order like sorted() did.
        order = np.lexsort((urgency, doomed))
        # per-job candidate cost + eligibility (the sorted (w, c*) list):
        # non-doomed jobs walk their *acceptable* workers by T_estimated;
        # doomed jobs minimize expected completion (wait + exec) over all
        # feasible workers, restricted to options within 1.5x of the best
        # so a doomed job waits for a fast worker instead of seizing a far
        # slower idle one and blocking it for everyone else.
        feasible = np.isfinite(t)
        if doomed.any():
            cost = np.where(doomed[:, None], t + busy_wait[None, :], t)
            best_cost = np.where(feasible, cost, np.inf).min(axis=1)
            elig = np.where(doomed[:, None],
                            feasible & (t <= 1.5 * best_cost[:, None]),
                            acceptable)
        else:
            cost = t
            elig = acceptable
        if batched:
            # batch-formation rules: a live batch only admits its own
            # engine, under the slot and KV-cache budgets — and, under
            # disaggregated pools, the phase-role match
            keys = {(j.engine, cluster.phase_of(j)) for j in queue}
            emask = {k: np.fromiter(
                (cluster.admit_engine_ok(k[0], w, now, phase=k[1])
                 for w in workers), dtype=bool, count=len(workers))
                for k in keys}
            elig = elig & np.stack(
                [emask[(j.engine, cluster.phase_of(j))] for j in queue])
        ranked = np.where(elig, cost, np.inf)
        # jobs with no eligible idle worker can never place this round
        live = np.isfinite(ranked[:, avail]).any(axis=1)

        out: List[Assignment] = []
        open_slots = avail.copy()
        n_open = int(open_slots.sum())
        for ji in order:
            if not live[ji]:
                continue
            cand = np.where(open_slots, ranked[ji], np.inf)
            wi = int(cand.argmin())
            if np.isfinite(cand[wi]):
                w = workers[wi]
                job = queue[ji]
                out.append(Assignment(job, w, cluster.cd.optimal(job.engine,
                                                                 w)))
                open_slots[wi] = False
                n_open -= 1
                if n_open == 0:
                    break
        return out
