"""Discrete-event cluster simulator for scheduler evaluation (paper §5).

Jobs run in strict isolation on their assigned worker (paper §5.1: "all jobs
scheduled and executed in strict isolation ... zero interference").  The
simulator also implements the fault-tolerance extensions (worker failure,
straggler slowdown, elastic pool membership) used by the robustness tests.

The engine is *event-indexed*: a single ``heapq`` holds every future
wake-up (job arrival, job completion, worker failure, failure recovery,
elastic-provision completion) so advancing time is O(log n) instead of the
seed's per-iteration rescan of every worker, failure and running job.
Entries whose underlying state changed (a speculated job's new finish time,
a killed job, a retired clone) are invalidated lazily at pop time, which
keeps the wake sequence — and therefore the simulated schedule — identical
to the reference tick-scanning loop preserved in
``repro.core.simulator_legacy.LegacySimulator``.  Fleet-scale runs
(10k jobs x 64 pools) complete in seconds; see
``benchmarks/scheduler_experiments.py`` for the old-vs-new comparison.

Two serving models share the engine (``Simulator(..., serving=...)``):

* ``"job"`` (default, the paper's model) — a job occupies its worker
  exclusively for ``exec_time`` seconds.
* ``"batched"`` — the serving bridge (``repro.core.serving_bridge``):
  workers run continuous batches of same-engine jobs under max-batch and
  KV-cache-byte budgets, a prefill phase plus per-token decode draining at
  the profile-calibrated token rates, and every batch change re-estimates
  member completions through the event heap.  ``BatchedWorkerSim`` below
  holds the per-worker batch state; the profile math lives in the bridge
  module.

Both modes report *streaming QoS* per request — ``JobResult.ttft``
(arrival to first decoded token) and ``JobResult.tpot`` (seconds per
decoded token after it) — and enforce the optional per-job deadlines on
``Request.ttft_qos`` / ``tpot_qos``.  Batched mode additionally supports
*prefill/decode-disaggregated pools* (``WorkerPool.role``): jobs run a
prefill phase on a prefill pool, re-enter the queue as an
independently-placed decode phase, and pull their parked KV cache over
the disaggregation link (``serving_bridge.kv_transfer_s``) at decode
admission — free when the decode leg lands back on the same
``role="both"`` pool.  Design note: ``docs/serving_bridge.md``.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.configdict import ConfigDict, Entry
from repro.core.job import Job, Request, exec_time
from repro.core.serving_bridge import batch_multiplier
from repro.core.workers import WorkerPool, default_fleet


@dataclasses.dataclass
class WorkerSim:
    pool: WorkerPool
    busy_until: float = 0.0
    last_freed: float = 0.0
    last_assigned: float = -math.inf
    energy_j: float = 0.0
    n_jobs: int = 0
    busy_s: float = 0.0
    failed_until: float = 0.0      # fault injection
    slowdown: float = 1.0          # straggler injection
    # static-floor joules burned while parked (idle/static power floor,
    # constants.IDLE_POWER_FRACTION) — settled once by Simulator.run at
    # end of run, kept separate so ``energy_j`` stays "active energy"
    # (the paper's Fig. 12 TDP methodology)
    idle_energy_j: float = 0.0

    @property
    def total_energy_j(self) -> float:
        return self.energy_j + self.idle_energy_j

    def __setattr__(self, name, value):
        # write-through into the Cluster's struct-of-arrays mirror
        # (attached lazily by Cluster._build_arrays): scalar state stays
        # authoritative on the instance, the arrays feed the schedulers'
        # O(W) vector ops.  A failure write also bumps the cluster's
        # failure generation, the score-cache invalidation signal.
        object.__setattr__(self, name, value)
        if name == "busy_until":
            a = self.__dict__.get("_arrays")
            if a is not None:
                a.busy_until[self._aidx] = value
        elif name == "failed_until":
            a = self.__dict__.get("_arrays")
            if a is not None:
                a.failed_until[self._aidx] = value
            c = self.__dict__.get("_cluster")
            if c is not None:
                c._fail_gen += 1

    def idle(self, now: float) -> bool:
        return self.busy_until <= now and self.failed_until <= now


@dataclasses.dataclass
class _InFlight:
    """One continuous-batch member, tracked in solo-equivalent service
    seconds: ``work_s`` total, ``served_s`` done so far (drains at
    ``m(b)`` of the solo rate).  ``prefill_s`` marks the boundary between
    the admission+prefill prefix and the per-token decode phase, matching
    the real engine's prefill-then-decode loop
    (``repro.serving.engine``).  ``prefill_done_at`` is the wall time the
    member crossed that boundary — the first decoded token, interpolated
    exactly inside ``accrue`` (the drain rate is constant between batch
    events) and the source of the per-request TTFT."""

    jid: int
    work_s: float
    prefill_s: float
    request: Request
    served_s: float = 0.0
    prefill_done_at: Optional[float] = None

    @property
    def remaining_s(self) -> float:
        return self.work_s - self.served_s


@dataclasses.dataclass
class BatchedWorkerSim(WorkerSim):
    """Continuous-batching service model for one worker pool (the serving
    bridge, ``serving="batched"``; profile math in
    ``repro.core.serving_bridge``).

    Replaces exclusive occupancy with an active batch of same-engine
    jobs.  ``idle`` means "can admit another member"; ``busy_until``
    tracks the earliest slot-free time while the batch is full (so
    policies' backlog estimates keep working) and the provisioning delay
    of elastic clones."""

    max_batch: int = 8
    alpha_override: Optional[float] = None
    active: Dict[int, _InFlight] = dataclasses.field(default_factory=dict)
    last_progress: float = 0.0
    batch_engine: Optional[str] = None
    batch_entry: Optional[Entry] = None
    batch_alpha_: float = 0.5
    kv_limit: int = 1
    kv_job_bytes: float = 0.0
    # serving stats (EngineStats analogue at fleet scale)
    admitted: int = 0
    peak_batch: int = 0
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    abandoned: int = 0
    # WAN-transfer seconds folded into members' service (cross-region
    # input shipping, KV handoffs) still pending their energy re-rate:
    # the chips idle while the wire moves bytes, so ``accrue`` bills the
    # next ``xfer_debt_s`` wall-seconds at the batch entry's static floor
    # instead of its full draw.  ``xfer_idle_s`` counts the seconds
    # already re-rated (energy-conservation tests reconcile with it).
    xfer_debt_s: float = 0.0
    xfer_idle_s: float = 0.0

    def _has_slot(self) -> bool:
        return (not self.active
                or len(self.active) < min(self.max_batch, self.kv_limit))

    def _sync_batch(self):
        """Mirror the batch state (depth, slot budget, engine lock,
        alpha) into the cluster's struct-of-arrays after every membership
        change — ``active`` is a dict, so ``__setattr__`` can't see it."""
        a = self.__dict__.get("_arrays")
        if a is None:
            return
        i = self._aidx
        a.depth[i] = len(self.active)
        a.slot_cap[i] = min(self.max_batch, self.kv_limit)
        eng = self.batch_engine
        a.engine_id[i] = (-1 if eng is None
                          else self._cluster.engine_code(eng))
        a.alpha[i] = self.batch_alpha_

    def idle(self, now: float) -> bool:
        return (self.busy_until <= now and self.failed_until <= now
                and self._has_slot())

    def can_admit(self, engine: str, now: float) -> bool:
        return self.idle(now) and (self.batch_engine is None
                                   or self.batch_engine == engine)

    def multiplier(self, b: Optional[int] = None) -> float:
        return batch_multiplier(self.batch_alpha_,
                                len(self.active) if b is None else b)

    def accrue(self, now: float):
        """Drain every member by the elapsed wall time at the current
        batch multiplier; account busy time and energy (the whole batch
        shares one engine's power draw — batching's energy win)."""
        dt = now - self.last_progress
        self.last_progress = now
        if not self.active or dt <= 0:
            return
        m = self.multiplier()
        t0 = now - dt
        for f in self.active.values():
            before = f.served_s
            f.served_s = min(f.work_s, before + dt * m)
            if f.prefill_done_at is None and f.served_s >= f.prefill_s:
                # first token: the drain rate is constant over [t0, now],
                # so the prefill-boundary crossing interpolates exactly
                f.prefill_done_at = t0 + (f.prefill_s - before) / m
        self.busy_s += dt
        self.energy_j += self.batch_entry.power_w * dt
        if self.xfer_debt_s > 0.0:
            # re-rate pending WAN-transfer seconds at the idle floor
            pay = min(self.xfer_debt_s, dt)
            self.energy_j -= ((self.batch_entry.power_w
                               - self.batch_entry.idle_power_w) * pay)
            self.xfer_debt_s -= pay
            self.xfer_idle_s += pay

    def admit(self, now: float, jid: int, engine: str, entry: Entry,
              prof, request: Request, work_s: float, prefill_s: float):
        assert self.batch_engine in (None, engine), "mixed-engine batch"
        if not self.active:
            self.batch_engine = engine
            self.batch_entry = entry
            self.batch_alpha_ = (self.alpha_override
                                 if self.alpha_override is not None
                                 else prof.alpha)
            self.kv_limit = prof.kv_limit
            self.kv_job_bytes = prof.kv_job_bytes
            self.last_progress = now
        f = _InFlight(jid, work_s, prefill_s, request)
        if prefill_s <= 0.0:        # decode-only phase: first token is past
            f.prefill_done_at = now
        self.active[jid] = f
        self.admitted += 1
        self.peak_batch = max(self.peak_batch, len(self.active))
        self._sync_batch()

    def finish(self, jid: int) -> Optional[_InFlight]:
        """Retire a fully-served member; tokens count here and only here,
        so a member killed by a failure mid-flight contributes nothing
        (its re-dispatch counts once, wherever it completes)."""
        f = self.active.pop(jid, None)
        if f is not None:
            self.prefill_tokens += f.request.prompt_tokens
            self.decoded_tokens += f.request.decode_tokens
        if not self.active:
            self.batch_engine = None
            self.batch_entry = None
        self._sync_batch()
        return f

    def abandon(self, jid: int) -> Optional[_InFlight]:
        """A member's client hung up mid-batch: the member leaves and its
        partial service is lost.  Tokens only count in ``finish``, so an
        abandoned member contributes nothing to the worker's token
        totals — exact token conservation, same rule as a failure kill.
        Callers must ``accrue(now)`` first and ``_rebatch`` after (the
        survivors speed up)."""
        f = self.active.pop(jid, None)
        if f is not None:
            self.abandoned += 1
        if not self.active:
            self.batch_engine = None
            self.batch_entry = None
        self._sync_batch()
        return f

    def on_failure(self, now: float):
        """Worker died: partial service is lost, the batch resets (the
        simulator re-queues every killed member for checkpoint-restart)."""
        self.accrue(now)
        self.active.clear()
        self.batch_engine = None
        self.batch_entry = None
        self.xfer_debt_s = 0.0     # the transfers died with the batch
        self._sync_batch()


@dataclasses.dataclass
class Assignment:
    job: Job
    worker: str
    entry: Entry
    # cross-region placement surcharge (repro/core/hierarchy.py): seconds
    # of inter-region input shipping (REGION_XFER link) charged ahead of
    # the job's service.  0.0 — the default every flat policy uses —
    # changes nothing bit-for-bit.
    xfer_s: float = 0.0


@dataclasses.dataclass
class JobResult:
    job: Job
    worker: str
    config: str
    start: float
    end: float
    waiting: float
    exec_s: float
    e2e: float
    violated: bool
    excess: float
    overhead_s: float
    decision_s: float
    speculated: bool = False
    # streaming QoS (both serving modes): seconds from arrival to the
    # first decoded token, and average seconds per decoded token after it.
    # Under disaggregated pools the transfer + decode-queue time lands in
    # ``tpot`` (TTFT is the prefill pool's first token).  ``violated``
    # ORs the streaming deadline misses in; with no deadlines set the
    # *_violated flags stay False and ``violated`` keeps its end-to-end
    # meaning bit-for-bit.
    ttft: float = math.nan
    tpot: float = math.nan
    ttft_violated: bool = False
    tpot_violated: bool = False
    prefill_worker: Optional[str] = None   # disaggregated: prefill pool
    # solo service seconds: slowdown- and noise-scaled service time
    # excluding batch contention, cross-region transfer and queueing —
    # what the worker's *physics* cost, which is the observable online
    # re-characterization fits drift from (``exec_s`` is stretched by
    # the live batch multiplier under ``serving="batched"``, so profile
    # drift and load contention would be confounded there).  Spans both
    # legs of a disaggregated job.
    service_s: float = math.nan
    # the offline profile's prediction for the same solo service (no
    # slowdown, no noise): what a real serving stack knows about each
    # request from its characterization tables.  ``service_s /
    # service_pred_s`` is therefore exactly ``slowdown * exec noise`` —
    # the drift observable, free of service-model approximation error.
    service_pred_s: float = math.nan
    # terminal outcome taxonomy (docs/robustness.md).  ``""`` means the
    # job was actually served — ``metrics.outcome_of`` refines that into
    # ``"completed"`` / ``"violated"`` from the flags above.  The
    # overload-control layer writes the non-served outcomes: ``"shed"``
    # (dropped by the OverloadController), ``"abandoned"`` (client
    # patience expired in queue), ``"failed"`` (retry budget exhausted).
    outcome: str = ""


@dataclasses.dataclass
class FailureEvent:
    worker: str
    at: float
    duration: float


@dataclasses.dataclass
class LinkFailureEvent:
    """A WAN partition between two regions: the ``REGION_XFER`` link
    connecting regions ``a`` and ``b`` (both directions) is severed for
    ``[at, at + duration)``.  While active, the hierarchical scheduler
    masks the pair out of cross-region spillover
    (``RegionRouter.blocked_regions``) and a disaggregated decode leg
    trying to pull its KV cache across the dead link loses the cache —
    the job restarts from prefill under its retry budget.  Intra-region
    traffic is unaffected; fleets without region tags never see one."""

    a: str
    b: str
    at: float
    duration: float


@dataclasses.dataclass
class RetryEvent:
    """Bookkeeping for one backoff re-entry scheduled on the event heap
    (``Simulator.retry_events``): the job re-joins the scan queue at
    ``at``.  ``attempt`` counts failure-driven re-executions so far (0
    for an outage-parking entry, which consumes no budget)."""

    job_id: int
    at: float
    attempt: int


@dataclasses.dataclass
class DegradationEvent:
    """A worker running slower than its offline profile for a window:
    thermal throttling, a colocated tenant, a driver regression.  The
    worker keeps serving (unlike a ``FailureEvent``) at ``factor``x its
    characterized service time — and *nothing tells the policies*: the
    profiles in the ConfigDict still describe the healthy device, so
    estimates on the degraded rows are silently wrong until an online
    re-characterization (``repro.core.recharacterize``) corrects the
    beliefs.  Overlapping windows on one worker compose
    multiplicatively."""

    worker: str
    at: float
    duration: float
    factor: float = 3.0


# pool roles / serving phases as small ints for the vectorized masks.
# ROLE_CODE["both"] == PHASE_CODE["full"] == 0, so the role gate is the
# single vector op ``(role == 0) | (role == PHASE_CODE[phase])``: a
# whole-job placement only passes "both" pools, a phase-sliced one its
# matching specialized pools plus "both" — exactly ``Cluster.role_ok``.
ROLE_CODE = {"both": 0, "prefill": 1, "decode": 2}
PHASE_CODE = {"full": 0, "prefill": 1, "decode": 2}
PHASE_NAME = {0: "full", 1: "prefill", 2: "decode"}


@dataclasses.dataclass(eq=False)
class _FleetArrays:
    """Struct-of-arrays mirror of ``Cluster.workers`` (docs/performance.md).

    One slot per worker, in dict insertion order.  ``busy_until`` /
    ``failed_until`` are written through by ``WorkerSim.__setattr__``,
    the batch columns by ``BatchedWorkerSim._sync_batch``; membership
    changes (elastic clones) rebuild the whole mirror lazily.  Schedulers
    read these for O(W) vector availability / penalty / admission masks
    instead of Python loops over the worker dict."""

    names: List[str]
    index: Dict[str, int]
    busy_until: np.ndarray        # [W] f64
    failed_until: np.ndarray      # [W] f64
    role: np.ndarray              # [W] i8, ROLE_CODE of pool.role
    depth: np.ndarray             # [W] i32, live batch size (0 in job mode)
    slot_cap: np.ndarray          # [W] i32, min(max_batch, kv_limit)
    engine_id: np.ndarray         # [W] i32, interned batch engine (-1 none)
    alpha: np.ndarray             # [W] f64, live batch_alpha_


class _WorkerDict(dict):
    """``Cluster.workers``: a plain dict plus membership hooks, so adding
    or retiring a pool (elastic scaling) invalidates the struct-of-arrays
    mirror and bumps the fleet generation without any caller changes."""

    def __init__(self, cluster: "Cluster"):
        super().__init__()
        self._cluster = cluster

    def __setitem__(self, key, value):
        super().__setitem__(key, value)
        self._cluster._fleet_changed()

    def __delitem__(self, key):
        super().__delitem__(key)
        self._cluster._fleet_changed()

    # every other mutator must invalidate too — a membership change that
    # slipped past the hooks would leave schedulers scoring ghost columns
    def pop(self, key, *default):
        had = key in self
        out = super().pop(key, *default)
        if had:
            self._cluster._fleet_changed()
        return out

    def popitem(self):
        out = super().popitem()
        self._cluster._fleet_changed()
        return out

    def clear(self):
        had = bool(self)
        super().clear()
        if had:
            self._cluster._fleet_changed()

    def update(self, *args, **kwargs):
        super().update(*args, **kwargs)
        self._cluster._fleet_changed()

    def setdefault(self, key, default=None):
        had = key in self
        out = super().setdefault(key, default)
        if not had:
            self._cluster._fleet_changed()
        return out


_CLUSTER_SERIAL = itertools.count()


class Cluster:
    def __init__(self, cd: ConfigDict, fleet: Optional[Sequence[WorkerPool]]
                 = None, serving: str = "job", max_batch: int = 8,
                 batch_alpha: Optional[float] = None):
        self.cd = cd
        self.serving = serving
        self._max_batch = max_batch
        self._batch_alpha = batch_alpha
        # struct-of-arrays state: the mirror itself (built lazily), the
        # membership / failure generations (score-cache invalidation), a
        # process-unique serial (so caches never confuse two clusters),
        # and the interned engine ids for the batch-engine column
        self.serial = next(_CLUSTER_SERIAL)
        self._arrays: Optional[_FleetArrays] = None
        self._member_gen = 0
        self._fail_gen = 0
        self._worker_token: Optional[int] = None
        self._engine_code: Dict[str, int] = {}
        self.workers: Dict[str, WorkerSim] = _WorkerDict(self)
        for w in (fleet or default_fleet()):
            self.workers[w.name] = self._make_worker(w)
        # prefill/decode disaggregation (docs/serving_bridge.md): pools
        # carry a phase role, jobs move through prefill -> decode phases
        # tracked here (maintained by the simulator); a whole-job cluster
        # reports phase "full" and gates nothing.
        self.disaggregated = serving == "batched" and any(
            ws.pool.role != "both" for ws in self.workers.values())
        self.job_phase: Dict[int, str] = {}
        # WAN partition timeline (``LinkFailureEvent``, installed by the
        # Simulator): severed region pairs gate cross-region spillover
        # and KV pulls while active.  Empty — the default — is free.
        self.link_outages: List[LinkFailureEvent] = []
        self._part_memo: tuple = (None, frozenset())

    def _make_worker(self, pool: WorkerPool) -> WorkerSim:
        if self.serving == "batched":
            ws = BatchedWorkerSim(pool, max_batch=self._max_batch,
                                  alpha_override=self._batch_alpha)
        else:
            ws = WorkerSim(pool)
        ws._cluster = self        # failure writes bump self._fail_gen
        return ws

    # -- struct-of-arrays mirror + generations -------------------------

    def _fleet_changed(self):
        self._arrays = None
        self._member_gen += 1
        self._worker_token = None

    @property
    def fleet_gen(self) -> int:
        """Monotone fleet generation: bumps on every membership change
        (elastic clone added/retired) and every failure injection — the
        coarse invalidation token for cross-tick score caches."""
        return self._member_gen + self._fail_gen

    @property
    def fail_gen(self) -> int:
        """Failure-only generation (membership changes excluded): lets a
        score cache distinguish an appended clone (extend columns) from a
        failure (flush)."""
        return self._fail_gen

    @property
    def worker_token(self) -> int:
        """Interned id of the current worker-name tuple (see
        ``estimator.intern_worker_tuple``): the cheap per-tick cache key
        that replaces hashing hundreds of pool names every call."""
        tok = self._worker_token
        if tok is None:
            from repro.core.estimator import intern_worker_tuple
            tok = self._worker_token = intern_worker_tuple(self.cd,
                                                           self.workers)
        return tok

    def engine_code(self, engine: str) -> int:
        code = self._engine_code.get(engine)
        if code is None:
            code = self._engine_code[engine] = len(self._engine_code)
        return code

    @property
    def arrays(self) -> _FleetArrays:
        a = self._arrays
        if a is None:
            a = self._arrays = self._build_arrays()
        return a

    def _build_arrays(self) -> _FleetArrays:
        names = list(self.workers)
        W = len(names)
        a = _FleetArrays(
            names=names, index={n: i for i, n in enumerate(names)},
            busy_until=np.empty(W), failed_until=np.empty(W),
            role=np.zeros(W, np.int8), depth=np.zeros(W, np.int32),
            slot_cap=np.ones(W, np.int32),
            engine_id=np.full(W, -1, np.int32), alpha=np.full(W, 0.5))
        batched = self.serving == "batched"
        for i, ws in enumerate(self.workers.values()):
            a.busy_until[i] = ws.busy_until
            a.failed_until[i] = ws.failed_until
            a.role[i] = ROLE_CODE[ws.pool.role]
            ws._arrays = a
            ws._aidx = i
            if batched:
                ws._sync_batch()
        return a

    # -- vectorized scheduler views (O(W), no Python worker loops) -----

    def avail_array(self, now: float) -> np.ndarray:
        """[W] bool: ``WorkerSim.idle`` over the whole fleet (in batched
        mode: a free slot under the max-batch / KV budgets)."""
        a = self.arrays
        free = (a.busy_until <= now) & (a.failed_until <= now)
        if self.serving == "batched":
            free &= (a.depth == 0) | (a.depth < a.slot_cap)
        return free

    def busy_wait_array(self, now: float) -> np.ndarray:
        """[W] f64: seconds until each worker frees (0 when idle)."""
        a = self.arrays
        return np.maximum(0.0, np.maximum(a.busy_until - now,
                                          a.failed_until - now))

    def depth_penalty_array(self, now: float) -> np.ndarray:
        """[W] f64: ``depth_penalty`` over the whole fleet in one shot."""
        a = self.arrays
        pen = np.ones(len(a.names))
        if self.serving == "batched":
            m = ((a.depth > 0) & (a.busy_until <= now)
                 & (a.failed_until <= now) & (a.depth < a.slot_cap))
            if m.any():
                pen[m] = 1.0 + a.alpha[m] * a.depth[m]
        return pen

    def admit_engine_mask(self, engine: str, now: float,
                          phase: str = "full") -> np.ndarray:
        """[W] bool: ``admit_engine_ok`` over the whole fleet — the
        batch-formation + phase-role gate as one vector op instead of
        ``keys x W`` Python calls per tick."""
        a = self.arrays
        ok = (a.busy_until <= now) & (a.failed_until <= now)
        if self.disaggregated:
            ok &= (a.role == 0) | (a.role == PHASE_CODE[phase])
        if self.serving == "batched":
            ok &= (a.depth == 0) | (a.depth < a.slot_cap)
            eid = self._engine_code.get(engine, -2)   # -2: never batched
            ok &= (a.engine_id == -1) | (a.engine_id == eid)
        return ok

    def partitioned_pairs(self, now: float) -> frozenset:
        """Region pairs (as ``frozenset({a, b})``) whose WAN link is
        severed at ``now`` — memoized per timestamp, so per-job checks
        within one scheduler tick cost a dict probe."""
        memo_t, memo_v = self._part_memo
        if memo_t == now:
            return memo_v
        pairs = frozenset(frozenset((ev.a, ev.b))
                          for ev in self.link_outages
                          if ev.at <= now < ev.at + ev.duration)
        self._part_memo = (now, pairs)
        return pairs

    def link_down(self, r1: str, r2: str, now: float) -> bool:
        """Is the REGION_XFER link between two regions severed right now?"""
        if not self.link_outages or r1 == r2:
            return False
        return frozenset((r1, r2)) in self.partitioned_pairs(now)

    def idle_workers(self, now: float) -> List[str]:
        return [n for n, w in self.workers.items() if w.idle(now)]

    def feasible(self, engine: str, worker: str, use_default: bool) -> bool:
        ent = (self.cd.default_entry(engine, worker) if use_default
               else self.cd.optimal(engine, worker))
        return ent is not None and ent.qps > 0

    # -- serving-bridge views (identical to plain idleness in job mode) ----

    def phase_of(self, job: Job) -> str:
        """The job's current serving phase: ``"full"`` outside
        disaggregated clusters; ``"prefill"`` then ``"decode"`` inside one
        (every job starts at prefill; the simulator advances it)."""
        if not self.disaggregated:
            return "full"
        return self.job_phase.get(job.id, "prefill")

    def role_ok(self, job: Job, worker: str) -> bool:
        """Pool-role gate: a ``prefill``/``decode`` pool only serves its
        phase; ``both`` pools serve anything.  Always True outside
        disaggregated clusters."""
        if not self.disaggregated:
            return True
        role = self.workers[worker].pool.role
        return role == "both" or role == self.phase_of(job)

    def admit_ok(self, job: Job, worker: str, now: float) -> bool:
        """Can ``worker`` start/admit ``job`` right now?  In job mode this
        is plain idleness; in batched mode it adds the bridge's batch
        formation rules (same engine, free slot, KV headroom) and, under
        disaggregated pools, the phase-role match."""
        if not self.role_ok(job, worker):
            return False
        ws = self.workers[worker]
        if isinstance(ws, BatchedWorkerSim):
            return ws.can_admit(job.engine, now)
        return ws.idle(now)

    def admit_engine_ok(self, engine: str, worker: str, now: float,
                        phase: str = "full") -> bool:
        ws = self.workers[worker]
        if self.disaggregated:
            role = ws.pool.role
            if role != "both" and role != phase:
                return False
        if isinstance(ws, BatchedWorkerSim):
            return ws.can_admit(engine, now)
        return ws.idle(now)

    def depth_penalty(self, worker: str, now: float) -> float:
        """Queue-depth-adjusted latency factor: how much slower a job runs
        if it joins ``worker``'s current batch (``1 + alpha * b`` for a
        joinable batch of ``b``; 1.0 in job mode, for empty batches, and
        for full batches the job would have to wait out anyway)."""
        ws = self.workers[worker]
        if (isinstance(ws, BatchedWorkerSim) and ws.active
                and ws.idle(now)):
            return 1.0 + ws.batch_alpha_ * len(ws.active)
        return 1.0


class Policy:
    """Interface: look at the queue, return assignments onto idle workers."""

    name = "base"
    use_default_config = True       # baselines use device defaults (paper)

    def on_arrival(self, job: Job, cluster: Cluster, now: float):
        pass

    def on_requeue(self, job: Job, cluster: Cluster, now: float):
        """A previously-placed (or staged) job re-entered the queue —
        failure checkpoint-restart, or a parked KV cache lost with its
        pool.  Routing policies re-evaluate the job here; the default is
        inert so every flat policy is untouched."""
        pass

    def on_complete(self, result: "JobResult", cluster: Cluster,
                    now: float):
        """A job finished: its ``JobResult`` is final (both serving
        modes).  Online policies observe outcomes here — e.g. the
        ``OnlineRecharacterizer``'s observed-vs-predicted service-time
        residuals.  The default is inert so every existing policy (and
        schedule) is untouched."""
        pass

    def on_terminal(self, job: Job, cluster: Cluster, now: float):
        """A job left the system *without* completing — shed by the
        overload controller, abandoned by its client, or failed out of
        its retry budget.  Stateful policies release per-job state here
        (SynergAI reclaims the job's ScoreCache row, the hierarchical
        router drops its home assignment).  Default inert."""
        pass

    def schedule(self, now: float, queue: List[Job], cluster: Cluster
                 ) -> List[Assignment]:
        raise NotImplementedError


# wake-up kinds on the event heap
_W_ARRIVAL, _W_FAILURE, _W_COMPLETE, _W_RECOVER, _W_FREE = range(5)


class Simulator:
    def __init__(self, cd: ConfigDict, policy: Policy,
                 fleet: Optional[Sequence[WorkerPool]] = None,
                 tick: float = 1.0,
                 failures: Sequence[FailureEvent] = (),
                 degradations: Sequence[DegradationEvent] = (),
                 straggler_prob: float = 0.0,
                 straggler_factor: float = 3.0,
                 speculative: bool = False,
                 exec_noise: float = 0.2,
                 elastic_max: int = 0,
                 elastic_threshold: int = 6,
                 provision_s: float = 30.0,
                 serving: str = "job",
                 max_batch: int = 8,
                 batch_alpha: Optional[float] = None,
                 engines: Optional[dict] = None,
                 link_failures: Sequence[LinkFailureEvent] = (),
                 retry_budget: Optional[int] = None,
                 retry_base_s: float = 2.0,
                 retry_jitter: float = 0.5,
                 elastic_cooldown_s: float = 0.0,
                 seed: int = 0):
        if serving not in ("job", "batched"):
            raise ValueError(f"serving must be 'job' or 'batched', "
                             f"got {serving!r}")
        if serving == "batched" and speculative:
            raise ValueError("speculative re-dispatch is not supported "
                             "with serving='batched' (a batch member has "
                             "no single backup worker)")
        self.serving = serving
        # engine shapes are needed in both modes: batched serving derives
        # token rates from them, job mode uses decode_len for the TTFT/TPOT
        # streaming metrics
        from repro.core.engines import default_engines
        self._engines = dict(engines or default_engines())
        self.cd = cd
        self.policy = policy
        self.cluster = Cluster(cd, fleet, serving=serving,
                               max_batch=max_batch, batch_alpha=batch_alpha)
        if serving != "batched" and any(
                ws.pool.role != "both" for ws in
                self.cluster.workers.values()):
            raise ValueError(
                "prefill/decode-disaggregated fleets (WorkerPool.role != "
                "'both') require serving='batched'")
        self._disagg = self.cluster.disaggregated
        # disaggregation state: results parked between prefill completion
        # and decode dispatch, per-job KV-pull delays (charged at decode
        # admission), and the heap of decode legs awaiting re-queue
        self._between: Dict[int, JobResult] = {}
        self._xfer_s: Dict[int, float] = {}
        self._handoff: list = []
        self.tick = tick
        self.failures = sorted(failures, key=lambda f: f.at)
        self.degradations = sorted(degradations, key=lambda d: d.at)
        self.straggler_prob = straggler_prob
        self.straggler_factor = straggler_factor
        self.speculative = speculative
        # run-to-run execution variance (real inference serving is noisy;
        # schedulers only see profiled expectations).  Lognormal, mean 1.
        self.exec_noise = exec_noise
        # elastic scaling: clone the strongest pool under queue pressure.
        # ``elastic_cooldown_s`` is the scale-down hysteresis window:
        # clones only retire once the pressure trigger (queue depth >=
        # threshold) has been quiet that long, so a single flash crowd
        # doesn't thrash clone/retire cycles.  0.0 — the default — is
        # the historical retire-on-empty behavior, bit-for-bit.
        self.elastic_max = elastic_max
        self.elastic_threshold = elastic_threshold
        self.provision_s = provision_s
        self.elastic_cooldown_s = elastic_cooldown_s
        self._clones = 0
        self._clone_names: List[str] = []
        self._last_pressure = -math.inf
        self.elastic_clones_total = 0
        self.elastic_retires_total = 0
        # ---- overload control / failure hardening (docs/robustness.md),
        # all inert by default ----
        # retry budget + exponential backoff: a failure requeue parks the
        # job on ``self._retry`` for ``retry_base_s * 2^attempt`` seconds
        # (jittered from the sim RNG — drawn only when the feature is on,
        # so the historical draw order is untouched) instead of instantly
        # re-entering the scan queue; budget exhaustion is terminal
        # ``outcome="failed"``.  ``retry_budget=None`` (and no per-job
        # override) keeps instant-requeue-forever.
        self.retry_budget = retry_budget
        self.retry_base_s = retry_base_s
        self.retry_jitter = retry_jitter
        self.link_failures = sorted(link_failures, key=lambda e: e.at)
        self._retry: list = []              # (ready, seq, job) backoff heap
        self._parked: set = set()           # job ids currently on _retry
        self._abandon: list = []            # (deadline, seq, job) patience
        self._attempts: Dict[int, int] = {}
        self._terminal: set = set()         # ids with a terminal outcome
        self._feas_cache: Dict[tuple, list] = {}
        self.retry_events: List[RetryEvent] = []
        self._results: Optional[List[JobResult]] = None
        # per-main-loop-iteration queue depth samples (post-control), the
        # bounded-p99-depth observable of bench_overload; and the
        # iteration count, pinned by the outage hot-loop regression test
        self.queue_depths: List[int] = []
        self.loop_iters = 0
        self.rng = np.random.default_rng(seed)
        # event heap; None outside run() (and always for LegacySimulator),
        # which turns the _notify hooks into no-ops
        self._heap: Optional[list] = None
        self._seq = itertools.count()

    # ------------------------------------------------------------------
    # event-heap bookkeeping (no-ops when self._heap is None)

    def _notify_end_changed(self, jid: int, end: float):
        if self._heap is not None:
            heapq.heappush(self._heap, (end, next(self._seq),
                                        _W_COMPLETE, jid))

    def _notify_worker_free(self, worker: str, at: float):
        if self._heap is not None:
            heapq.heappush(self._heap, (at, next(self._seq), _W_FREE, worker))

    def _wake_valid(self, t: float, kind: int, payload,
                    running: Dict[int, JobResult]) -> bool:
        if kind in (_W_ARRIVAL, _W_FAILURE):
            return True          # arrival/failure times are static
        if kind == _W_COMPLETE:
            rec = running.get(payload)
            return rec is not None and rec.end == t
        ws = self.cluster.workers.get(payload)
        if kind == _W_RECOVER:
            return ws is not None and ws.failed_until == t
        return ws is not None and ws.busy_until == t          # _W_FREE

    def _next_wake(self, now: float, queue: List[Job],
                   running: Dict[int, JobResult]) -> float:
        heap = self._heap
        while heap:
            t, _, kind, payload = heap[0]
            if t > now + 1e-12 and self._wake_valid(t, kind, payload,
                                                    running):
                break
            heapq.heappop(heap)   # already handled, or state changed
        nxt = heap[0][0] if heap else math.inf
        if self.tick and (queue or (self.speculative and running)):
            nxt = min(nxt, now + self.tick)
        return nxt

    # ------------------------------------------------------------------

    def run(self, jobs: Sequence[Job]) -> List[JobResult]:
        # a new run is a new world: bump the failure generation so any
        # cross-tick score cache (keyed by job id) starts from scratch
        # even if this simulator is reused with a different job set
        self.cluster._fail_gen += 1
        pending = sorted(jobs, key=lambda j: j.arrival)
        queue: List[Job] = []
        results: List[JobResult] = []
        running: Dict[int, JobResult] = {}
        first_attempt: Dict[int, float] = {}
        decision_time: Dict[int, float] = {}
        failures = list(self.failures)
        self._heap = []
        self._seq = itertools.count()
        self._between.clear()
        self._xfer_s.clear()
        self._handoff = []
        self.cluster.job_phase.clear()
        # overload-control state (docs/robustness.md)
        self._retry = []
        self._parked.clear()
        self._abandon = []
        self._attempts.clear()
        self._terminal.clear()
        self._feas_cache.clear()
        self.retry_events = []
        self.queue_depths = []
        self._last_pressure = -math.inf
        self._results = results
        self.cluster.link_outages = list(self.link_failures)
        self.cluster._part_memo = (None, frozenset())
        ctrl = getattr(self.policy, "overload", None)
        for job in pending:
            heapq.heappush(self._heap, (job.arrival, next(self._seq),
                                        _W_ARRIVAL, None))
        for f in failures:
            heapq.heappush(self._heap, (f.at, next(self._seq),
                                        _W_FAILURE, None))
        # slowdown edit timeline: an onset installs its factor, the
        # expiry removes it, and the worker's slowdown is recomputed as
        # the product of its still-active factors (exactly 1.0 when none
        # remain — no float residue from repeated multiply/divide)
        deg_edits: List[tuple] = []
        for k, d in enumerate(self.degradations):
            deg_edits.append((d.at, k, d.worker, d.factor))
            deg_edits.append((d.at + d.duration, k, d.worker, None))
        deg_edits.sort(key=lambda e: (e[0], e[1]))
        deg_active: Dict[str, Dict[int, float]] = {}
        for t, _, _, _ in deg_edits:
            heapq.heappush(self._heap, (t, next(self._seq),
                                        _W_FAILURE, None))
        pi = fi = di = 0         # cursors into pending / failures / edits
        now = 0.0
        n_total = len(pending)

        guard = 0
        try:
            while len(results) < n_total:
                guard += 1
                assert guard < 2_000_000, "simulator livelock"
                # 1) deliver arrivals
                while pi < len(pending) and (pending[pi].arrival
                                             <= now + 1e-12):
                    job = pending[pi]
                    pi += 1
                    queue.append(job)
                    if job.patience is not None:
                        # the client's hang-up clock starts at submission
                        # and never pauses (retry parking included)
                        t_ab = job.arrival + job.patience
                        heapq.heappush(self._abandon,
                                       (t_ab, next(self._seq), job))
                        heapq.heappush(self._heap, (t_ab, next(self._seq),
                                                    _W_ARRIVAL, None))
                    self.policy.on_arrival(job, self.cluster, now)
                # 1b) backoff re-entries that are due re-join the scan
                # queue (skipping jobs that meanwhile went terminal)
                while self._retry and self._retry[0][0] <= now + 1e-12:
                    _, _, job = heapq.heappop(self._retry)
                    if job.id not in self._parked:
                        continue
                    self._parked.discard(job.id)
                    queue.append(job)
                    self.policy.on_requeue(job, self.cluster, now)
                # 2) worker failures: kill the running job, re-queue it
                while fi < len(failures) and failures[fi].at <= now + 1e-12:
                    f = failures[fi]
                    fi += 1
                    w = self.cluster.workers[f.worker]
                    w.failed_until = f.at + f.duration
                    heapq.heappush(self._heap, (w.failed_until,
                                                next(self._seq),
                                                _W_RECOVER, f.worker))
                    for jid, rec in list(running.items()):
                        if rec.worker == f.worker and rec.end > now:
                            del running[jid]
                            w.busy_until = now
                            if self._disagg:
                                # the pool's KV state died with it: the job
                                # restarts from prefill (a decode-phase
                                # member re-prefills; partial decode tokens
                                # are discarded uncounted — ``finish`` never
                                # saw them)
                                self.cluster.job_phase[jid] = "prefill"
                                self._xfer_s.pop(jid, None)
                                self._between.pop(jid, None)
                            # checkpoint-restart: instant requeue without
                            # a retry budget, backoff park (or terminal
                            # "failed") with one
                            self._requeue_failed(rec.job, now, queue)
                    if self._disagg:
                        # pull-style staging parks the KV on a "both"
                        # prefill pool until the decode leg is admitted
                        # (the jid stays in _xfer_s); if that pool dies
                        # first, the parked cache dies with it and the
                        # (still-queued) job re-prefills.  Pushed caches
                        # already left their pool and are unaffected.
                        for jid, brec in list(self._between.items()):
                            if (brec.prefill_worker == f.worker
                                    and jid in self._xfer_s):
                                self.cluster.job_phase[jid] = "prefill"
                                del self._xfer_s[jid]
                                brec_job = self._between.pop(jid).job
                                # still queued, but its phase (and any
                                # region affinity to the dead producer)
                                # just changed under it
                                self.policy.on_requeue(brec_job,
                                                       self.cluster, now)
                    if isinstance(w, BatchedWorkerSim):
                        w.on_failure(now)
                # 2b) profile degradations: the worker keeps serving,
                # just slower than its offline characterization says —
                # running jobs keep their committed end times, new
                # dispatches (and batch admissions) pay the factor
                while di < len(deg_edits) and deg_edits[di][0] <= now + 1e-12:
                    _t, k, wname, f = deg_edits[di]
                    di += 1
                    w = self.cluster.workers.get(wname)
                    if w is None:
                        continue
                    act = deg_active.setdefault(wname, {})
                    if f is None:
                        act.pop(k, None)
                    else:
                        act[k] = f
                    s = 1.0
                    for v in act.values():
                        s *= v
                    w.slowdown = s
                # 3) complete finished jobs (running is at most one record
                # per worker in job mode and at most max_batch in batched
                # mode, so this scan is O(W), not O(jobs))
                due = [(jid, rec) for jid, rec in running.items()
                       if rec.end <= now + 1e-12]
                rebatch: Dict[str, BatchedWorkerSim] = {}
                for jid, rec in due:
                    del running[jid]
                    w = self.cluster.workers[rec.worker]
                    w.last_freed = rec.end
                    if isinstance(w, BatchedWorkerSim):
                        w.accrue(now)
                        fin = w.finish(jid)
                        rebatch[rec.worker] = w
                        if (self._disagg and
                                self.cluster.phase_of(rec.job)
                                == "prefill"):
                            # prefill done: not a completion — hand the KV
                            # off and re-queue the decode phase
                            self._handoff_prefill(jid, rec, now,
                                                  first_attempt)
                            continue
                        self._finish_streaming(rec, fin)
                    results.append(rec)
                    self.policy.on_complete(rec, self.cluster, now)
                # surviving batch members speed up (fewer sharers):
                # re-estimate their completions through the heap
                for w in rebatch.values():
                    self._rebatch(w, now, running)
                # deliver decode legs whose staging is done: parked
                # caches (handed off by the completions above from a
                # "both" pool) re-queue in this same iteration, pushed
                # ones once their transfer lands
                while self._handoff and self._handoff[0][0] <= now + 1e-12:
                    _, _, job = heapq.heappop(self._handoff)
                    if job.id in self._terminal:
                        continue     # abandoned while its KV was in flight
                    queue.append(job)
                    self.policy.on_arrival(job, self.cluster, now)
                # 3a) client abandonment: queued (or backoff-parked, or
                # handoff-staged) jobs whose patience expired hang up
                if self._abandon:
                    self._abandon_due(now, queue, running, results)
                # 3b) straggler mitigation (speculative re-dispatch)
                if self.speculative:
                    self._speculate(now, running)
                # 3c) elastic scaling
                if self.elastic_max:
                    self._elastic(now, queue)
                # 4) ask the policy for assignments
                t0 = time.perf_counter()
                assignments = self.policy.schedule(now, queue, self.cluster)
                dt = time.perf_counter() - t0
                for a in assignments:
                    decision_time[a.job.id] = (
                        decision_time.get(a.job.id, 0.0)
                        + dt / max(1, len(assignments)))
                # track blocked head-of-line attempts (scheduling overhead)
                if not assignments and queue:
                    for j in queue[:1]:
                        first_attempt.setdefault(j.id, now)
                for a in assignments:
                    self._start(a, now, queue, running, first_attempt,
                                decision_time)
                # 4b) drain the overload controller's shed decisions
                # (queued jobs the policy marked certainly-doomed or over
                # the admission cap): terminal ``outcome="shed"``
                if ctrl is not None:
                    for job in ctrl.drain():
                        if job.id in self._terminal or job.id in running:
                            continue
                        try:
                            queue.remove(job)
                        except ValueError:
                            continue    # left the queue some other way
                        results.append(
                            self._terminal_result(job, now, "shed"))
                        self.policy.on_terminal(job, self.cluster, now)
                # 4c) full-engine outage: a queued job with zero live
                # pools parks on the backoff heap until the earliest
                # recovery instead of re-entering scoring every tick.
                # Gated on retry being configured — parking shifts
                # head-of-line overhead accounting, so the historical
                # default stays bit-for-bit.
                if (not assignments and queue
                        and self.retry_budget is not None):
                    self._park_outage_victims(now, queue)
                self.queue_depths.append(len(queue))
                # 5) advance time to the next indexed wake-up
                nxt = self._next_wake(now, queue, running)
                if nxt is math.inf and not running and queue:
                    # every queued job is infeasible everywhere -> drop loudly
                    raise RuntimeError(
                        f"stuck: {[j.engine for j in queue]} infeasible")
                if nxt is math.inf:
                    break
                now = max(now, nxt)
        finally:
            self._heap = None
            self._results = None
            self.loop_iters = guard
        # settle the idle/static power floor over the run's span: parked
        # seconds burn each pool's cheapest idle draw.  Kept out of
        # ``energy_j`` (active energy, the Fig. 12 series) but it is what
        # makes "race to idle" visible in ``total_energy_j`` — fast modes
        # finish early and idle cheap instead of running long at full draw.
        span = max((r.end for r in results), default=0.0)
        for w in self.cluster.workers.values():
            w.idle_energy_j += (w.pool.idle_power_w
                                * max(0.0, span - w.busy_s))
        return results

    # ------------------------------------------------------------------
    # overload control / failure hardening (docs/robustness.md)

    def _terminal_result(self, job: Job, now: float,
                         outcome: str) -> JobResult:
        """Close a job out with a terminal non-completion outcome
        (``failed`` / ``abandoned`` / ``shed``) and release its serving
        state.  A disaggregated job keeps its prefill-leg record (that
        service really ran) with the terminal outcome stamped on it."""
        jid = job.id
        self._terminal.add(jid)
        self._parked.discard(jid)
        self._xfer_s.pop(jid, None)
        self.cluster.job_phase.pop(jid, None)
        rec = self._between.pop(jid, None)
        wait = max(0.0, now - job.arrival)
        if rec is None:
            rec = JobResult(job, "", "", now, now, wait, 0.0, wait,
                            False, 0.0, 0.0, 0.0)
        else:
            rec.end = now
            rec.e2e = wait
            rec.violated = False
            rec.excess = 0.0
        rec.outcome = outcome
        return rec

    def _park(self, job: Job, ready: float, attempt: int):
        """Put a job on the backoff heap until ``ready`` (with a matching
        event-heap wake, so the main loop never tick-scans for it)."""
        heapq.heappush(self._retry, (ready, next(self._seq), job))
        self._parked.add(job.id)
        self.retry_events.append(RetryEvent(job.id, ready, attempt))
        if self._heap is not None:
            heapq.heappush(self._heap, (ready, next(self._seq),
                                        _W_ARRIVAL, None))

    def _requeue_failed(self, job: Job, now: float, queue: List[Job]):
        """A failure killed this job's execution.  Without a retry budget
        (the historical default) it re-enters the scan queue instantly;
        with one, the re-entry backs off exponentially
        (``retry_base_s * 2^attempt``, jittered from the sim RNG) and
        budget exhaustion is terminal ``outcome="failed"``."""
        budget = (job.retry_budget if job.retry_budget is not None
                  else self.retry_budget)
        if budget is None:
            queue.append(job)
            self.policy.on_requeue(job, self.cluster, now)
            return
        att = self._attempts.get(job.id, 0)
        if att >= budget:
            self._results.append(self._terminal_result(job, now, "failed"))
            self.policy.on_terminal(job, self.cluster, now)
            return
        self._attempts[job.id] = att + 1
        delay = self.retry_base_s * (2.0 ** att)
        if self.retry_jitter:
            delay *= 1.0 + self.retry_jitter * float(self.rng.random())
        self._park(job, now + delay, att + 1)

    def _abandon_due(self, now: float, queue: List[Job],
                     running: Dict[int, JobResult],
                     results: List[JobResult]):
        """Expired-patience sweep.  A job abandons while queued, parked
        on the backoff heap, or staged between disaggregated phases; a
        running batched member abandons only before its first decoded
        token (the client saw nothing yet) — it leaves the batch without
        counting tokens and the survivors speed up.  Jobs already
        streaming (or in exclusive job-mode service) are committed."""
        while self._abandon and self._abandon[0][0] <= now + 1e-12:
            _, _, job = heapq.heappop(self._abandon)
            jid = job.id
            if jid in self._terminal:
                continue
            if jid in running:
                rec = running[jid]
                w = self.cluster.workers.get(rec.worker)
                if isinstance(w, BatchedWorkerSim) and jid in w.active:
                    w.accrue(now)
                    f = w.active.get(jid)
                    if f is not None and f.prefill_done_at is None:
                        w.abandon(jid)
                        del running[jid]
                        results.append(
                            self._terminal_result(job, now, "abandoned"))
                        self.policy.on_terminal(job, self.cluster, now)
                        self._rebatch(w, now, running)
                continue
            in_queue = any(q.id == jid for q in queue)
            staged = jid in self._between       # KV handoff in flight
            if not (in_queue or jid in self._parked or staged):
                continue                        # already completed
            if in_queue:
                queue[:] = [q for q in queue if q.id != jid]
            results.append(self._terminal_result(job, now, "abandoned"))
            self.policy.on_terminal(job, self.cluster, now)

    def _feasible_pools(self, engine: str) -> List[str]:
        # feasibility is static per (engine, fleet membership): clones
        # share their base pool's profile rows
        key = (engine, self.cluster._member_gen,
               self.policy.use_default_config)
        hit = self._feas_cache.get(key)
        if hit is None:
            use_default = self.policy.use_default_config
            hit = self._feas_cache[key] = [
                n for n in self.cluster.workers
                if self.cluster.feasible(engine, n, use_default)]
        return hit

    def _park_outage_victims(self, now: float, queue: List[Job]):
        """Full-engine outage parking: a queued job every one of whose
        feasible pools is failed parks on the backoff heap until the
        earliest recovery (no budget consumed — nothing *killed* it), so
        a dead engine costs O(1) wakes instead of a tick-scan per second
        of outage."""
        until: Dict[str, float] = {}
        for job in list(queue):
            t = until.get(job.engine)
            if t is None:
                t = 0.0
                names = self._feasible_pools(job.engine)
                if names:
                    workers = self.cluster.workers
                    t = math.inf
                    for n in names:
                        fu = workers[n].failed_until
                        if fu <= now:
                            t = 0.0      # a live pool exists
                            break
                        t = min(t, fu)
                    if t is math.inf:    # engine feasible nowhere: leave
                        t = 0.0          # queued so "stuck" still trips
                until[job.engine] = t
            if t > now:
                queue.remove(job)
                self._park(job, t + 1e-9,
                           self._attempts.get(job.id, 0))

    def _speculate(self, now: float, running: Dict[int, "JobResult"]):
        use_default = self.policy.use_default_config
        for jid, rec in list(running.items()):
            if rec.speculated or rec.end <= now:
                continue
            ent = (self.cd.default_entry(rec.job.engine, rec.worker)
                   if use_default else
                   self.cd.optimal(rec.job.engine, rec.worker))
            est = exec_time(ent, rec.job.queries)
            if now - rec.start < 1.5 * est:
                continue  # not (yet) a straggler
            # find the fastest idle worker that could beat the laggard
            best = None
            for w in self.cluster.idle_workers(now):
                ent2 = (self.cd.default_entry(rec.job.engine, w)
                        if use_default else
                        self.cd.optimal(rec.job.engine, w))
                if ent2 is None or ent2.qps <= 0:
                    continue
                end2 = now + exec_time(ent2, rec.job.queries)
                if end2 < rec.end and (best is None or end2 < best[1]):
                    best = (w, end2, ent2)
            if best is None:
                continue
            w2, end2, ent2 = best
            ws_old = self.cluster.workers[rec.worker]
            ws_new = self.cluster.workers[w2]
            # the backup wins: cancel the original at the backup's finish
            ws_old.busy_until = end2
            # refund the cancelled tail [end2, rec.end) that was billed in
            # full at dispatch — the original worker frees at end2, so
            # keeping its busy_s/energy_j would charge those seconds twice
            # (once here, once on the backup)
            saved = rec.end - end2
            ws_old.busy_s -= saved
            ws_old.energy_j -= ent.power_w * saved
            # the original worker's free time is no longer tied to the
            # job's completion record (which now lives on the backup): if a
            # failure later kills the backup, the completion wake becomes
            # stale but this worker still frees at end2 — index that wake
            # independently, like the legacy loop's busy_until rescan does
            self._notify_worker_free(rec.worker, end2)
            ws_new.busy_until = end2
            ws_new.last_assigned = now
            ws_new.n_jobs += 1
            extra = end2 - now
            ws_new.busy_s += extra
            ws_new.energy_j += ent2.power_w * extra
            rec.end = end2
            rec.e2e = end2 - rec.job.arrival
            rec.exec_s = end2 - rec.start
            rec.violated = rec.e2e > rec.job.t_qos
            rec.excess = max(0.0, rec.e2e - rec.job.t_qos)
            rec.worker = w2
            rec.config = f"{ent2.mode}/r{ent2.chips_per_replica}"
            rec.speculated = True
            # streaming metrics follow the winning (backup) execution,
            # which restarts the job from its prefill at ``now``
            from repro.core.serving_bridge import prefill_prefix
            base = exec_time(ent2, rec.job.queries)
            pre = prefill_prefix(ent2, rec.job.queries)
            first_s = (pre / base) * extra if base > 0 else 0.0
            rec.ttft = (now - rec.job.arrival) + first_s
            dtok = self._decode_tokens(rec.job)
            rec.tpot = (extra - first_s) / dtok if dtok > 0 else math.nan
            self._apply_stream_deadlines(rec)
            self._notify_end_changed(rec.job.id, end2)

    def _elastic_base(self, now: float) -> "WorkerPool":
        """The pool to clone.  Region-tagged fleets scale the *hottest*
        region: pick the region with the highest busy/failed fraction
        right now, then its strongest pool — so the clone inherits the
        pressured region's tag and joins that region's scheduling columns
        instead of bulking up a cold one.  Untagged (or single-region)
        fleets reduce to the historical global argmax, bit-for-bit (ties:
        first in fleet order, exactly like ``max``)."""
        workers = list(self.cluster.workers.values())
        regions = {w.pool.region for w in workers}
        if len(regions) > 1:
            stats: Dict[str, List[float]] = {}  # region -> [busy, total]
            for w in workers:
                s = stats.setdefault(w.pool.region, [0.0, 0.0])
                s[0] += float(w.busy_until > now or w.failed_until > now)
                s[1] += 1.0
            best_r, best_load = None, -1.0
            for r, (busy, total) in stats.items():   # insertion order
                load = busy / total
                if load > best_load:
                    best_r, best_load = r, load
            workers = [w for w in workers if w.pool.region == best_r]
        return max(workers, key=lambda w: w.pool.chip_flops
                   * w.pool.n_chips).pool

    def _elastic(self, now: float, queue: List[Job]):
        """Spin up a clone of the strongest pool (of the hottest region,
        when the fleet is region-tagged) when the queue backs up
        (provisioning delay applies); retire idle clones once pressure
        subsides.  Only clones created here are ever retired, so synthetic
        fleet members (also named ``base__k``) are left alone."""
        if len(queue) >= self.elastic_threshold:
            self._last_pressure = now       # hysteresis clock restarts
        if (len(queue) >= self.elastic_threshold
                and self._clones < self.elastic_max):
            self._clones += 1
            self.elastic_clones_total += 1
            base = self._elastic_base(now)
            # reuse retired slot numbers (bounded by elastic_max) so the
            # estimator's per-worker-tuple row cache cycles through a small
            # set of keys instead of growing with every provision
            slot = 1
            while any(n.endswith(f"__clone{slot}")
                      for n in self._clone_names):
                slot += 1
            name = f"{base.name}__clone{slot}"
            clone = self.cluster._make_worker(base)
            clone.busy_until = now + self.provision_s
            self.cluster.workers[name] = clone
            self._clone_names.append(name)
            self._notify_worker_free(name, clone.busy_until)
        elif (not queue
              and now - self._last_pressure >= self.elastic_cooldown_s):
            # scale-down hysteresis: the pressure trigger must have been
            # quiet for the cooldown window (0.0 default = retire as soon
            # as the queue drains, the historical behavior)
            for name in list(self._clone_names):
                ws = self.cluster.workers[name]
                # a batched clone is "idle" whenever it has a free slot —
                # only retire it once its batch has fully drained
                if ws.idle(now) and not getattr(ws, "active", None):
                    del self.cluster.workers[name]
                    self._clone_names.remove(name)
                    self._clones -= 1
                    self.elastic_retires_total += 1

    def _start(self, a: Assignment, now: float, queue, running,
               first_attempt, decision_time):
        w = self.cluster.workers[a.worker]
        if isinstance(w, BatchedWorkerSim):
            self._start_batched(a, w, now, queue, running, first_attempt,
                                decision_time)
            return
        assert w.idle(now), f"{a.worker} busy"
        queue.remove(a.job)
        pred_s = exec_time(a.entry, a.job.queries)
        exec_s = pred_s * w.slowdown
        if self.exec_noise:
            s = self.exec_noise
            exec_s *= float(self.rng.lognormal(-0.5 * s * s, s))
        if self.straggler_prob and self.rng.random() < self.straggler_prob:
            exec_s *= self.straggler_factor
        solo_s = exec_s
        if a.xfer_s:
            # cross-region placement: the input ships over the REGION_XFER
            # link before service starts (deterministic — not noise-scaled)
            exec_s += a.xfer_s
        start = now
        end = start + exec_s
        w.busy_until = end
        w.last_assigned = now
        w.n_jobs += 1
        w.busy_s += exec_s
        if a.xfer_s:
            # the compute seconds bill at the entry's draw, the WAN-transfer
            # prefix at the idle/static floor (the chips wait on the wire)
            w.energy_j += (a.entry.power_w * (exec_s - a.xfer_s)
                           + a.entry.idle_power_w * a.xfer_s)
        else:
            w.energy_j += a.entry.power_w * exec_s
        waiting = start - a.job.arrival
        e2e = end - a.job.arrival
        overhead = now - first_attempt.get(a.job.id, now)
        rec = JobResult(a.job, a.worker, f"{a.entry.mode}/r"
                        f"{a.entry.chips_per_replica}", start, end, waiting,
                        exec_s, e2e, e2e > a.job.t_qos,
                        max(0.0, e2e - a.job.t_qos), overhead,
                        decision_time.get(a.job.id, 0.0))
        rec.service_s = solo_s
        rec.service_pred_s = pred_s
        self._job_mode_streaming(rec, a.entry, exec_s, xfer_s=a.xfer_s)
        running[a.job.id] = rec
        self._notify_end_changed(a.job.id, end)

    # ------------------------------------------------------------------
    # streaming QoS (TTFT / TPOT)

    def _decode_tokens(self, job: Job) -> int:
        """Decoded-token count behind a job's TPOT: its ``Request``, or
        the engine-default shape (matching ``default_request``)."""
        if job.request is not None:
            return job.request.decode_tokens
        spec = self._engines.get(job.engine)
        return job.queries * spec.decode_len if spec is not None else 0

    def _job_mode_streaming(self, rec: JobResult, entry, exec_s: float,
                            xfer_s: float = 0.0):
        """TTFT/TPOT for exclusive job-level service: the profiled
        prefill share of the (noisy) execution time marks the first
        token; noise and stragglers stretch both phases alike.  A
        cross-region shipping prefix (``Assignment.xfer_s``, already in
        ``exec_s``) precedes the prefill, delaying the first token by its
        full length."""
        from repro.core.serving_bridge import prefill_prefix
        job = rec.job
        base = exec_time(entry, job.queries)
        if xfer_s:
            exec_s -= xfer_s
        first_s = xfer_s
        pre = prefill_prefix(entry, job.queries)
        first_s += (pre / base) * exec_s if base > 0 else 0.0
        rec.ttft = rec.waiting + first_s
        dtok = self._decode_tokens(job)
        rec.tpot = (exec_s - first_s) / dtok if dtok > 0 else math.nan
        self._apply_stream_deadlines(rec)

    def _apply_stream_deadlines(self, rec: JobResult):
        """Fold TTFT/TPOT deadline misses into the violation flags (NaN
        metrics never violate; jobs without deadlines are untouched)."""
        req = rec.job.request
        if req is None:
            return
        rec.ttft_violated = (req.ttft_qos is not None
                             and rec.ttft > req.ttft_qos)
        rec.tpot_violated = (req.tpot_qos is not None
                             and rec.tpot > req.tpot_qos)
        if rec.ttft_violated or rec.tpot_violated:
            rec.violated = True

    def _finish_streaming(self, rec: JobResult, fin: Optional[_InFlight]):
        """Final streaming metrics for a completed batched job.  Under
        disaggregation ``rec.ttft`` was pinned at prefill handoff and the
        transfer + decode-queue time lands in TPOT; otherwise the first
        token is the member's interpolated prefill crossing."""
        if fin is not None:
            if not math.isnan(rec.ttft):      # disaggregated: set at handoff
                first = rec.job.arrival + rec.ttft
            else:
                first = (fin.prefill_done_at
                         if fin.prefill_done_at is not None else rec.end)
                rec.ttft = first - rec.job.arrival
            dtok = self._decode_tokens(rec.job)
            rec.tpot = ((rec.end - first) / dtok if dtok > 0 else math.nan)
        self._apply_stream_deadlines(rec)

    # ------------------------------------------------------------------
    # serving bridge (serving="batched"): continuous-batching service

    def _start_batched(self, a: Assignment, w: BatchedWorkerSim,
                       now: float, queue, running, first_attempt,
                       decision_time):
        from repro.core.serving_bridge import (batch_profile,
                                               default_request,
                                               kv_transfer_s, solo_service)
        if (not w.can_admit(a.job.engine, now)
                or not self.cluster.role_ok(a.job, a.worker)):
            # the policy raced the batch-formation rules (engine mismatch,
            # KV/slot budget, or phase-role); the job stays queued
            first_attempt.setdefault(a.job.id, now)
            return
        phase = (self.cluster.job_phase.get(a.job.id, "prefill")
                 if self._disagg else "full")
        if phase == "decode":
            brec = self._between.get(a.job.id)
            pws = (self.cluster.workers.get(brec.prefill_worker)
                   if brec is not None else None)
            if (pws is not None and a.worker != brec.prefill_worker
                    and pws.pool.region != w.pool.region
                    and self.cluster.link_down(pws.pool.region,
                                               w.pool.region, now)):
                # WAN partition: the cross-region KV pull dies on the
                # severed link and the parked cache is unreachable — the
                # in-flight handoff is lost and the job restarts from
                # prefill under its retry budget
                queue.remove(a.job)
                self.cluster.job_phase[a.job.id] = "prefill"
                self._xfer_s.pop(a.job.id, None)
                self._between.pop(a.job.id, None)
                self._requeue_failed(a.job, now, queue)
                return
        queue.remove(a.job)
        spec = self._engines[a.job.engine]
        prof = batch_profile(a.entry, spec, w.pool)
        req = a.job.request
        work, prefill = solo_service(a.entry, prof, req, a.job.queries)
        full_req = req or default_request(spec, a.job.queries)
        if phase == "prefill":
            # prefill-only slice of the service (preproc + prompt pass);
            # the member's first token *is* its phase completion
            work = prefill
            track_req = Request(full_req.prompt_tokens, 0)
        elif phase == "decode":
            work, prefill = work - prefill, 0.0
            track_req = Request(0, full_req.decode_tokens)
        else:
            track_req = full_req
        pred_s = work
        # the same noise model as job-level serving, in the same op order
        # (forcing max_batch=1 reproduces job mode bit-for-bit)
        work *= w.slowdown
        prefill *= w.slowdown
        if self.exec_noise:
            s = self.exec_noise
            noise = float(self.rng.lognormal(-0.5 * s * s, s))
            work *= noise
            prefill *= noise
        if self.straggler_prob and self.rng.random() < self.straggler_prob:
            work *= self.straggler_factor
            prefill *= self.straggler_factor
        solo_s = work
        wire_s = 0.0               # WAN/handoff seconds billed at idle floor
        if a.xfer_s:
            # cross-region placement: the input ships over the REGION_XFER
            # link first.  Deterministic link time — not noise-scaled —
            # and it precedes the prefill, so the first token waits on it.
            work += a.xfer_s
            wire_s += a.xfer_s
            if phase != "decode":
                prefill += a.xfer_s
        if phase == "decode":
            # a cache parked on a "both" pool (pull-style staging) is
            # fetched now that the placement is known — free when the
            # decode leg lands back on the pool that prefilled it (the
            # cache never moves).  The pull heads the member's service (a
            # contended batch stretches it like any service seconds) but
            # is not noise-scaled: link time is deterministic.  Pushed
            # caches paid the link before re-queueing (xfer is 0 here).
            xfer = self._xfer_s.pop(a.job.id, 0.0)
            pw = self._between[a.job.id].prefill_worker
            if a.worker != pw:
                work += xfer
                wire_s += xfer
                # a decode leg pulling its cache from another *region*
                # pays the WAN surcharge on top of the in-region handoff
                pws = self.cluster.workers.get(pw)
                if (pws is not None
                        and pws.pool.region != w.pool.region):
                    from repro.core.serving_bridge import \
                        region_xfer_extra_s
                    extra = region_xfer_extra_s(prof)
                    work += extra
                    wire_s += extra
        w.accrue(now)
        w.admit(now, a.job.id, a.job.engine, a.entry, prof, track_req,
                work, prefill)
        if wire_s:
            w.xfer_debt_s += wire_s
        w.last_assigned = now
        w.n_jobs += 1
        start = now
        end = start + work
        config = f"{a.entry.mode}/r{a.entry.chips_per_replica}"
        if phase == "decode":
            # second leg of a disaggregated job: extend the record opened
            # at prefill (exec_s spans prefill start -> decode end, i.e.
            # it includes the KV transfer and any decode queueing).  The
            # handoff cleared this job's first_attempt entry, so blocked
            # decode attempts and decode-round decisions accumulate on
            # top of the prefill leg's overhead.
            rec = self._between.pop(a.job.id)
            rec.worker = a.worker
            rec.config = config
            rec.end = end
            rec.exec_s = end - rec.start
            rec.e2e = end - a.job.arrival
            rec.violated = rec.e2e > a.job.t_qos
            rec.excess = max(0.0, rec.e2e - a.job.t_qos)
            rec.overhead_s += now - first_attempt.get(a.job.id, now)
            rec.decision_s = decision_time.get(a.job.id, 0.0)
            rec.service_s = (solo_s if math.isnan(rec.service_s)
                             else rec.service_s + solo_s)
            rec.service_pred_s = (pred_s if math.isnan(rec.service_pred_s)
                                  else rec.service_pred_s + pred_s)
        else:
            waiting = start - a.job.arrival
            e2e = end - a.job.arrival
            overhead = now - first_attempt.get(a.job.id, now)
            rec = JobResult(a.job, a.worker, config, start, end, waiting,
                            work, e2e, e2e > a.job.t_qos,
                            max(0.0, e2e - a.job.t_qos), overhead,
                            decision_time.get(a.job.id, 0.0))
            rec.service_s = solo_s
            rec.service_pred_s = pred_s
            if phase == "prefill":
                self._xfer_s[a.job.id] = kv_transfer_s(prof)
        running[a.job.id] = rec
        self._notify_end_changed(a.job.id, end)
        # joining slows the whole batch down: re-estimate everyone
        self._rebatch(w, now, running)

    def _handoff_prefill(self, jid: int, rec: JobResult, now: float,
                         first_attempt: Dict[int, float]):
        """Prefill phase of a disaggregated job finished: record TTFT
        (the prefill pool produced the first token), stage the KV cache,
        and re-queue the decode phase.

        Staging is role-aware.  A ``prefill``-only pool can never win the
        decode leg, so its cache is *pushed* eagerly — the transfer
        overlaps the re-queue and the decode leg arrives once it lands
        (the pre-pull behavior, bit-for-bit).  A ``role="both"`` pool
        might decode the job itself, so its cache is *parked* (the jid
        stays in ``self._xfer_s``) and the decode leg queues immediately;
        the pull is charged at decode admission, and costs nothing when
        the leg lands back on the producing pool.  The job's
        blocked-attempt clock restarts so the decode leg's scheduling
        overhead accrues on top of the prefill leg's."""
        first_attempt.pop(jid, None)
        rec.ttft = rec.end - rec.job.arrival
        rec.prefill_worker = rec.worker
        self.cluster.job_phase[jid] = "decode"
        self._between[jid] = rec
        ready = now
        if self.cluster.workers[rec.worker].pool.role != "both":
            ready += self._xfer_s.pop(jid, 0.0)       # push eagerly
        heapq.heappush(self._handoff, (ready, next(self._seq), rec.job))
        if ready > now and self._heap is not None:
            heapq.heappush(self._heap, (ready, next(self._seq),
                                        _W_ARRIVAL, None))

    def _rebatch(self, w: BatchedWorkerSim, now: float,
                 running: Dict[int, JobResult]):
        """Batch membership changed: re-estimate every member's completion
        at the new sharing multiplier and re-index the changed wakes
        (``accrue`` must have brought the batch up to ``now`` first)."""
        m = w.multiplier()
        ends = []
        for f in w.active.values():
            end = now + f.remaining_s / m
            ends.append(end)
            rec = running[f.jid]
            if rec.end != end:
                rec.end = end
                rec.exec_s = end - rec.start
                rec.e2e = end - rec.job.arrival
                rec.violated = rec.e2e > rec.job.t_qos
                rec.excess = max(0.0, rec.e2e - rec.job.t_qos)
                self._notify_end_changed(f.jid, end)
        # full batch: policies' backlog view is the earliest slot-free
        # time; otherwise the worker can admit right away
        w.busy_until = now if w._has_slot() else min(ends)
