"""Offline phase: architecture-driven performance analysis & characterization
(paper §4.1).

Performance-aware Configuration Generator (1A): enumerate chips-per-replica
(the vertical-scaling analogue of thread counts).
Architecture-aware Configuration Generator (1B): enumerate operating modes.
Design Space Exploration (1C) -> Optimal Deployments (1D) -> Configuration
Dictionary (1E).

Also implements the paper's cold-start heuristics for *new* devices/engines
(§4.2 "Incorporating new devices and inference engines").
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional

from repro.core.configdict import ConfigDict, Entry
from repro.core.engines import EngineSpec, default_engines
from repro.core.perfmodel import ConfigPoint, config_space, estimate
from repro.core.workers import WorkerPool, default_fleet


def _entry(engine: EngineSpec, worker: WorkerPool, point: ConfigPoint):
    est = estimate(engine, worker, point)
    if not est.feasible:
        return None
    return Entry(engine.name, worker.name, point.mode.name,
                 point.chips_per_replica, est.qps, est.query_time_s,
                 est.preproc_s, est.power_w, est.energy_per_query_j,
                 est.bottleneck, est.decode_frac, est.idle_power_w)


def characterize(engines: Optional[Dict[str, EngineSpec]] = None,
                 fleet: Optional[Iterable[WorkerPool]] = None) -> ConfigDict:
    """Full DSE over (engine x worker x mode x chips-per-replica)."""
    engines = engines or default_engines()
    fleet = list(fleet or default_fleet())
    cd = ConfigDict()
    for ename, engine in engines.items():
        for worker in fleet:
            best = None
            entries = []
            for point in config_space(engine, worker):
                ent = _entry(engine, worker, point)
                if ent is None:
                    continue
                entries.append(ent)
                if best is None or ent.qps > best.qps:
                    best = ent
            # the default configuration (baselines use this): all chips at
            # the default (max) mode
            dmode = worker.default_mode
            dpoint = ConfigPoint(dmode, min(dmode.chips_online,
                                            worker.n_chips))
            dent = _entry(engine, worker, dpoint)
            for ent in entries:
                cd.add(ent,
                       is_best=(ent is best),
                       is_default=(dent is not None
                                   and ent.mode == dent.mode
                                   and ent.chips_per_replica
                                   == dent.chips_per_replica))
            if dent is not None and dent not in entries:
                cd.add(dent, is_default=True)
    return cd


def cold_start_config(worker: WorkerPool) -> ConfigPoint:
    """Paper §4.2 heuristic for a new, un-characterized device: pick the
    highest frequency; among similar frequencies prefer the second-highest
    chip count (diminishing returns past that)."""
    best_clock = max(m.effective_clock() for m in worker.modes)
    near = [m for m in worker.modes
            if m.effective_clock() >= 0.95 * best_clock]
    counts = sorted({min(m.chips_online, worker.n_chips) for m in near})
    target = counts[-2] if len(counts) > 1 else counts[-1]
    mode = max(near, key=lambda m: (min(m.chips_online, worker.n_chips)
                                    == target, m.effective_clock()))
    return ConfigPoint(mode, min(target, worker.n_chips))
