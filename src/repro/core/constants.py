"""Hardware constants for the TPU v5e target and the heterogeneous fleet model.

The paper (SynergAI) characterizes a heterogeneous CPU fleet (x86 Xeon VM,
Jetson AGX, Jetson NX) with per-board operating modes (Table 2).  We adapt the
same structure to a TPU v5e fleet: worker pools are TPU slices of different
sizes, and operating modes scale (clock, #chips online, power budget) exactly
as the paper's Table 2 scales (CPU MHz, #online CPUs, power budget).

All roofline numbers are per-chip peak values for TPU v5e (the dry-run /
roofline target given in the assignment).
"""

from __future__ import annotations

import dataclasses

# --- TPU v5e per-chip peaks (assignment-given) -------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip, bf16 on the MXU
PEAK_FLOPS_INT8 = 394e12        # FLOP/s per chip, int8 (2x bf16)
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per ICI link
ICI_LINKS = 4                   # links per chip in a 2D torus (v5e)
HBM_BYTES = 16 * 1024**3        # 16 GiB HBM per chip
VMEM_BYTES = 128 * 1024**2      # ~128 MiB VMEM per chip (v5e, approximate)
MXU_DIM = 128                   # systolic array tile edge
CHIP_TDP_W = 200.0              # approximate per-chip board power at full clock

# Host-side constants used by the pre-processing time model (tokenization,
# request unpacking, weights paging on cold start).
HOST_TOKENIZE_S_PER_MB = 0.004  # host pre-processing seconds per MB of request
MODEL_LOAD_GBPS = 32e9          # weight-load bandwidth (DC network / PCIe-ish)
ENGINE_INIT_S = 0.8             # fixed engine/backend initialization cost

# Static share of CHIP_TDP_W a powered-on chip draws at zero load (the
# constant term in OperatingMode.power_w's static + dynamic-c^2 split).
# This is the idle/static power floor: it is what a slice burns while
# waiting, what WAN-transfer seconds are billed at (the chips idle while
# the wire moves bytes), and why "race to idle" — finish fast at a high
# clock, then idle — beats running slow (paper Fig. 12).
IDLE_POWER_FRACTION = 0.45


@dataclasses.dataclass(frozen=True)
class OperatingMode:
    """A slice operating point, mirroring the paper's Table 2 rows.

    clock_scale multiplies the chip's peak FLOP/s *and* HBM bandwidth (DVFS
    scales the whole SoC); chips_online restricts how many chips of the slice
    participate; power_budget_w caps the total slice draw.  Following the
    paper's Key Outcome 4 ("power budget influences performance indirectly
    based on the frequency and modes it enables"), the budget caps *energy*
    accounting, not the clock.
    """

    name: str
    clock_scale: float
    chips_online: int
    power_budget_w: float

    def effective_clock(self) -> float:
        return self.clock_scale

    def power_w(self) -> float:
        # Total slice draw: static floor + dynamic ~c^2 (boards draw a
        # large static fraction, which is why "race to idle" at high clock
        # saves energy per job — the effect behind the paper's Fig. 12).
        c = self.effective_clock()
        draw = CHIP_TDP_W * (IDLE_POWER_FRACTION + 0.55 * c * c) \
            * self.chips_online
        return min(draw, self.power_budget_w)

    def idle_power_w(self) -> float:
        """Static draw of the slice at this operating point: the powered-on
        chips' idle floor, with no dynamic term.  Capped by the same power
        budget as the active draw (a budget that clamps active draw clamps
        the floor too, trivially)."""
        draw = CHIP_TDP_W * IDLE_POWER_FRACTION * self.chips_online
        return min(draw, self.power_budget_w)


# Mirrors the paper's Table 2 row-for-row (clock ratios from the MHz values;
# power budgets scaled to TPU wattage): "edge-large" has the AGX's 6 modes,
# "edge-small" the NX's 9 modes.  The cloud pod runs one full-clock mode,
# like the x86 VM (whose tunable was thread count == our chips-per-replica,
# explored by the Performance-aware Configuration Generator instead).
AGX_LIKE_MODES = [
    OperatingMode("mode1", 0.53, 8, 600.0),   # 1200 MHz, 8 cores, 30 W
    OperatingMode("mode2", 0.64, 6, 600.0),   # 1450 MHz, 6
    OperatingMode("mode3", 0.79, 4, 600.0),   # 1780 MHz, 4
    OperatingMode("mode4", 0.93, 2, 600.0),   # 2100 MHz, 2
    OperatingMode("mode5", 0.97, 4, 300.0),   # 2188 MHz, 4, 15 W
    OperatingMode("mode6", 1.00, 8, 800.0),   # 2266 MHz, 8, MAXN (~2x the 30W-class draw, as on real boards)
]

NX_LIKE_MODES = [
    OperatingMode("mode1", 0.63, 4, 200.0),   # 1200 MHz, 4, 10 W
    OperatingMode("mode2", 0.74, 4, 300.0),   # 1400 MHz, 4, 15 W
    OperatingMode("mode3", 0.74, 4, 400.0),   # 1400 MHz, 4, 20 W
    OperatingMode("mode4", 0.74, 6, 300.0),   # 1400 MHz, 6, 15 W
    OperatingMode("mode5", 0.74, 6, 400.0),   # 1400 MHz, 6, 20 W
    OperatingMode("mode6", 0.79, 2, 200.0),   # 1500 MHz, 2, 10 W
    OperatingMode("mode7", 1.00, 2, 300.0),   # 1900 MHz, 2, 15 W
    OperatingMode("mode8", 1.00, 2, 400.0),   # 1900 MHz, 2, 20 W
    OperatingMode("mode9", 1.00, 4, 200.0),   # 1900 MHz, 4, 10 W
]

CLOUD_MODES = [OperatingMode("full", 1.00, 16, 16 * 400.0)]

# Cloud chips are a beefier generation (v5p-class), mirroring the paper's
# x86 server being the most powerful node in the testbed.
V5P_FLOPS_BF16 = 459e12
V5P_HBM_BW = 2765e9
V5P_HBM_BYTES = 95 * 1024**3

# Inter-region WAN link (hierarchical scheduling, repro/core/hierarchy.py):
# cross-region placements ship the request input — and, for disaggregated
# jobs whose decode leg lands in another region, the KV handoff — over a
# metro/long-haul link that is an order of magnitude thinner and ~10x
# higher-latency than the in-region disaggregation fabric
# (serving_bridge.DISAGG_XFER_*).
REGION_XFER_GBPS = 1e9         # bytes/s
REGION_XFER_LAT_S = 0.05       # one-way inter-region latency
TOKEN_BYTES = 4                # wire bytes per shipped prompt token id
