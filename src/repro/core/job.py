"""Inference jobs + workload generation (paper §5.1).

Each experiment = 24 jobs over the engine catalogue; Poisson arrivals; QoS
demands from the execution-time distribution of the characterization:
DL (demand-low) = median, DH (demand-high) = 25%-ile; arrival frequency
FL = 1/median, FH = 1/25%-ile.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.configdict import ConfigDict
from repro.core.engines import EngineSpec, default_engines

DEFAULT_QUERIES = 1000


@dataclasses.dataclass(frozen=True)
class Request:
    """Token-level view of a job's traffic, used by the batched serving
    bridge (``repro.core.serving_bridge``): total prompt tokens to prefill
    and total tokens to decode across all of the job's queries.  Jobs
    without a ``Request`` fall back to the engine's profiled per-query
    shape, which makes the token-level service time identical to the
    job-level ``exec_time``.

    ``ttft_qos`` / ``tpot_qos`` are the streaming SLOs (PerLLM-style,
    arXiv:2405.14636): allowed seconds from submission to the first
    decoded token, and allowed seconds per decoded token after the first.
    ``None`` means the job carries no streaming deadline — only the
    end-to-end ``Job.t_qos`` applies, exactly as before the split."""

    prompt_tokens: int
    decode_tokens: int
    ttft_qos: Optional[float] = None    # arrival -> first token budget (s)
    tpot_qos: Optional[float] = None    # per-decoded-token budget (s/tok)


@dataclasses.dataclass
class Job:
    id: int
    engine: str
    queries: int
    t_qos: float                  # allowed seconds from submission
    arrival: float                # submission time
    request: Optional[Request] = None   # token counts (batched serving)
    tenant: str = ""              # traffic class (``TenantSpec.name``)
    # --- overload-control knobs (all inert by default) ---
    # ``patience``: absolute seconds of queueing the client tolerates
    # before hanging up (terminal ``outcome="abandoned"``).  ``None``
    # means the client waits forever, exactly the historical behavior.
    patience: Optional[float] = None
    # ``retry_budget``: per-job override of the simulator-level retry
    # budget — the number of failure-driven re-executions allowed before
    # the job is terminally ``outcome="failed"``.  ``None`` defers to
    # ``Simulator(retry_budget=...)``; when both are ``None`` failures
    # requeue instantly and forever (historical behavior).
    retry_budget: Optional[int] = None


def exec_time(entry, queries: int) -> float:
    """T_estimated per Eq. 2: preproc + q / QPS."""
    return entry.preproc_s + queries / entry.qps


def exec_time_distribution(cd: ConfigDict, queries: int = DEFAULT_QUERIES,
                           engine: Optional[str] = None) -> np.ndarray:
    """Execution times across all configurations and workers (paper §5.1)."""
    pre, qps, _ = _dist_arrays(cd, engine)
    return pre + queries / qps


def _dist_arrays(cd: ConfigDict, engine: Optional[str]):
    # (preproc, qps, decode_frac) vectors over the feasible DSE table rows,
    # cached on the ConfigDict: workload generators call this once per
    # *job* at fleet scale, so the per-call table scan has to go.
    cache = cd.__dict__.setdefault("_dist_cache", {})
    arr = cache.get(engine)
    if arr is None:
        ents = [e for e in cd.table
                if e.qps > 0 and (engine is None or e.engine == engine)]
        arr = cache[engine] = (np.array([e.preproc_s for e in ents]),
                               np.array([e.qps for e in ents]),
                               np.clip([e.decode_frac for e in ents],
                                       0.05, 0.95))
    return arr


def qos_threshold(cd: ConfigDict, engine: str, queries: int,
                  pct: float) -> float:
    """QoS demand for an engine at a given query count: the pct-percentile
    of its execution-time distribution (paper §5.1, DL=50 / DH=25,
    generalized to arbitrary job sizes for the fleet-scale workloads)."""
    return float(np.percentile(exec_time_distribution(cd, queries, engine),
                               pct))


def streaming_threshold(cd: ConfigDict, engine: str, queries: int,
                        pct: float, engines=None):
    """(ttft_s, tpot_s): streaming-QoS analogue of ``qos_threshold``.

    The pct-percentile, over the engine's feasible configurations, of the
    solo prefill-prefix time (``preproc + (q/qps) * (1 - decode_frac)`` —
    the time to the first decoded token when served alone) and of the
    per-output-token decode time (``decode_frac / (qps * decode_len)``,
    independent of the job size).  Workload generators scale these into
    per-class TTFT/TPOT deadlines (``TenantSpec.ttft_scale`` /
    ``tpot_scale``); like ``t_qos``, the thresholds cover service only, so
    queueing eats into the same budget."""
    engines = engines or default_engines()
    pre, qps, df = _dist_arrays(cd, engine)
    ttft = np.percentile(pre + (queries / qps) * (1.0 - df), pct)
    tpot = np.percentile(df / (qps * engines[engine].decode_len), pct)
    return float(ttft), float(tpot)


def make_experiment(cd: ConfigDict, demand: str, freq: str,
                    n_jobs: int = 24, queries: int = DEFAULT_QUERIES,
                    seed: int = 0,
                    engines: Optional[Dict[str, EngineSpec]] = None,
                    intensity: float = 4.0) -> List[Job]:
    """Build a DL-FL / DL-FH / DH-FH job set (paper-fidelity wrapper; the
    general fleet-scale generators live in ``repro.core.workload``)."""
    assert demand in ("DL", "DH") and freq in ("FL", "FH")
    engines = engines or default_engines()
    rng = np.random.default_rng(seed)
    names = list(engines)
    # demands per engine: median (DL) / 25%-ile (DH) of its exec-time dist
    pct = 50 if demand == "DL" else 25
    t_qos = {name: qos_threshold(cd, name, queries, pct) for name in names}
    # arrival rate from the aggregate distribution (paper §5.1: lambda from
    # the median / 25%-ile of execution times over all configs and workers)
    all_dist = exec_time_distribution(cd, queries)
    mean_gap = float(np.percentile(all_dist, 50 if freq == "FL" else 25))
    # the fleet serves W jobs in parallel; ``intensity`` calibrates the
    # utilization to the paper's 3-worker testbed regime
    mean_gap /= intensity
    gaps = rng.exponential(mean_gap, size=n_jobs)
    gaps[0] = 0.0
    arrivals = np.cumsum(gaps)
    jobs = []
    for i in range(n_jobs):
        name = names[i % len(names)]
        jobs.append(Job(i, name, queries, t_qos[name], float(arrivals[i])))
    rng.shuffle(jobs)
    for i, j in enumerate(sorted(jobs, key=lambda j: j.arrival)):
        j.id = i
    return sorted(jobs, key=lambda j: j.arrival)
