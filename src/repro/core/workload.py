"""Fleet-scale workload subsystem: composable arrival processes, heavy-tail
query sizes, multi-tenant mixes, and synthetic failure traces.

The paper's evaluation (§5.1) uses 24-job Poisson experiments on a 3-worker
testbed — that stays in ``repro.core.job.make_experiment``, the
paper-fidelity wrapper.  This module generates the large, bursty, diverse
traces (PerLLM-style: arXiv:2405.14636) that the event-heap simulator and
the ``synth_fleet`` clusters are built for:

* ``PoissonArrivals``      — homogeneous baseline.
* ``MMPPArrivals``         — Markov-modulated Poisson: bursty at equal mean
                             rate (dispersion index > 1).
* ``DiurnalArrivals``      — sinusoidal non-homogeneous Poisson (thinning).
* ``FlashCrowdArrivals``   — a spike window at ``spike_factor`` x the base.
* ``DriftedArrivals``      — engine-popularity drift: a base arrival
  process plus time-varying engine mix weights (smooth or piecewise,
  re-normalized per window), so the offline-profiled traffic mix goes
  stale mid-trace.
* ``ParetoSize``           — heavy-tail query counts.
* ``TenantSpec`` + ``make_workload`` — multi-tenant mixes over the engine
  catalogue with per-tenant QoS tightness.
* ``scenario``             — named presets used by tests and benchmarks.
* ``attach_requests``      — token-level ``Request`` annotations (prompt /
  decode token counts, Pareto-sampled around each engine's profiled
  per-query shape) for the batched serving bridge; every preset also runs
  token-level via ``scenario(..., serving="batched")``.  Tenants with
  ``ttft_scale`` / ``tpot_scale`` additionally get per-class streaming
  SLOs (``Request.ttft_qos`` / ``tpot_qos``;
  ``scenario(..., streaming=...)`` is the all-tenants shorthand).
* ``save_trace`` / ``load_trace`` / ``replay`` — JSON-lines serving
  traces: any job list (or completed ``Simulator`` run) exports to a
  trace file that round-trips exactly, so replays are bit-for-bit.
* ``synth_failures``       — Poisson worker failures / exponential repair;
  ``regions=`` + ``correlation=`` group pools into regions with
  correlated outage windows (one event downs a sampled fraction of a
  region simultaneously — shared-infrastructure edge outages);
  ``flap=`` splits every outage into crash-restart pulses (flapping
  pools, the retry-budget stress case).
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.configdict import ConfigDict
from repro.core.engines import default_engines
from repro.core.job import (DEFAULT_QUERIES, Job, Request, exec_time,
                            qos_threshold, streaming_threshold)
from repro.core.simulator import DegradationEvent, FailureEvent
from repro.core.workers import WorkerPool


# ---------------------------------------------------------------------------
# arrival processes


class ArrivalProcess:
    """Generates ``n`` sorted arrival times (seconds) from an rng."""

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError

    def mean_rate(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class PoissonArrivals(ArrivalProcess):
    rate: float                                   # jobs / second

    def sample(self, rng, n):
        return np.cumsum(rng.exponential(1.0 / self.rate, size=n))

    def mean_rate(self):
        return self.rate


@dataclasses.dataclass
class MMPPArrivals(ArrivalProcess):
    """Markov-modulated Poisson process: a continuous-time chain cycles
    through ``rates`` states with exponential dwell times ``dwell_s``.
    Exact simulation — the exponential's memorylessness lets us redraw the
    inter-arrival gap whenever a state switch interrupts it."""

    rates: Sequence[float]
    dwell_s: Sequence[float]

    def sample(self, rng, n):
        assert len(self.rates) == len(self.dwell_s) >= 2
        times = np.empty(n)
        state, t, i = 0, 0.0, 0
        switch = t + rng.exponential(self.dwell_s[0])
        while i < n:
            gap = rng.exponential(1.0 / self.rates[state])
            if t + gap >= switch:
                t = switch
                state = (state + 1) % len(self.rates)
                switch = t + rng.exponential(self.dwell_s[state])
                continue
            t += gap
            times[i] = t
            i += 1
        return times

    def mean_rate(self):                          # time-weighted
        r = np.asarray(self.rates, float)
        d = np.asarray(self.dwell_s, float)
        return float((r * d).sum() / d.sum())


class _ThinnedArrivals(ArrivalProcess):
    """Non-homogeneous Poisson via Lewis-Shedler thinning."""

    def rate_at(self, t: float) -> float:
        raise NotImplementedError

    def max_rate(self) -> float:
        raise NotImplementedError

    def sample(self, rng, n):
        lam = self.max_rate()
        times = np.empty(n)
        t, i = 0.0, 0
        while i < n:
            t += rng.exponential(1.0 / lam)
            if rng.random() * lam <= self.rate_at(t):
                times[i] = t
                i += 1
        return times


@dataclasses.dataclass
class DiurnalArrivals(_ThinnedArrivals):
    """rate(t) = base * (1 + amplitude * sin(2 pi t / period))."""

    base_rate: float
    amplitude: float = 0.8                        # in [0, 1)
    period_s: float = 3600.0

    def rate_at(self, t):
        return self.base_rate * (
            1.0 + self.amplitude * np.sin(2.0 * np.pi * t / self.period_s))

    def max_rate(self):
        return self.base_rate * (1.0 + abs(self.amplitude))

    def mean_rate(self):
        return self.base_rate


@dataclasses.dataclass
class CarbonTrace:
    """Per-region diurnal grid carbon-intensity curves (gCO2eq/kWh).

    ``intensity(region, t) = base[region] * (1 + amplitude *
    sin(2 pi (t + phase_s[region]) / period_s))`` — attach to a
    region-tagged fleet (``synth_fleet(..., regions=k)``) and hand the
    trace to ``SynergAI(energy_weight=..., carbon=...)`` /
    ``HierarchicalSynergAI``: regions differ in mean grid mix (``base``)
    *and* in diurnal phase, so the carbon-optimal region moves over the
    trace (solar noon walks around the globe).  Unknown regions (e.g. the
    untagged ``""``) read ``default_g``, flat.
    """

    base: Dict[str, float]             # region -> mean gCO2eq/kWh
    amplitude: float = 0.5             # in [0, 1)
    period_s: float = 86400.0          # diurnal by default
    phase_s: Optional[Dict[str, float]] = None   # region -> offset seconds
    default_g: float = 400.0           # intensity of unknown regions

    def intensity(self, region: str, t: float) -> float:
        base = self.base.get(region)
        if base is None:
            return self.default_g
        off = (self.phase_s or {}).get(region, 0.0)
        return base * (1.0 + self.amplitude
                       * math.sin(2.0 * math.pi * (t + off) / self.period_s))

    def mean_intensity(self) -> float:
        """Across-region mean of the per-region means (the sinusoid
        integrates to zero over a period) — the normalization behind
        ``relative``."""
        if not self.base:
            return self.default_g
        return sum(self.base.values()) / len(self.base)

    def relative(self, region: str, t: float) -> float:
        """Dimensionless intensity (1.0 == fleet-mean grid): what scales
        the scheduler's energy term into a carbon term without changing
        ``energy_weight``'s seconds-per-joule units."""
        m = self.mean_intensity()
        return self.intensity(region, t) / m if m > 0 else 1.0

    def relative_for(self, regions: Sequence[str], t: float) -> np.ndarray:
        """[W] ``relative`` over a per-worker region list (memoized per
        distinct region — fleets have few regions, many workers)."""
        memo: Dict[str, float] = {}
        out = np.empty(len(regions))
        for i, r in enumerate(regions):
            v = memo.get(r)
            if v is None:
                v = memo[r] = self.relative(r, t)
            out[i] = v
        return out

    def cleanest(self, regions: Sequence[str], t: float) -> str:
        """The region with the lowest intensity at ``t``."""
        return min(regions, key=lambda r: self.intensity(r, t))

    @classmethod
    def synth(cls, regions: Sequence[str], amplitude: float = 0.5,
              period_s: float = 86400.0, lo: float = 250.0,
              hi: float = 700.0) -> "CarbonTrace":
        """A deterministic synthetic grid for k regions: mean intensities
        spread linearly over [lo, hi] and diurnal phases staggered by
        ``period_s / k`` (region i's solar noon lags region i+1's), so
        both the *structurally* cleanest region and the *instantaneously*
        cleanest one are exercised."""
        rs = list(regions)
        k = max(1, len(rs))
        base = {r: lo + (hi - lo) * (i / max(1, k - 1) if k > 1 else 0.0)
                for i, r in enumerate(rs)}
        phase = {r: period_s * i / k for i, r in enumerate(rs)}
        return cls(base=base, amplitude=amplitude, period_s=period_s,
                   phase_s=phase)


@dataclasses.dataclass
class FlashCrowdArrivals(_ThinnedArrivals):
    """Baseline Poisson plus a flash-crowd window at ``spike_factor`` x."""

    base_rate: float
    spike_at: float
    spike_duration: float
    spike_factor: float = 8.0

    def rate_at(self, t):
        in_spike = self.spike_at <= t < self.spike_at + self.spike_duration
        return self.base_rate * (self.spike_factor if in_spike else 1.0)

    def max_rate(self):
        return self.base_rate * self.spike_factor

    def mean_rate(self):
        return self.base_rate                     # spike excluded: lower bound


@dataclasses.dataclass
class DriftedArrivals(ArrivalProcess):
    """Engine-popularity drift: arrival *times* come from ``base``, while
    the engine mix drifts from ``weights_start`` to ``weights_end`` over
    ``span_s`` seconds.  ``make_workload`` picks each job's engine with
    ``weights_at(arrival)`` instead of the tenant's static mix, so the
    offline-profiled traffic mix goes stale mid-trace and the online
    policy has to recover (PerLLM-style service-mix shift,
    arXiv:2405.14636).

    ``mode="smooth"`` interpolates linearly; ``mode="piecewise"`` holds
    the mix constant inside each of ``n_windows`` equal windows and steps
    between them (first window = start mix, last = end mix).  Weights are
    re-normalized per window, so they sum to 1 at every instant whatever
    the inputs' scales.  Weight vectors index the *tenant's* engine list
    (``TenantSpec.engines``); ``engine_weights`` must stay ``None`` —
    the drift carries the mix."""

    base: ArrivalProcess
    weights_start: Sequence[float]
    weights_end: Sequence[float]
    span_s: float
    mode: str = "smooth"
    n_windows: int = 4

    def __post_init__(self):
        if self.mode not in ("smooth", "piecewise"):
            raise ValueError(f"mode must be 'smooth' or 'piecewise', "
                             f"got {self.mode!r}")
        if self.span_s <= 0:
            raise ValueError("span_s must be positive")
        if self.mode == "piecewise" and self.n_windows < 2:
            raise ValueError("piecewise drift needs n_windows >= 2")
        w0 = np.asarray(self.weights_start, float)
        w1 = np.asarray(self.weights_end, float)
        if w0.shape != w1.shape or w0.ndim != 1:
            raise ValueError("weights_start/weights_end must be equal-"
                             "length 1-D vectors")
        if (w0 < 0).any() or (w1 < 0).any() or not (w0.sum() > 0
                                                    and w1.sum() > 0):
            raise ValueError("weights must be non-negative with a "
                             "positive sum")
        # make_workload calls weights_at once per job at fleet scale;
        # normalize the endpoints once here
        self._w0n = w0 / w0.sum()
        self._w1n = w1 / w1.sum()

    def weights_at(self, t: float) -> np.ndarray:
        """Normalized engine mix at time ``t`` (clamped to the drift
        span: before 0 it is the start mix, after ``span_s`` the end)."""
        return self.weights_at_times([t])[0]

    def weights_at_times(self, times) -> np.ndarray:
        """Vectorized ``weights_at``: the ``[len(times), n_engines]``
        mix matrix, one normalized row per instant (the fleet-scale
        path — ``make_workload`` draws every pick from one call)."""
        u = np.clip(np.asarray(times, float) / self.span_s, 0.0, 1.0)
        if self.mode == "piecewise":
            k = np.minimum((u * self.n_windows).astype(int),
                           self.n_windows - 1)
            u = k / (self.n_windows - 1)
        w = (1.0 - u)[:, None] * self._w0n + u[:, None] * self._w1n
        return w / w.sum(axis=1, keepdims=True)

    def sample(self, rng, n):
        return self.base.sample(rng, n)

    def mean_rate(self):
        return self.base.mean_rate()


def index_of_dispersion(times: np.ndarray, window_s: float) -> float:
    """Variance/mean of per-window arrival counts: 1 for Poisson, > 1 for
    bursty processes.  The standard burstiness sanity metric."""
    t = np.asarray(times, float)
    edges = np.arange(0.0, float(t.max()) + window_s, window_s)
    counts, _ = np.histogram(t, edges)
    return float(counts.var() / max(counts.mean(), 1e-12))


# ---------------------------------------------------------------------------
# query-size distributions


class SizeDistribution:
    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class FixedSize(SizeDistribution):
    queries: int = DEFAULT_QUERIES

    def sample(self, rng, n):
        return np.full(n, self.queries, dtype=int)


@dataclasses.dataclass
class ParetoSize(SizeDistribution):
    """Heavy-tail query counts: q = q_min * (1 + Pareto(alpha)), capped."""

    alpha: float = 1.5
    q_min: int = 200
    q_max: int = 20_000

    def sample(self, rng, n):
        q = self.q_min * (1.0 + rng.pareto(self.alpha, size=n))
        return np.minimum(q, self.q_max).astype(int)


# ---------------------------------------------------------------------------
# multi-tenant workloads


@dataclasses.dataclass
class TenantSpec:
    """One traffic class: its own arrival process, engine subset (with
    optional mix weights), size distribution and QoS tightness (percentile
    per paper §5.1: DL=50, DH=25; ``qos_scale`` loosens/tightens the
    budget).

    ``ttft_scale`` / ``tpot_scale`` add per-class *streaming* SLOs
    (``Request.ttft_qos`` / ``tpot_qos``, set by ``attach_requests``):
    each job's deadline is the scale times its engine's
    ``streaming_threshold`` at ``qos_percentile``.  ``None`` (default)
    emits no streaming deadline; batched serving is required to meet (or
    even observe) one."""

    name: str
    arrivals: ArrivalProcess
    n_jobs: int
    engines: Optional[Sequence[str]] = None       # None -> whole catalogue
    engine_weights: Optional[Sequence[float]] = None   # None -> uniform
    sizes: SizeDistribution = dataclasses.field(default_factory=FixedSize)
    qos_percentile: float = 50.0
    qos_scale: float = 1.0
    start_at: float = 0.0
    ttft_scale: Optional[float] = None    # x streaming_threshold ttft
    tpot_scale: Optional[float] = None    # x streaming_threshold tpot
    # client patience as a multiple of each job's QoS budget: a queued
    # job abandons (terminal outcome "abandoned") after
    # ``patience_scale * t_qos`` seconds of waiting.  None (default)
    # waits forever — the historical behaviour.
    patience_scale: Optional[float] = None


def make_workload(cd: ConfigDict, tenants: Sequence[TenantSpec],
                  seed: int = 0) -> List[Job]:
    """Merge all tenants into one arrival-ordered, re-numbered job list."""
    rng = np.random.default_rng(seed)
    jobs: List[Job] = []
    for tenant in tenants:
        names = list(tenant.engines or default_engines())
        drift = (tenant.arrivals
                 if isinstance(tenant.arrivals, DriftedArrivals) else None)
        p = None
        if tenant.engine_weights is not None:
            if drift is not None:
                raise ValueError(
                    f"tenant {tenant.name!r}: a DriftedArrivals tenant "
                    f"carries its mix in the drift weights; leave "
                    f"engine_weights=None")
            p = np.asarray(tenant.engine_weights, float)
            p = p / p.sum()
        arrivals = tenant.start_at + tenant.arrivals.sample(rng,
                                                            tenant.n_jobs)
        queries = tenant.sizes.sample(rng, tenant.n_jobs)
        if drift is not None:
            if len(np.asarray(drift.weights_start)) != len(names):
                raise ValueError(
                    f"tenant {tenant.name!r}: drift weights cover "
                    f"{len(np.asarray(drift.weights_start))} engines, "
                    f"tenant has {len(names)}")
            # per-job mix at the job's arrival (drift clock starts at
            # the tenant's start_at): one inverse-CDF draw per job over
            # the [n_jobs, n_engines] weight matrix
            cdf = np.cumsum(
                drift.weights_at_times(arrivals - tenant.start_at),
                axis=1)
            picks = np.minimum(
                (cdf < rng.random(tenant.n_jobs)[:, None]).sum(axis=1),
                len(names) - 1)
        else:
            picks = rng.choice(len(names), size=tenant.n_jobs, p=p)
        for at, q, ei in zip(arrivals, queries, picks):
            engine = names[int(ei)]
            t_qos = tenant.qos_scale * qos_threshold(
                cd, engine, int(q), tenant.qos_percentile)
            patience = (tenant.patience_scale * float(t_qos)
                        if tenant.patience_scale is not None else None)
            jobs.append(Job(0, engine, int(q), float(t_qos), float(at),
                            tenant=tenant.name, patience=patience))
    jobs.sort(key=lambda j: j.arrival)
    for i, j in enumerate(jobs):
        j.id = i
    return jobs


# ---------------------------------------------------------------------------
# token-level requests (batched serving bridge)


def attach_requests(jobs: Sequence[Job], engines=None, seed: int = 0,
                    alpha: float = 2.5, cd: Optional[ConfigDict] = None,
                    tenants: Optional[Sequence[TenantSpec]] = None
                    ) -> Sequence[Job]:
    """Annotate jobs with token-level ``Request``s for the serving bridge.

    Per-query prompt and decode lengths are Pareto-sampled (via the
    ``ParetoSize`` machinery) around each engine's profiled shape —
    ``q_min = 0.6 * len`` with tail index ``alpha`` has mean ~= the
    profiled length, so the aggregate load matches the job-level
    calibration while individual jobs spread over a heavy-tailed range.
    Jobs are mutated in place (and returned for convenience).

    ``tenants`` + ``cd`` additionally stamp per-class streaming SLOs:
    a job whose ``Job.tenant`` names a spec with ``ttft_scale`` /
    ``tpot_scale`` gets ``Request.ttft_qos`` / ``tpot_qos`` set to the
    scale times its engine's ``streaming_threshold`` at the tenant's
    ``qos_percentile`` (the same construction as ``t_qos``).
    """
    engines = engines or default_engines()
    by_tenant = {t.name: t for t in (tenants or ())}
    if cd is None and any(t.ttft_scale is not None
                          or t.tpot_scale is not None
                          for t in by_tenant.values()):
        raise ValueError("streaming deadlines (ttft_scale/tpot_scale) "
                         "need the ConfigDict: pass cd=...")
    rng = np.random.default_rng(seed)
    by_engine: dict = {}
    for i, j in enumerate(jobs):
        by_engine.setdefault(j.engine, []).append(i)
    for name, idx in sorted(by_engine.items()):
        spec = engines[name]
        p_dist = ParetoSize(alpha, max(1, int(0.6 * spec.prefill_len)),
                            6 * spec.prefill_len)
        d_dist = ParetoSize(alpha, max(1, int(0.6 * spec.decode_len)),
                            6 * spec.decode_len)
        prompts = p_dist.sample(rng, len(idx))
        decodes = d_dist.sample(rng, len(idx))
        thresholds: dict = {}      # (engine, queries, pct) -> (ttft, tpot)
        for i, p, d in zip(idx, prompts, decodes):
            job = jobs[i]
            ttft_qos = tpot_qos = None
            ts = by_tenant.get(job.tenant)
            if ts is not None and (ts.ttft_scale is not None
                                   or ts.tpot_scale is not None):
                key = (job.engine, job.queries, ts.qos_percentile)
                if key not in thresholds:
                    thresholds[key] = streaming_threshold(
                        cd, job.engine, job.queries, ts.qos_percentile,
                        engines)
                ttft_t, tpot_t = thresholds[key]
                if ts.ttft_scale is not None:
                    ttft_qos = ts.ttft_scale * ttft_t
                if ts.tpot_scale is not None:
                    tpot_qos = ts.tpot_scale * tpot_t
            job.request = Request(int(job.queries * p),
                                  int(job.queries * d),
                                  ttft_qos, tpot_qos)
    return jobs


# ---------------------------------------------------------------------------
# scenario presets


def engine_throughput(cd: ConfigDict, fleet: Sequence[WorkerPool],
                      engines: Sequence[str],
                      queries: int = DEFAULT_QUERIES) -> dict:
    """Fleet-wide peak throughput per engine (jobs/s): each pool serves
    1/T_exec jobs per second at its optimal configuration."""
    thr = {}
    for e in engines:
        total = 0.0
        for w in fleet:
            ent = cd.optimal(e, w.name)
            if ent is not None and ent.qps > 0:
                total += 1.0 / exec_time(ent, queries)
        thr[e] = total
    return thr


def fleet_rate(cd: ConfigDict, fleet: Sequence[WorkerPool],
               utilization: float = 0.7,
               engines: Optional[Sequence[str]] = None,
               weights: Optional[Sequence[float]] = None,
               queries: int = DEFAULT_QUERIES) -> float:
    """Arrival rate that drives ``fleet`` to ~``utilization``.

    On a heterogeneous fleet a global median is meaningless: a cloud-only
    236B engine contributes hours of work per job while a 2B edge engine
    contributes seconds.  Each engine's offered work is weighed against its
    *fleet-wide throughput* (sum of 1/T_exec over feasible pools), i.e. the
    utilization the mix induces under throughput-proportional routing.
    Defaults to the capacity-proportional mix used by ``scenario``."""
    engines = list(engines or default_engines())
    thr = engine_throughput(cd, fleet, engines, queries)
    if weights is None:
        weights = [thr[e] for e in engines]       # capacity-proportional
    for e, w in zip(engines, weights):
        if w > 0 and thr[e] <= 0:
            raise ValueError(f"engine {e!r} is infeasible on this fleet")
    wsum = float(sum(weights))
    work = sum(w / wsum / thr[e]
               for e, w in zip(engines, weights) if w > 0)
    return utilization / work


def region_rates(cd: ConfigDict, fleet: Sequence[WorkerPool],
                 utilization: float = 0.7,
                 engines: Optional[Sequence[str]] = None,
                 queries: int = DEFAULT_QUERIES) -> dict:
    """Per-region arrival rates: ``fleet_rate`` over each region's pool
    group of a tagged fleet (``WorkerPool.region``).  Regions differ in
    capacity — and, with archetypes striped round-robin, in *feasible
    engine set* — so one global rate over-drives small regions and idles
    large ones; this is the calibration behind multi-region scenarios
    and the hierarchy router's load picture.  Engines infeasible within
    a region are dropped from that region's mix; a region where nothing
    runs gets rate 0.0.  Untagged fleets collapse to ``{"": rate}``."""
    from repro.core.workers import region_groups
    engines = list(engines or default_engines())
    out = {}
    for r, pools in region_groups(fleet).items():
        thr = engine_throughput(cd, pools, engines, queries)
        feas = [e for e in engines if thr[e] > 0]
        out[r] = (fleet_rate(cd, pools, utilization, feas,
                             queries=queries) if feas else 0.0)
    return out


def regional_scenario(cd: ConfigDict, kind: str, n_jobs: int = 10_000,
                      fleet: Optional[Sequence[WorkerPool]] = None,
                      utilization: float = 0.7, seed: int = 0,
                      serving: str = "job", streaming=None,
                      patience: Optional[float] = None) -> List[Job]:
    """Multi-region traffic for a tagged fleet: one independent
    ``scenario`` stream per region, each calibrated (rate *and* engine
    mix) against that region's own pools, merged by arrival time with
    fresh sequential ids.  Job counts split proportional to the regional
    rates (largest-remainder, so they sum to ``n_jobs`` exactly) and
    each region draws from its own sub-seed.  Untagged or single-region
    fleets fall through to plain ``scenario`` unchanged."""
    from repro.core.workers import default_fleet, region_groups
    fleet = list(fleet if fleet is not None else default_fleet())
    groups = region_groups(fleet)
    if len(groups) <= 1:
        return scenario(cd, kind, n_jobs=n_jobs, fleet=fleet,
                        utilization=utilization, seed=seed,
                        serving=serving, streaming=streaming,
                        patience=patience)
    rates = region_rates(cd, fleet, utilization)
    total = sum(rates.values())
    names = list(groups)
    if total <= 0:
        raise ValueError("no engine is feasible in any region")
    shares = [rates[r] / total for r in names]
    counts = [int(n_jobs * s) for s in shares]
    rema = sorted(range(len(names)),
                  key=lambda i: (counts[i] - n_jobs * shares[i], i))
    for i in range(n_jobs - sum(counts)):
        counts[rema[i % len(names)]] += 1
    jobs: List[Job] = []
    for i, (r, n_r) in enumerate(zip(names, counts)):
        if n_r <= 0:
            continue
        jobs.extend(scenario(cd, kind, n_jobs=n_r, fleet=groups[r],
                             utilization=utilization,
                             seed=seed + 7919 * (i + 1), serving=serving,
                             streaming=streaming, patience=patience))
    jobs.sort(key=lambda j: j.arrival)
    for i, j in enumerate(jobs):
        j.id = i
    return jobs


# engines light enough for edge pools vs the heavyweight cloud set — used
# by the multi-tenant preset to shape per-tenant placement pressure
EDGE_ENGINES = ("danube-1.8b/bf16", "gemma-2b/bf16", "gemma-2b/int8",
                "qwen3-4b/int8", "hymba-1.5b/bf16", "rwkv6-1.6b/bf16")
HEAVY_ENGINES = ("qwen3-32b/bf16", "qwen3-4b/bf16", "phi3.5-moe/bf16",
                 "deepseek-v2/int8", "llama32-vision/bf16",
                 "seamless-m4t/bf16")

SCENARIOS = ("poisson", "mmpp", "diurnal", "flash", "multi-tenant",
             "drift")


def _mix(cd, fleet, engines):
    """Capacity-proportional traffic mix over the feasible engine subset:
    light edge-friendly engines carry most of the traffic, heavyweights
    proportionally less — a fleet mix whose offered load is well-defined."""
    thr = engine_throughput(cd, fleet, engines)
    names = [e for e in engines if thr[e] > 0]
    assert names, "no engine of the mix is feasible on this fleet"
    return names, [thr[e] for e in names]


def scenario(cd: ConfigDict, kind: str, n_jobs: int = 10_000,
             fleet: Optional[Sequence[WorkerPool]] = None,
             utilization: float = 0.7, seed: int = 0,
             serving: str = "job",
             streaming=None,
             patience: Optional[float] = None) -> List[Job]:
    """Named fleet-scale scenarios over the engine catalogue, calibrated to
    ``utilization`` of the given fleet (default: the 3-pool paper fleet).
    ``kind="drift"`` adds engine-popularity drift: the capacity-
    proportional mix slides toward a heavyweight-dominated one over the
    trace (``DriftedArrivals``), so the calibration goes stale.

    ``serving="batched"`` additionally attaches token-level ``Request``
    annotations (see ``attach_requests``) so the trace drives the
    continuous-batching serving bridge — pair it with
    ``Simulator(..., serving="batched")``.

    ``streaming=(ttft_scale, tpot_scale)`` stamps every tenant with those
    streaming-SLO scales (per-class control wants explicit ``TenantSpec``
    + ``make_workload`` + ``attach_requests``); batched serving only.

    ``patience=`` stamps every tenant with that ``patience_scale``: each
    job abandons after ``patience * t_qos`` seconds of queueing
    (``JobResult.outcome == "abandoned"``).  ``None`` (default) waits
    forever — bit-for-bit the historical traces.
    """
    if serving not in ("job", "batched"):
        raise ValueError(f"serving must be 'job' or 'batched', "
                         f"got {serving!r}")
    if streaming is not None and serving != "batched":
        raise ValueError("streaming TTFT/TPOT deadlines ride on the "
                         "token-level Request: use serving='batched'")
    from repro.core.workers import default_fleet
    fleet = list(fleet or default_fleet())
    engines, weights = _mix(cd, fleet, list(default_engines()))
    r = fleet_rate(cd, fleet, utilization, engines, weights)
    tenant = dict(engines=engines, engine_weights=weights)
    if kind == "poisson":
        tenants = [TenantSpec("all", PoissonArrivals(r), n_jobs, **tenant)]
    elif kind == "mmpp":
        # 7:1 burst ratio at the same time-averaged rate as "poisson"
        tenants = [TenantSpec(
            "bursty", MMPPArrivals((0.25 * r, 1.75 * r), (240.0, 240.0)),
            n_jobs, **tenant)]
    elif kind == "diurnal":
        period = max(600.0, 0.25 * n_jobs / r)    # a few cycles per trace
        tenants = [TenantSpec(
            "diurnal", DiurnalArrivals(r, amplitude=0.8, period_s=period),
            n_jobs, **tenant)]
    elif kind == "flash":
        span = n_jobs / r
        tenants = [TenantSpec(
            "flash", FlashCrowdArrivals(0.8 * r, spike_at=span / 3.0,
                                        spike_duration=span / 20.0,
                                        spike_factor=8.0), n_jobs,
            **tenant)]
    elif kind == "drift":
        # popularity flip: the capacity-proportional mix drifts until the
        # edge-friendly engines' aggregate traffic share and the
        # heavyweights' have swapped — the offline calibration priced the
        # heavy engines as rare, so the fleet slides into overload as the
        # mix goes stale.  Rate is calibrated at the midpoint mix: the
        # trace starts below target utilization and ends above it.
        w0 = np.asarray(weights, float)
        w0 = w0 / w0.sum()
        edge = np.fromiter((e in EDGE_ENGINES for e in engines),
                           dtype=bool, count=len(engines))
        s_edge, s_heavy = w0[edge].sum(), w0[~edge].sum()
        if s_edge > 0 and s_heavy > 0:
            w1 = np.where(edge, w0 * (s_heavy / s_edge),
                          w0 * (s_edge / s_heavy))
        else:                       # degenerate fleet: reverse the mix
            w1 = w0[::-1].copy()
        w_mid = 0.5 * (w0 + w1 / w1.sum())
        r_d = fleet_rate(cd, fleet, utilization, engines, list(w_mid))
        span = n_jobs / r_d
        tenants = [TenantSpec(
            "drift", DriftedArrivals(PoissonArrivals(r_d), list(w0),
                                     list(w1), span_s=span),
            n_jobs, engines=engines)]
    elif kind == "multi-tenant":
        edge_e, edge_w = _mix(cd, fleet, list(EDGE_ENGINES))
        heavy_e, heavy_w = _mix(cd, fleet, list(HEAVY_ENGINES))
        # utilization shares per tenant; job counts follow each tenant's
        # rate so the three traces overlap in time
        r_int = fleet_rate(cd, fleet, 0.5 * utilization, edge_e, edge_w)
        r_batch = fleet_rate(cd, fleet, 0.35 * utilization, heavy_e,
                             heavy_w)
        r_launch = fleet_rate(cd, fleet, 0.15 * utilization, edge_e,
                              edge_w)
        r_tot = r_int + r_batch + r_launch
        n_int = int(n_jobs * r_int / r_tot)
        n_batch = int(n_jobs * r_batch / r_tot)
        n_launch = n_jobs - n_int - n_batch
        span = n_jobs / r_tot
        tenants = [
            # interactive: small engines, tight QoS, steady traffic
            TenantSpec("interactive", PoissonArrivals(r_int), n_int,
                       engines=edge_e, engine_weights=edge_w,
                       qos_percentile=25.0),
            # batch: heavy engines, heavy-tail sizes, loose QoS, bursty
            TenantSpec("batch",
                       MMPPArrivals((0.4 * r_batch, 1.6 * r_batch),
                                    (300.0, 300.0)), n_batch,
                       engines=heavy_e, engine_weights=heavy_w,
                       sizes=ParetoSize(), qos_percentile=50.0,
                       qos_scale=3.0),
            # a product launch: flash crowd on the small engines
            TenantSpec("launch",
                       FlashCrowdArrivals(r_launch, spike_at=span / 2.0,
                                          spike_duration=span / 15.0,
                                          spike_factor=10.0),
                       n_launch, engines=edge_e, engine_weights=edge_w,
                       qos_percentile=50.0),
        ]
    else:
        raise ValueError(f"unknown scenario {kind!r}; one of {SCENARIOS}")
    if streaming is not None:
        ttft_scale, tpot_scale = streaming
        tenants = [dataclasses.replace(t, ttft_scale=ttft_scale,
                                       tpot_scale=tpot_scale)
                   for t in tenants]
    if patience is not None:
        tenants = [dataclasses.replace(t, patience_scale=patience)
                   for t in tenants]
    jobs = make_workload(cd, tenants, seed=seed)
    if serving == "batched":
        attach_requests(jobs, seed=seed, cd=cd, tenants=tenants)
    return jobs


# ---------------------------------------------------------------------------
# trace replay (JSON-lines serving logs)

TRACE_VERSION = 1
_TRACE_HEADER = "synergai_trace"


def _job_record(job: Job) -> dict:
    rec = {"id": job.id, "arrival": job.arrival, "engine": job.engine,
           "queries": job.queries, "t_qos": job.t_qos,
           "tenant": job.tenant}
    if job.patience is not None:
        rec["patience"] = job.patience
    if job.retry_budget is not None:
        rec["retry_budget"] = job.retry_budget
    if job.request is not None:
        r = job.request
        rec["prompt_tokens"] = r.prompt_tokens
        rec["decode_tokens"] = r.decode_tokens
        if r.ttft_qos is not None:
            rec["ttft_qos"] = r.ttft_qos
        if r.tpot_qos is not None:
            rec["tpot_qos"] = r.tpot_qos
    return rec


def save_trace(path, trace) -> int:
    """Export jobs as a JSON-lines trace; returns the record count.

    ``trace`` is a sequence of ``Job``s or of ``JobResult``s (a completed
    ``Simulator`` run — the jobs are pulled out of the results), written
    in arrival order after a one-line header.  Floats are serialized at
    full precision (json uses ``repr``), so ``load_trace`` round-trips
    every field bit-for-bit and a replayed run reproduces the original
    ``JobResult`` stream exactly (same fleet / policy / simulator seed).
    """
    jobs = [t.job if hasattr(t, "job") else t for t in trace]
    jobs = sorted(jobs, key=lambda j: (j.arrival, j.id))
    with open(path, "w") as f:
        f.write(json.dumps({_TRACE_HEADER: TRACE_VERSION,
                            "jobs": len(jobs)}) + "\n")
        for job in jobs:
            f.write(json.dumps(_job_record(job)) + "\n")
    return len(jobs)


def _trace_error(path, lineno: int, msg: str) -> ValueError:
    return ValueError(f"{path}:{lineno}: {msg}")


def load_trace(path) -> List[Job]:
    """Parse a ``save_trace`` file back into the exact job list.

    Malformed input — missing/garbled header, non-JSON lines, missing or
    mistyped fields, a record-count mismatch — raises ``ValueError``
    naming the offending line."""
    jobs: List[Job] = []
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        raise _trace_error(path, 1, "empty file, expected a "
                           f"{{'{_TRACE_HEADER}': ...}} header")
    try:
        header = json.loads(lines[0])
    except json.JSONDecodeError as e:
        raise _trace_error(path, 1, f"bad header: {e}") from None
    if not isinstance(header, dict) or _TRACE_HEADER not in header:
        raise _trace_error(path, 1, f"not a SynergAI trace (missing "
                           f"{_TRACE_HEADER!r} header key)")
    if header[_TRACE_HEADER] != TRACE_VERSION:
        raise _trace_error(path, 1, f"unsupported trace version "
                           f"{header[_TRACE_HEADER]!r}")
    seen: set = set()
    for lineno, line in enumerate(lines[1:], 2):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise _trace_error(path, lineno, f"bad record: {e}") from None
        if not isinstance(rec, dict):
            raise _trace_error(path, lineno, "record is not an object")
        try:
            request = None
            if "prompt_tokens" in rec or "decode_tokens" in rec:
                request = Request(int(rec["prompt_tokens"]),
                                  int(rec["decode_tokens"]),
                                  (float(rec["ttft_qos"])
                                   if "ttft_qos" in rec else None),
                                  (float(rec["tpot_qos"])
                                   if "tpot_qos" in rec else None))
            jobs.append(Job(int(rec["id"]), str(rec["engine"]),
                            int(rec["queries"]), float(rec["t_qos"]),
                            float(rec["arrival"]), request=request,
                            tenant=str(rec.get("tenant", "")),
                            patience=(float(rec["patience"])
                                      if "patience" in rec else None),
                            retry_budget=(int(rec["retry_budget"])
                                          if "retry_budget" in rec
                                          else None)))
        except (KeyError, TypeError, ValueError) as e:
            raise _trace_error(path, lineno,
                               f"bad job record ({e!r})") from None
        if jobs[-1].id in seen:
            raise _trace_error(path, lineno, f"duplicate job id "
                               f"{jobs[-1].id} (the simulator keys "
                               f"running state by id)")
        seen.add(jobs[-1].id)
    n = header.get("jobs")
    if n is not None and n != len(jobs):
        raise _trace_error(path, 1, f"header promises {n} jobs, file "
                           f"holds {len(jobs)}")
    return jobs


def replay(trace) -> List[Job]:
    """Jobs ready to feed the simulator's event heap, from a trace file
    path, a job list, or a completed run's ``JobResult`` stream.  Jobs are
    arrival-sorted with their original ids preserved, so
    ``Simulator(...).run(replay(path))`` reproduces the exporting run
    bit-for-bit (same fleet, policy and simulator seed — the rng draws
    depend only on the event order, which the trace pins)."""
    if isinstance(trace, (str, bytes)) or hasattr(trace, "__fspath__"):
        jobs = load_trace(trace)
    else:
        jobs = [t.job if hasattr(t, "job") else t for t in trace]
    return sorted(jobs, key=lambda j: (j.arrival, j.id))


# ---------------------------------------------------------------------------
# external serving-log import (Azure LLM inference trace format)


def _azure_timestamp(raw: str, path, lineno: int) -> float:
    """Seconds from an Azure trace TIMESTAMP cell: either a plain float
    (relative seconds) or an ISO datetime — Azure publishes 7-digit
    fractional seconds, which ``fromisoformat`` rejects, so the fraction
    is truncated to microseconds first."""
    s = raw.strip()
    try:
        return float(s)
    except ValueError:
        pass
    import datetime
    m = s.replace("T", " ")
    if "." in m:
        head, frac = m.split(".", 1)
        frac = "".join(c for c in frac if c.isdigit())[:6]
        m = f"{head}.{frac or 0}"
    try:
        return datetime.datetime.fromisoformat(m).timestamp()
    except ValueError:
        raise _trace_error(path, lineno, f"bad TIMESTAMP {raw!r} "
                           "(want seconds or ISO datetime)") from None


def load_azure_llm_trace(cd: ConfigDict, path, engines=None,
                         qos_scale: float = 1.0,
                         qos_percentile: float = 50.0,
                         max_jobs: Optional[int] = None,
                         tenant: str = "azure") -> List[Job]:
    """Import an Azure-LLM-inference-style serving log as a job list.

    The public Azure trace is a CSV with (at least) ``TIMESTAMP``,
    ``ContextTokens`` and ``GeneratedTokens`` columns — request arrival
    plus prompt/generation token counts, with no engine or QoS columns.
    Each row becomes a ``Job``:

    - **engine**: the catalogue engine whose request *shape* best
      matches the row — minimize ``|log((ctx / prefill_len) /
      (gen / decode_len))|`` over ``engines`` — so prompt-heavy rows
      land on prompt-heavy engine shapes and the per-engine mix follows
      the trace instead of a synthetic sampler.
    - **queries**: the geometric mean of the prefill- and decode-implied
      query counts, ``max(1, round(sqrt(q_p * q_d)))``.
    - **request**: the row's exact token counts (the batched serving
      bridge uses them verbatim).
    - **t_qos**: ``qos_scale * qos_threshold(...)`` at
      ``qos_percentile`` — the same construction every synthetic
      scenario uses.
    - **arrival**: normalized so the first row arrives at ``t = 0``.

    Returns arrival-sorted jobs with sequential ids, ready for
    ``Simulator.run`` — and for ``save_trace``, which round-trips them
    bit-for-bit into the native replay format.  Malformed input (missing
    header columns, non-numeric or non-positive token counts, a bad
    timestamp) raises ``ValueError`` naming ``path:line``.
    """
    specs = dict(engines or default_engines())
    if not specs:
        raise ValueError("load_azure_llm_trace: empty engine catalogue")
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        raise _trace_error(path, 1, "empty file, expected a CSV header "
                           "with TIMESTAMP, ContextTokens, "
                           "GeneratedTokens")
    header = [c.strip().lower() for c in lines[0].split(",")]
    cols = {}
    for want in ("timestamp", "contexttokens", "generatedtokens"):
        if want not in header:
            raise _trace_error(path, 1, f"missing column {want!r} "
                               f"(header has {lines[0]!r})")
        cols[want] = header.index(want)
    shapes = sorted((name, spec.prefill_len, spec.decode_len)
                    for name, spec in specs.items())
    rows = []
    for lineno, line in enumerate(lines[1:], 2):
        if not line.strip():
            continue
        cells = line.split(",")
        if len(cells) < len(header):
            raise _trace_error(path, lineno, f"row has {len(cells)} "
                               f"cells, header has {len(header)}")
        at = _azure_timestamp(cells[cols["timestamp"]], path, lineno)
        try:
            ctx = int(float(cells[cols["contexttokens"]]))
            gen = int(float(cells[cols["generatedtokens"]]))
        except ValueError:
            raise _trace_error(path, lineno, "non-numeric token count "
                               f"{line!r}") from None
        if ctx <= 0 or gen <= 0:
            raise _trace_error(path, lineno, f"non-positive token "
                               f"count (ctx={ctx}, gen={gen})")
        rows.append((at, ctx, gen))
        if max_jobs is not None and len(rows) >= max_jobs:
            break
    if not rows:
        raise _trace_error(path, 2, "trace has a header but no rows")
    t0 = min(at for at, _c, _g in rows)
    jobs: List[Job] = []
    for at, ctx, gen in rows:
        best = None
        for name, plen, dlen in shapes:
            mismatch = abs(math.log((ctx / plen) / (gen / dlen)))
            if best is None or mismatch < best[0] - 1e-12:
                best = (mismatch, [(name, plen, dlen)])
            elif mismatch < best[0] + 1e-12:
                best[1].append((name, plen, dlen))
        # engines sharing a request shape tie; spread them by a
        # deterministic token-count hash instead of collapsing the whole
        # trace onto the alphabetically first name
        tied = best[1]
        engine, plen, dlen = tied[(ctx * 31 + gen) % len(tied)]
        q = max(1, round(math.sqrt((ctx / plen) * (gen / dlen))))
        t_qos = qos_scale * qos_threshold(cd, engine, q, qos_percentile)
        jobs.append(Job(0, engine, q, float(t_qos), at - t0,
                        request=Request(ctx, gen), tenant=tenant))
    jobs.sort(key=lambda j: j.arrival)
    for i, j in enumerate(jobs):
        j.id = i
    return jobs


# ---------------------------------------------------------------------------
# failure traces


def _failure_regions(fleet: Sequence[WorkerPool],
                     regions) -> Dict[str, List[str]]:
    """Resolve the ``synth_failures`` regions spec into
    ``{region: [pool names]}``: ``True`` reads ``WorkerPool.region`` tags
    (``synth_fleet(..., regions=k)`` sets them), an int groups the fleet
    round-robin, a mapping is taken as-is (every pool in at most one
    region)."""
    if regions is True:
        groups: Dict[str, List[str]] = {}
        for w in fleet:
            if not w.region:
                raise ValueError(f"pool {w.name!r} has no region tag; "
                                 f"build the fleet with synth_fleet(..., "
                                 f"regions=k) or pass regions=<int|dict>")
            groups.setdefault(w.region, []).append(w.name)
        return groups
    if isinstance(regions, int):
        if regions <= 0:
            raise ValueError("regions must be a positive int")
        groups = {}
        for i, w in enumerate(fleet):
            groups.setdefault(f"r{i % regions}", []).append(w.name)
        return groups
    if isinstance(regions, dict):
        names = {w.name for w in fleet}
        seen: set = set()
        for rname, pools in regions.items():
            if not pools:
                raise ValueError(f"region {rname!r} has no pools")
            for p in pools:
                if p not in names:
                    raise ValueError(f"region {rname!r} names unknown "
                                     f"pool {p!r}")
                if p in seen:
                    raise ValueError(f"pool {p!r} appears in more than "
                                     f"one region")
                seen.add(p)
        return {str(r): list(p) for r, p in regions.items()}
    raise ValueError(f"regions must be True, an int or a mapping, "
                     f"got {regions!r}")


def _flap_events(events: List[FailureEvent],
                 flap: int) -> List[FailureEvent]:
    """Crash-restart flapping: split each outage window into ``flap``
    short pulses at 50% duty cycle — pulse ``i`` covers
    ``[at + i*d/flap, at + i*d/flap + 0.5*d/flap)``.  Same envelope,
    same pool, but every pulse kills and requeues whatever was placed
    during the preceding half-window of apparent health (the
    retry-budget stress case)."""
    if flap <= 1:
        return events
    out: List[FailureEvent] = []
    for e in events:
        step = e.duration / flap
        for i in range(flap):
            out.append(FailureEvent(e.worker, e.at + i * step,
                                    0.5 * step))
    return sorted(out, key=lambda f: f.at)


def synth_failures(fleet: Sequence[WorkerPool], horizon_s: float,
                   mtbf_s: float, mttr_s: float, seed: int = 0,
                   regions=None,
                   correlation: float = 0.5,
                   flap: Optional[int] = None) -> List[FailureEvent]:
    """Synthetic failure traces for fleet-scale robustness runs (the
    simulator re-queues killed jobs).

    Default (``regions=None``): independent per-worker Poisson failures
    with exponential repair times — the original model, byte-identical
    output for a given seed.

    ``regions=`` switches to *correlated multi-region outages*
    (shared-infrastructure failures at the edge: power, uplink, cooling).
    Pools are grouped into regions (``True`` → ``WorkerPool.region``
    tags, int → round-robin, mapping → explicit); each region suffers
    Poisson outage events (mean gap ``mtbf_s``), and every event downs
    ``max(1, round(correlation * len(region)))`` of the region's pools
    *simultaneously* for one shared exponential repair window.  A
    region's next outage is drawn after the previous repair completes,
    so no pool's failure windows ever overlap.

    ``flap=k`` (k > 1) turns every outage into a flapping pool: the
    window is split into ``k`` crash-restart pulses at 50% duty cycle
    (see ``_flap_events``), so pools oscillate between apparent health
    and failure instead of staying down — jobs placed during the
    up-phases get killed and requeued repeatedly, stressing retry
    budgets.  ``None``/``1`` keeps the seed-identical solid windows."""
    rng = np.random.default_rng(seed)
    events: List[FailureEvent] = []
    if regions is None or regions is False:    # False == off, like
        regions = None                         # synth_fleet(disaggregate=)
    if regions is None:
        for w in fleet:
            t = rng.exponential(mtbf_s)
            while t < horizon_s:
                d = rng.exponential(mttr_s)
                events.append(FailureEvent(w.name, float(t), float(d)))
                t += d + rng.exponential(mtbf_s)
        events.sort(key=lambda f: f.at)
        return _flap_events(events, flap) if flap else events
    if not 0.0 < correlation <= 1.0:
        raise ValueError(f"correlation must be in (0, 1], "
                         f"got {correlation}")
    groups = _failure_regions(fleet, regions)
    for rname in sorted(groups):
        pools = groups[rname]
        n_down = max(1, int(round(correlation * len(pools))))
        t = rng.exponential(mtbf_s)
        while t < horizon_s:
            d = rng.exponential(mttr_s)
            down = rng.choice(len(pools), size=n_down, replace=False)
            for i in sorted(down):
                events.append(FailureEvent(pools[i], float(t), float(d)))
            t += d + rng.exponential(mtbf_s)
    events.sort(key=lambda f: f.at)
    return _flap_events(events, flap) if flap else events


def synth_degradations(fleet: Sequence[WorkerPool], horizon_s: float,
                       onset_s: Optional[float] = None,
                       duration_s: Optional[float] = None,
                       factor: float = 3.0, fraction: float = 0.35,
                       prefix: Optional[str] = None,
                       seed: int = 0) -> List[DegradationEvent]:
    """Synthetic *profile-drift* traces: a share of the fleet starts
    running slower than its offline characterization (thermal
    throttling, colocated tenants, a driver regression) while the
    ConfigDict keeps describing the healthy device — the scenario
    ``repro.core.recharacterize`` exists for.

    ``fraction`` of the pools (optionally restricted to names starting
    with ``prefix``, e.g. ``"edge"`` for the battery/thermal-limited
    tier) each get one ``DegradationEvent``: onset jittered uniformly in
    ``[onset_s, 1.25 * onset_s]`` (default ``horizon_s / 3`` — the
    detector's anchor windows see the healthy regime first), duration
    ``duration_s`` (default: through the end of the trace), slowdown
    jittered uniformly in ``[0.8, 1.2] * factor``."""
    if factor <= 0:
        raise ValueError(f"factor must be > 0, got {factor}")
    if not 0.0 < fraction <= 1.0:
        raise ValueError(f"fraction must be in (0, 1], got {fraction}")
    names = [w.name for w in fleet
             if prefix is None or w.name.startswith(prefix)]
    if not names:
        raise ValueError(f"no pool name starts with {prefix!r}")
    rng = np.random.default_rng(seed)
    onset_s = horizon_s / 3.0 if onset_s is None else float(onset_s)
    n = max(1, int(round(fraction * len(names))))
    picks = rng.choice(len(names), size=n, replace=False)
    events = []
    for i in sorted(picks):
        at = float(onset_s * rng.uniform(1.0, 1.25))
        dur = (float(duration_s) if duration_s is not None
               else max(0.0, horizon_s - at) + horizon_s)
        f = float(factor * rng.uniform(0.8, 1.2))
        events.append(DegradationEvent(names[i], at, dur, f))
    return sorted(events, key=lambda d: d.at)
