"""Heterogeneous worker fleet: TPU slices with operating modes.

TPU-native analogue of the paper's testbed (§3.1): an x86 cloud VM plus two
ARM edge boards with mode tables.  Here: one 16-chip cloud slice and two
smaller edge slices whose operating modes mirror Table 2 row-for-row.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.constants import (AGX_LIKE_MODES, CLOUD_MODES, HBM_BW,
                                  HBM_BYTES, NX_LIKE_MODES, PEAK_FLOPS_BF16,
                                  V5P_FLOPS_BF16, V5P_HBM_BW, V5P_HBM_BYTES,
                                  OperatingMode)


@dataclasses.dataclass(frozen=True)
class WorkerPool:
    name: str
    n_chips: int                     # physical chips in the slice
    modes: tuple                     # available operating modes
    mesh_shape: tuple                # physical topology
    is_edge: bool
    chip_flops: float = PEAK_FLOPS_BF16   # per-chip bf16 peak
    chip_hbm_bw: float = HBM_BW
    chip_hbm_bytes: float = HBM_BYTES
    # phase specialization under the disaggregated serving bridge
    # (docs/serving_bridge.md): "both" serves whole jobs (and either phase
    # in a disaggregated cluster); "prefill"/"decode" pools only admit that
    # phase.  Requires ``Simulator(..., serving="batched")``.
    role: str = "both"
    # shared-infrastructure grouping for correlated failure traces
    # (``workload.synth_failures(regions=True)``): pools in one region
    # share power/network and go down together in a regional outage.
    # "" means ungrouped.
    region: str = ""

    @property
    def default_mode(self) -> OperatingMode:
        # The "default configuration" baselines use (paper §5.2: schedulers
        # without the offline phase "rely on predefined configurations,
        # typically selecting the worker with the highest CPU resources"):
        # the stock mode with the most chips online — which, as on real
        # Jetson boards, is a low-clock mode, not MAXN.
        most_chips = max(m.chips_online for m in self.modes)
        cands = [m for m in self.modes if m.chips_online == most_chips]
        return min(cands, key=lambda m: m.clock_scale)

    def hbm_capacity(self, mode: OperatingMode) -> int:
        return min(mode.chips_online, self.n_chips) * self.chip_hbm_bytes

    @property
    def idle_power_w(self) -> float:
        """The pool's static floor while parked: the cheapest idle draw
        across its mode table (a board waiting for work throttles to its
        lowest operating point)."""
        return min(m.idle_power_w() for m in self.modes)


def power_capped_fleet(fleet, cap_w: float,
                       edge_only: bool = True) -> List[WorkerPool]:
    """Energy-capped scenario helper: throttle pools to a power budget
    instead of failing them.

    Each matching pool keeps only the operating modes whose full-load draw
    fits ``cap_w``; if none fit, the pool throttles to its lowest-draw mode
    with ``power_budget_w`` clamped to the cap (the board brown-outs to its
    floor rather than going dark — paper Key Outcome 4: the budget shapes
    which modes are *enabled*).  The capped pools re-characterize to
    different optimal configurations, so run ``offline.characterize`` on
    the returned fleet.  ``edge_only`` leaves cloud pools untouched (the
    usual scenario: a site-level budget on the edge boxes).
    """
    out: List[WorkerPool] = []
    for pool in fleet:
        if edge_only and not pool.is_edge:
            out.append(pool)
            continue
        fits = tuple(m for m in pool.modes if m.power_w() <= cap_w)
        if not fits:
            low = min(pool.modes, key=lambda m: m.power_w())
            fits = (dataclasses.replace(
                low, power_budget_w=min(low.power_budget_w, cap_w)),)
        out.append(dataclasses.replace(pool, modes=fits))
    return out


def default_fleet() -> List[WorkerPool]:
    """Cloud pod = v5p-class chips (the paper's x86 server analogue: the
    most powerful node); edge slices = v5e-class with mode tables."""
    return [
        WorkerPool("cloud-pod", 16, tuple(CLOUD_MODES), (4, 4), False,
                   chip_flops=V5P_FLOPS_BF16, chip_hbm_bw=V5P_HBM_BW,
                   chip_hbm_bytes=V5P_HBM_BYTES),
        WorkerPool("edge-large", 8, tuple(AGX_LIKE_MODES), (2, 4), True),
        WorkerPool("edge-small", 6, tuple(NX_LIKE_MODES), (2, 3), True),
    ]


def synth_fleet(n_cloud: int = 1, n_edge_large: int = 1,
                n_edge_small: int = 1,
                disaggregate=False, regions: int = 0) -> List[WorkerPool]:
    """Synthetic fleet: replicate the three profiled pool archetypes.

    Replica k > 0 of an archetype is named ``<archetype>__<k+1>`` so it
    shares the archetype's Configuration Dictionary profile (see
    ``ConfigDict.optimal``, which strips the ``__`` suffix): a single
    ``characterize()`` over the 3-pool default fleet drives simulations of
    any fleet size — e.g. ``synth_fleet(8, 28, 28)`` is a 64-pool cluster.

    ``disaggregate`` tags replicas for prefill/decode-disaggregated
    serving (``serving="batched"`` only): within each archetype a
    ``prefill``-only share of the replicas (``True`` → 25%, or pass a
    float fraction; at least one when the archetype has ≥ 2 replicas —
    prefill is the short, compute-hot phase) and the rest ``decode``-only.
    Splitting *within* each archetype keeps every engine feasible in both
    phases.  Singleton archetypes stay ``role="both"`` so no engine loses
    a phase.  For explicit placements (e.g. cloud-archetype prefill +
    edge-archetype decode) build the fleet manually and set
    ``dataclasses.replace(pool, role=...)``.

    ``regions > 0`` tags pools with region labels ``r0..r<regions-1>``
    round-robin across the whole fleet, so every region holds a mix of
    archetypes (a regional outage degrades the fleet instead of wiping
    out one archetype).  Feed the tagged fleet to
    ``workload.synth_failures(..., regions=True)`` for correlated
    multi-region failure traces.
    """
    assert n_cloud + n_edge_large + n_edge_small > 0, "empty fleet"
    prefill_frac = 0.25 if disaggregate is True else float(disaggregate)
    out: List[WorkerPool] = []
    counts = (n_cloud, n_edge_large, n_edge_small)
    for pool, n in zip(default_fleet(), counts):
        n_prefill = (min(n - 1, max(1, round(prefill_frac * n)))
                     if n >= 2 else 0)
        for k in range(n):
            name = pool.name if k == 0 else f"{pool.name}__{k + 1}"
            role = "both"
            if disaggregate and n >= 2:
                role = "prefill" if k < n_prefill else "decode"
            out.append(dataclasses.replace(pool, name=name, role=role))
    if regions:
        out = [dataclasses.replace(w, region=f"r{i % regions}")
               for i, w in enumerate(out)]
    return out


def fleet_by_name(fleet=None) -> Dict[str, WorkerPool]:
    return {w.name: w for w in (fleet or default_fleet())}


def region_groups(fleet) -> Dict[str, List[WorkerPool]]:
    """Pools grouped by region tag, in fleet order within each group and
    first-sighting order across groups (the canonical region ordering
    used by ``repro.core.hierarchy``).  An untagged fleet collapses to
    one ``""`` group — which is exactly the hierarchy's flat-equivalence
    case."""
    out: Dict[str, List[WorkerPool]] = {}
    for w in fleet:
        out.setdefault(w.region, []).append(w)
    return out
