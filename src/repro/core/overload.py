"""Deadline-aware load shedding + admission backpressure (docs/robustness.md).

Under sustained overload every queue-growing policy in this repo used to
pay twice: certainly-doomed jobs (already past the point where even the
fleet's best estimate misses their QoS) still occupied worker slots that
feasible jobs needed, and the queue itself grew without bound.  The
``OverloadController`` is the shared shed/backpressure brain consulted by
``SynergAI`` and (per region) ``HierarchicalSynergAI`` during
``schedule``:

* **doom shedding** — the cached ``t_rem < min_est`` predicate from the
  lazy-placement path: ``min_est`` is the job's *best possible* service
  estimate across the fleet (already maintained cross-tick by the
  ``ScoreCache``), so a job whose remaining QoS budget is below it cannot
  complete in time no matter what the scheduler does.  Shedding it is
  O(1) per shed against already-maintained state, and — because the
  depth-penalty factor is always >= 1 — the unpenalized predicate is a
  *certain*-doom test under batching too.
* **queue-depth admission backpressure** — with ``queue_cap`` set, only
  the cap-most-schedulable jobs (the scheduler's own
  ``lexsort((urgency, doomed))`` priority order) stay queued; the excess
  is shed while still fresh instead of aging into doom.  Under the
  hierarchical scheduler each region consults separately, so the cap is
  per region.

The policy only *marks* sheds (and excludes them from placement); the
``Simulator`` drains the marks after each ``schedule`` call and closes
the jobs out with terminal ``JobResult(outcome="shed")`` — policies never
mutate the queue.  A policy constructed without a controller (the
default) takes none of these branches, keeping every historical schedule
bit-for-bit.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.job import Job


class OverloadController:
    """Shed/backpressure decisions for one scheduling policy.

    Parameters
    ----------
    shed_doomed:
        Shed jobs whose remaining QoS budget is below their best-case
        service estimate (``t_rem < min_est``).  Default on.
    queue_cap:
        Admission backpressure: after doom shedding, keep at most this
        many jobs per consulted queue (per region under the hierarchical
        scheduler), shedding from the tail of the scheduler's own
        priority order.  ``None`` (default) means unbounded.
    """

    def __init__(self, shed_doomed: bool = True,
                 queue_cap: Optional[int] = None):
        self.shed_doomed = shed_doomed
        self.queue_cap = queue_cap
        self._pending: List[Job] = []
        # counters (introspection / bench reporting)
        self.shed_doom_total = 0
        self.shed_backpressure_total = 0

    def consult(self, now: float, queue: List[Job], doomed: np.ndarray,
                urgency: np.ndarray) -> Optional[np.ndarray]:
        """Mark sheds for one queue: ``doomed`` is the caller's certain-
        doom mask, ``urgency`` its placement-priority key (lower = served
        sooner).  Returns a bool mask over ``queue`` of jobs the caller
        must skip during placement (``None`` when nothing sheds), and
        records the marked jobs for ``Simulator`` to drain."""
        J = len(queue)
        if J == 0:
            return None
        shed = np.zeros(J, dtype=bool)
        if self.shed_doomed:
            shed |= doomed
            self.shed_doom_total += int(shed.sum())
        cap = self.queue_cap
        if cap is not None:
            alive = J - int(shed.sum())
            if alive > cap:
                # keep the cap-most-schedulable survivors: the same
                # (urgency, doomed-last) order the placement walk uses
                order = np.lexsort((urgency, shed))
                drop = order[cap:]
                drop = drop[~shed[drop]]
                shed[drop] = True
                self.shed_backpressure_total += len(drop)
        if not shed.any():
            return None
        pend = self._pending
        for ji in np.nonzero(shed)[0]:
            pend.append(queue[ji])
        return shed

    def drain(self) -> List[Job]:
        """Hand the marked jobs to the simulator (clears the marks)."""
        out, self._pending = self._pending, []
        return out
