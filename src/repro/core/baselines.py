"""Baseline scheduling policies (paper §5.1): RR, SRR, LRU, MRU, BE.

All baselines use each device's *default configuration* regardless of job
requirements (paper §5.4: "these schedulers utilize the default
configuration of each device").

Dispatch goes through ``Cluster.admit_ok`` — plain idleness in job mode,
plus the serving bridge's batch-formation rules (same-engine batches under
slot/KV budgets) when the simulator runs with ``serving="batched"``, plus
the phase-role match under prefill/decode-disaggregated pools
(``WorkerPool.role``): a baseline never lands a decode phase on a
prefill-only pool.  That is the whole of their streaming awareness — by
design they keep ignoring TTFT/TPOT deadlines, exactly as they ignore
``t_qos`` (paper §5.4), which is what ``bench_streaming`` measures them
against.
"""

from __future__ import annotations

import math
from typing import List

from repro.core.simulator import Assignment, Cluster, Policy


def _entry(cluster: Cluster, engine: str, worker: str, use_default=True):
    ent = (cluster.cd.default_entry(engine, worker) if use_default
           else cluster.cd.optimal(engine, worker))
    if ent is None or ent.qps <= 0:
        return None
    return ent


class RoundRobin(Policy):
    name = "RR"

    def __init__(self):
        self.ptr = 0

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        names = list(cluster.workers)
        out, taken = [], set()
        for job in list(queue):
            placed = False
            for off in range(len(names)):
                w = names[(self.ptr + off) % len(names)]
                if w in taken or not cluster.admit_ok(job, w, now):
                    continue
                ent = _entry(cluster, job.engine, w)
                if ent is None:
                    continue
                out.append(Assignment(job, w, ent))
                taken.add(w)
                self.ptr = (self.ptr + off + 1) % len(names)
                placed = True
                break
            if not placed:
                break  # FIFO: don't skip ahead of the blocked head
        return out


class StrictRoundRobin(Policy):
    """Head job strictly waits for the next worker in rotation."""

    name = "SRR"

    def __init__(self):
        self.ptr = 0

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        if not queue:
            return []
        names = list(cluster.workers)
        job = queue[0]
        # advance past workers that can never run this engine
        for _ in range(len(names)):
            w = names[self.ptr % len(names)]
            if _entry(cluster, job.engine, w) is not None:
                break
            self.ptr += 1
        w = names[self.ptr % len(names)]
        if not cluster.admit_ok(job, w, now):
            return []  # strict: wait for this specific worker
        ent = _entry(cluster, job.engine, w)
        self.ptr += 1
        return [Assignment(job, w, ent)]


class LeastRecentlyUsed(Policy):
    name = "LRU"

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        out, taken = [], set()
        for job in list(queue):
            idle = [(cluster.workers[w].last_freed, w)
                    for w in cluster.idle_workers(now)
                    if w not in taken
                    and cluster.admit_ok(job, w, now)
                    and _entry(cluster, job.engine, w) is not None]
            if not idle:
                break
            _, w = min(idle)
            out.append(Assignment(job, w, _entry(cluster, job.engine, w)))
            taken.add(w)
        return out


class MostRecentlyUsed(Policy):
    name = "MRU"

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        out, taken = [], set()
        for job in list(queue):
            idle = [(cluster.workers[w].last_freed, w)
                    for w in cluster.idle_workers(now)
                    if w not in taken
                    and cluster.admit_ok(job, w, now)
                    and _entry(cluster, job.engine, w) is not None]
            if not idle:
                break
            _, w = max(idle)
            out.append(Assignment(job, w, _entry(cluster, job.engine, w)))
            taken.add(w)
        return out


class BestEffort(Policy):
    """Greedy: iterate from the strongest worker to the weakest."""

    name = "BE"

    def schedule(self, now, queue, cluster) -> List[Assignment]:
        strength = sorted(
            cluster.workers,
            key=lambda w: -(cluster.workers[w].pool.chip_flops
                            * cluster.workers[w].pool.n_chips))
        out, taken = [], set()
        for job in list(queue):
            placed = False
            for w in strength:
                if w in taken or not cluster.admit_ok(job, w, now):
                    continue
                ent = _entry(cluster, job.engine, w)
                if ent is None:
                    continue
                out.append(Assignment(job, w, ent))
                taken.add(w)
                placed = True
                break
            if not placed:
                break
        return out
