"""Two-level hierarchical scheduling: a global region router over
per-region SynergAI cores (ROADMAP "planetary-scale fleets").

The flat scheduler scores every queued job against every pool — even
incrementally (``docs/performance.md``) the per-tick work is linear in
total fleet width W.  PerLLM's edge-cloud collaborative placement
(arXiv:2405.14636) argues the deployable shape is hierarchical: a cheap
constrained upper level routes work between resource *groups*, and the
expensive architecture-aware scoring runs only within a group.  This
module is that split for SynergAI:

* ``RegionRouter`` — the upper level.  It keeps O(k) per-region
  aggregates (per-engine capacity from the shared estimator row tables,
  failure health from the fleet arrays, observed queue pressure, a
  drift-adjusted EWMA of the arriving engine mix) and routes each
  arriving job to a region in O(k).  No per-pool state is touched.
* ``RegionView`` — a read-only facade over one region's slice of a
  ``Cluster``: the struct-of-arrays vector views (availability, busy
  wait, depth penalty, admission) recomputed over the region's columns,
  and a region-interned worker token whose estimator table is a *column
  slice* of the full-fleet table (``estimator.register_region_table`` —
  the region never re-profiles or re-gathers rows the flat table holds).
  An unmodified ``SynergAI`` scheduled against a view behaves exactly as
  if the region were the whole cluster.
* ``HierarchicalSynergAI`` — the policy.  Arrivals are routed
  (``on_arrival``), the queue is partitioned by home region each tick,
  and one persistent per-region ``SynergAI`` (with its own cross-tick
  ``ScoreCache`` over region-sliced rows) places its own partition.
  Failure requeues drop the job's home so it re-routes against live
  aggregates (``on_requeue``).

**Cross-region spillover.**  A region whose partition outruns its open
slots may place its overflow on another region's idle pools — but a
spilled job ships its input over the inter-region WAN first
(``serving_bridge.job_region_xfer_s``, the REGION_XFER link model), so a
spill is taken only when the estimate *plus* the transfer still meets
the job's deadline.  The surcharge rides on ``Assignment.xfer_s`` and is
charged by the simulator as a deterministic service prefix (it delays
the first token).  Disaggregated decode legs never pay it here: crossing
regions at decode moves the KV cache instead, and the simulator charges
that WAN surcharge (``region_xfer_extra_s``) at decode admission.

**Flat equivalence.**  With one region (or an untagged fleet, which is
one ``""`` region) the policy delegates wholesale to a single flat
``SynergAI`` against the real cluster: no routing, no views, no
transfers — the schedule is bit-for-bit identical to flat SynergAI
(``tests/test_hierarchy.py`` pins the PR 2/PR 4 golden digests).

**Invalidation.**  Views and router are rebuilt when the cluster's
membership generation moves; the per-region sub-schedulers (and their
score caches) persist across rebuilds, so an elastic clone appended to
one region extends only that region's cached columns while every other
region's cache stays warm (same serial, same region worker tuple, same
failure generation).  Any failure bumps the shared ``fail_gen`` and
flushes every region's cache — the same conservative rule as flat.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.estimator import engine_rows, register_region_table
from repro.core.job import Job
from repro.core.scheduler import SynergAI
from repro.core.simulator import PHASE_CODE, Assignment, Cluster, Policy

# EWMA horizon for the router's drift-adjusted engine mix: once the
# total count passes this, every count is halved (recent traffic weighs
# ~2x the previous window — cheap, deterministic decay).
_MIX_HALF = 512


class _RegionArrays:
    """The ``names``/``index`` face of ``_FleetArrays`` for one region —
    what ``ScoreCache.sync`` and the placement loops read."""

    __slots__ = ("names", "index")

    def __init__(self, names: List[str]):
        self.names = names
        self.index = {n: i for i, n in enumerate(names)}


class RegionView:
    """One region's slice of a ``Cluster``, duck-typed to the scheduler-
    facing read API (``avail_array`` .. ``admit_engine_mask``,
    ``arrays``, ``serial``/``worker_token``/``fail_gen``).  Every vector
    view replicates the cluster's expression over the region's columns —
    pure comparisons on the same values, so the masks equal the global
    masks sliced, bit-for-bit.  Never mutates the cluster."""

    def __init__(self, cluster: Cluster, region: str, idx,
                 profile: int = 0):
        self._c = cluster
        self.region = region
        self._idx = np.asarray(idx, dtype=np.intp)
        a = cluster.arrays
        self.arrays = _RegionArrays([a.names[i] for i in self._idx])
        self.cd = cluster.cd
        self.serving = cluster.serving
        self.disaggregated = cluster.disaggregated
        # a (cluster, region) pair is a stable cache identity: rebuilt
        # views of the same region keep it, so region score caches
        # survive fleet growth elsewhere
        self.serial = (cluster.serial, region)
        self.worker_token = register_region_table(
            cluster.cd, a.names, self._idx, use_default=False,
            token=cluster.worker_token, profile=profile)

    # -- cache identity -------------------------------------------------

    @property
    def fail_gen(self) -> int:
        return self._c.fail_gen

    # -- serving-bridge delegates --------------------------------------

    def phase_of(self, job: Job) -> str:
        return self._c.phase_of(job)

    # -- vectorized scheduler views over the region's columns ----------

    def avail_array(self, now: float) -> np.ndarray:
        a = self._c.arrays
        i = self._idx
        free = (a.busy_until[i] <= now) & (a.failed_until[i] <= now)
        if self.serving == "batched":
            d = a.depth[i]
            free &= (d == 0) | (d < a.slot_cap[i])
        return free

    def busy_wait_array(self, now: float) -> np.ndarray:
        a = self._c.arrays
        i = self._idx
        return np.maximum(0.0, np.maximum(a.busy_until[i] - now,
                                          a.failed_until[i] - now))

    def depth_penalty_array(self, now: float) -> np.ndarray:
        a = self._c.arrays
        i = self._idx
        pen = np.ones(len(i))
        if self.serving == "batched":
            d = a.depth[i]
            m = ((d > 0) & (a.busy_until[i] <= now)
                 & (a.failed_until[i] <= now) & (d < a.slot_cap[i]))
            if m.any():
                pen[m] = 1.0 + a.alpha[i][m] * d[m]
        return pen

    def admit_engine_mask(self, engine: str, now: float,
                          phase: str = "full") -> np.ndarray:
        a = self._c.arrays
        i = self._idx
        ok = (a.busy_until[i] <= now) & (a.failed_until[i] <= now)
        if self.disaggregated:
            r = a.role[i]
            ok &= (r == 0) | (r == PHASE_CODE[phase])
        if self.serving == "batched":
            d = a.depth[i]
            ok &= (d == 0) | (d < a.slot_cap[i])
            eid = self._c._engine_code.get(engine, -2)
            e = a.engine_id[i]
            ok &= (e == -1) | (e == eid)
        return ok

    # -- router aggregates ---------------------------------------------

    def health(self, now: float) -> float:
        """Fraction of the region's pools not currently failed — the
        router's failure aggregate.  A correlated regional outage drives
        this to 0.0 on the next refresh (one tick), draining the region
        from the routing scores."""
        a = self._c.arrays
        return float((a.failed_until[self._idx] <= now).mean())

    @property
    def roles(self) -> np.ndarray:
        """[W_r] ROLE_CODE per pool (0 == "both") — the router's
        phase-aware capacity mask under disaggregated fleets."""
        return self._c.arrays.role[self._idx]


class RegionRouter:
    """O(k) upper level: per-region aggregates + deterministic routing.

    A job routes to the region minimizing ``(pressure + 1) / (health *
    capacity)`` — queued work per unit of *healthy, mix-weighted*
    throughput — over regions that can serve its engine at all.
    ``capacity`` blends the job's own engine capacity with the
    drift-adjusted mix capacity (an EWMA of the arriving engine mix, so
    a popularity drift re-weights routing without re-profiling).  Ties
    break at the lowest region index; a fully-failed feasible set falls
    back to ignoring health (the jobs must queue somewhere)."""

    def __init__(self, cd, views: Dict[str, RegionView], carbon=None):
        self.cd = cd
        self.views = views
        # optional workload.CarbonTrace: routing scores are weighted by
        # each region's relative grid intensity at decision time, so the
        # router prefers clean-grid regions long before any per-worker
        # scoring happens (None: carbon-blind, bit-for-bit historical)
        self._carbon = carbon
        self._cw: Optional[np.ndarray] = None    # [k] relative intensity
        self._cw_t: Optional[float] = None
        self.regions: List[str] = list(views)
        self._ri = {r: i for i, r in enumerate(self.regions)}
        k = len(self.regions)
        self.home: Dict[int, str] = {}       # job id -> routed region
        self.pressure = np.zeros(k)          # queued jobs seen this tick
        self.healthy = np.ones(k)            # live-pool fraction
        self._cap: Dict[tuple, np.ndarray] = {}  # (engine, phase) -> [k]
        self._counts: Dict[str, float] = {}      # EWMA engine mix
        self._cmix: Optional[np.ndarray] = None  # [k] mix-weighted cap

    def capacity(self, engine: str, phase: str = "full") -> np.ndarray:
        """[k] aggregate feasible throughput (sum of optimal-config qps)
        per region for one engine, from the shared region row tables —
        computed once per (engine, phase, fleet generation).  Under
        disaggregated fleets a ``prefill``/``decode`` phase masks pools
        whose role can't serve it, so a job is never homed to a region
        that could not run its current phase at all."""
        key = (engine, phase)
        cap = self._cap.get(key)
        if cap is None:
            vals = np.empty(len(self.regions))
            for i, v in enumerate(self.views.values()):
                qps = engine_rows(self.cd, engine, v.arrays.names,
                                  token=v.worker_token)[0]
                if phase != "full":
                    roles = v.roles
                    qps = qps * ((roles == 0)
                                 | (roles == PHASE_CODE[phase]))
                vals[i] = qps.sum()
            cap = self._cap[key] = vals
        return cap

    def refresh(self, now: float):
        """Per-tick aggregate update: failure health per region, the
        drift-adjusted mix capacity, and a pressure reset (the partition
        pass rebuilds it from the live queue)."""
        for i, r in enumerate(self.regions):
            self.healthy[i] = self.views[r].health(now)
        self._carbon_w(now)
        self.pressure[:] = 0.0
        total = sum(self._counts.values())
        if total > 0.0:
            cm = np.zeros(len(self.regions))
            for e, c in self._counts.items():
                cm += (c / total) * self.capacity(e)
            self._cmix = cm
        else:
            self._cmix = None

    def _carbon_w(self, now: Optional[float]):
        """[k] relative region carbon intensities at ``now`` (None
        without a trace); memoized per timestamp — ``route`` reuses the
        tick's vector across a whole partition pass."""
        if self._carbon is None:
            return None
        if now is not None and now != self._cw_t:
            self._cw = np.fromiter(
                (self._carbon.relative(r, now) for r in self.regions),
                dtype=np.float64, count=len(self.regions))
            self._cw_t = now
        return self._cw

    def route(self, job: Job, phase: str = "full",
              now: Optional[float] = None) -> str:
        """Pick a home region for ``job``'s current phase (O(k)), pin
        it, and fold the engine into the drift mix.  With a CarbonTrace
        attached, the pressure-per-capacity score is weighted by each
        region's relative intensity at ``now`` — a region on a 2x-dirty
        grid must look 2x better on load to win the job."""
        cap = self.capacity(job.engine, phase)
        blend = (cap if self._cmix is None
                 else 0.5 * cap + 0.5 * self._cmix)
        denom = self.healthy * blend
        ok = (cap > 0) & (denom > 0)
        if not ok.any():
            # every feasible region is down — ignore health; an engine
            # feasible nowhere just takes region 0 (it is doomed anyway)
            ok = cap > 0
            denom = np.maximum(cap, 1e-30)
        if ok.any():
            safe = np.where(ok, denom, 1.0)    # denom > 0 wherever ok
            score = np.where(ok, (self.pressure + 1.0) / safe, np.inf)
            cw = self._carbon_w(now)
            if cw is not None:
                score = score * cw             # inf stays inf: cw > 0
            ri = int(score.argmin())
        else:
            ri = 0
        r = self.regions[ri]
        self.home[job.id] = r
        c = self._counts
        c[job.engine] = c.get(job.engine, 0.0) + 1.0
        if sum(c.values()) > _MIX_HALF:
            for e in c:
                c[e] *= 0.5
        return r

    def note(self, region: str):
        """Count one queued job toward ``region``'s pressure this tick
        (called by the partition pass, so mid-tick routing decisions see
        the backlog accumulated ahead of them)."""
        self.pressure[self._ri[region]] += 1.0

    def blocked(self, region: str, cluster: Cluster,
                now: float) -> Optional[np.ndarray]:
        """[k] bool mask of regions whose WAN link to ``region`` is
        currently severed (``cluster.partitioned_pairs``), or ``None``
        when no partition touches ``region`` — the spillover pass must
        not ship input (or pull KV) across a down link."""
        pairs = cluster.partitioned_pairs(now)
        if not pairs:
            return None
        blk = np.zeros(len(self.regions), dtype=bool)
        hit = False
        for i, r2 in enumerate(self.regions):
            if r2 != region and frozenset((region, r2)) in pairs:
                blk[i] = True
                hit = True
        return blk if hit else None


class HierarchicalSynergAI(Policy):
    """Two-level SynergAI: ``RegionRouter`` over per-region ``SynergAI``
    cores scheduled against ``RegionView`` slices.  With one region (or
    an untagged fleet) delegates wholesale to a single flat ``SynergAI``
    on the real cluster — bit-for-bit the flat schedule."""

    name = "SynergAI-H"
    use_default_config = False

    def __init__(self, score_fn=None, incremental: bool = True,
                 spill: bool = True, recharacterizer=None,
                 energy_weight: float = 0.0, carbon=None,
                 overload=None):
        self._score_fn = score_fn
        self._incremental = incremental
        self.spill = spill
        # one shared OverloadController consulted by every per-region
        # sub-core: each region sheds against its own partition (so
        # ``queue_cap`` is a per-region bound), the marks pool in the
        # shared controller, and the simulator drains them once per tick.
        # None (default) keeps every historical schedule bit-for-bit.
        self.overload = overload
        # the same energy/carbon knob as flat SynergAI, applied at both
        # levels: every per-region core scores with ``energy_weight`` (and
        # its region's intensity via the CarbonTrace), and the router's
        # O(k) aggregates are carbon-weighted so routing itself prefers
        # clean-grid regions.  0.0 is bit-for-bit the energy-blind
        # hierarchy.
        if energy_weight < 0:
            raise ValueError("energy_weight must be >= 0")
        self.energy_weight = float(energy_weight)
        self.carbon = carbon
        # one shared recharacterizer: each region feeds its own drift
        # detector window (observe_arrival(region=...)), any region's
        # trigger runs the single global refresh, and every sub-core's
        # score cache reads the same profile overlay
        self.recharacterizer = recharacterizer
        self.profile = recharacterizer.profile if recharacterizer else 0
        self.router: Optional[RegionRouter] = None
        self._views: Dict[str, RegionView] = {}
        self._subs: Dict[str, SynergAI] = {}
        self._rid: Optional[np.ndarray] = None   # [W] region index
        self._sig = None
        self.spills = 0          # introspection: cross-region placements

    def _sub(self, region: str) -> SynergAI:
        sub = self._subs.get(region)
        if sub is None:
            sub = self._subs[region] = SynergAI(
                score_fn=self._score_fn, incremental=self._incremental,
                recharacterizer=self.recharacterizer,
                energy_weight=self.energy_weight, carbon=self.carbon,
                overload=self.overload)
        return sub

    def _ensure(self, cluster: Cluster):
        sig = (cluster.serial, cluster._member_gen)
        if sig == self._sig:
            return
        groups: Dict[str, List[int]] = {}
        for i, ws in enumerate(cluster.workers.values()):
            groups.setdefault(ws.pool.region, []).append(i)
        self._views = {r: RegionView(cluster, r, idx, profile=self.profile)
                       for r, idx in groups.items()}
        rid = np.empty(len(cluster.workers), dtype=np.intp)
        for ri, idx in enumerate(groups.values()):
            rid[idx] = ri
        self._rid = rid
        old = self.router
        self.router = RegionRouter(
            cluster.cd, self._views,
            carbon=self.carbon if self.energy_weight else None)
        if old is not None:
            # homes and the drift mix survive a fleet change; stale
            # homes of vanished regions re-route at next sighting
            self.router.home = old.home
            self.router._counts = old._counts
        self._sig = sig

    # -- simulator hooks ------------------------------------------------

    def on_arrival(self, job: Job, cluster: Cluster, now: float):
        self._ensure(cluster)
        if len(self._views) > 1 and job.id not in self.router.home:
            self.router.route(job, cluster.phase_of(job), now)
        if self.recharacterizer is not None:
            # per-region drift windows: each region's traffic mix is
            # tracked against its own anchor, so a mix flip confined to
            # one region triggers without diluting into the global mix
            region = (self.router.home.get(job.id, "")
                      if len(self._views) > 1 else "")
            self.recharacterizer.observe_arrival(job, cluster, now,
                                                 region=region)

    def on_complete(self, result, cluster, now):
        if self.recharacterizer is not None:
            self.recharacterizer.observe_complete(
                result, cluster, now, use_default=self.use_default_config)

    def on_requeue(self, job: Job, cluster: Cluster, now: float):
        self._ensure(cluster)
        if len(self._views) > 1:
            # the home region may have just failed — re-route against
            # live aggregates when the job is next seen
            self.router.home.pop(job.id, None)

    def on_terminal(self, job: Job, cluster: Cluster, now: float):
        # a shed/abandoned/failed job never re-enters any queue: reclaim
        # its score-cache row in whichever region core held it (release
        # is a no-op elsewhere) and drop its routing home
        for sub in self._subs.values():
            sub.on_terminal(job, cluster, now)
        if self.router is not None:
            self.router.home.pop(job.id, None)

    # -- the tick --------------------------------------------------------

    def schedule(self, now, queue, cluster: Cluster) -> List[Assignment]:
        if not queue:
            return []
        self._ensure(cluster)
        if len(self._views) <= 1:
            # flat equivalence: one region is just flat SynergAI on the
            # real cluster (no routing, no views, no transfers)
            region = next(iter(self._views), "")
            return self._sub(region).schedule(now, queue, cluster)
        router = self.router
        router.refresh(now)
        disagg = cluster.disaggregated
        parts: Dict[str, List[Job]] = {r: [] for r in router.regions}
        # pressure accumulates in a plain Python list (a numpy scalar
        # add per queued job is ~20x slower) and is flushed to the
        # router only when a routing decision actually reads it
        rix = router._ri
        pcount = [0.0] * len(router.regions)
        capok: Dict[tuple, bool] = {}
        for j in queue:
            phase = cluster.phase_of(j) if disagg else "full"
            r = router.home.get(j.id)
            if r is not None:
                if r not in parts:
                    r = None            # vanished region: re-route
                elif disagg:
                    key = (j.engine, phase, r)
                    ok = capok.get(key)
                    if ok is None:
                        ok = capok[key] = bool(
                            router.capacity(j.engine, phase)[rix[r]] > 0)
                    if not ok:
                        # a phase advance the home can't serve (e.g.
                        # its only decode pools live elsewhere)
                        r = None
            if r is None:
                router.pressure[:] = pcount
                r = router.route(j, phase, now)
            parts[r].append(j)
            pcount[rix[r]] += 1.0
        router.pressure[:] = pcount
        out: List[Assignment] = []
        placed = set()
        for r in router.regions:
            part = parts[r]
            if not part:
                continue
            for a in self._sub(r).schedule(now, part, self._views[r]):
                out.append(a)
                placed.add(a.job.id)
        if self.spill:
            self._spillover(now, cluster, parts, placed, out, disagg)
        for a in out:
            if not disagg or cluster.phase_of(a.job) == "decode":
                # terminal placement: the job will not re-enter the
                # queue (short of a failure, which re-routes anyway)
                router.home.pop(a.job.id, None)
        return out

    # per-tick global budget of per-job spill scans: overflow relief is
    # bounded so a deep standing backlog cannot turn the spill pass into
    # a second full scoring sweep (each scan is a W-wide numpy pass)
    SPILL_SCAN = 64

    def _spillover(self, now, cluster, parts, placed, out, disagg):
        """Overflow relief: a region whose open slots cannot serve its
        leftover jobs' phase may place its overflow on other regions'
        idle pools — charged the REGION_XFER input transfer, and only
        when the estimate plus the transfer still meets the deadline (a
        hopeless spill would burn a remote slot for a violation).

        Slot-starvation is judged per (engine, phase) from a memoized
        [k] mask of regions holding an open slot that *admits* that
        engine and phase: a job whose home region has one keeps waiting
        — its sub-scheduler left the slot open *by choice* (doomed-wait,
        batch engine lock), and spilling would second-guess it.  The
        memo makes the home check O(1) per job; the per-job foreign
        scan is capped at ``SPILL_SCAN`` W-wide passes per tick,
        most-urgent first, so relief cost stays bounded under deep
        backlogs.  The remote estimate uses the full-service row (a
        deliberate heuristic under disaggregation: spill is overload
        relief, the exact phase split stays a region-local concern)."""
        from repro.core.serving_bridge import job_region_xfer_s
        router = self.router
        index = cluster.arrays.index
        names = cluster.arrays.names
        rid = self._rid
        open_slots = cluster.avail_array(now).copy()
        for a in out:
            open_slots[index[a.worker]] = False
        if not open_slots.any():
            return
        batched = cluster.serving == "batched"
        cd = cluster.cd
        k = len(router.regions)
        # memo: (engine, phase) -> [k] "region holds an open slot that
        # admits this engine+phase" (invalidated when a spill consumes
        # a slot) — the O(1)-per-job home-starvation check
        home_ok: Dict[tuple, np.ndarray] = {}
        budget = self.SPILL_SCAN
        for r in router.regions:
            ri = router._ri[r]
            left = [j for j in parts[r] if j.id not in placed]
            if not left:
                continue
            # WAN partitions sever the REGION_XFER link: regions cut off
            # from this home take no spill (input could not ship, and a
            # decode leg could not pull its KV back across the link)
            rblk = router.blocked(r, cluster, now)
            wblk = rblk[rid] if rblk is not None else None
            if len(left) > budget:
                left = sorted(left, key=lambda j: j.t_qos
                              - (now - j.arrival))[:budget]
            for j in left:
                phase = cluster.phase_of(j) if disagg else "full"
                key = (j.engine, phase)
                ok = home_ok.get(key)
                if ok is None:
                    m = open_slots & (engine_rows(
                        cd, j.engine, names,
                        token=cluster.worker_token)[0] > 0)
                    if batched:
                        m &= cluster.admit_engine_mask(j.engine, now,
                                                       phase)
                    ok = home_ok[key] = \
                        np.bincount(rid[m], minlength=k) > 0
                if ok[ri]:
                    # home still has an open slot this job could use —
                    # it is waiting by its sub-scheduler's choice
                    continue
                if budget <= 0 or not open_slots.any():
                    return
                budget -= 1
                qps, pre, _ = engine_rows(cd, j.engine, names,
                                          token=cluster.worker_token)
                with np.errstate(divide="ignore", invalid="ignore"):
                    t = np.where(qps > 0,
                                 pre + float(j.queries) / qps, np.inf)
                elig = open_slots & np.isfinite(t) & (rid != ri)
                if wblk is not None:
                    elig &= ~wblk
                if batched:
                    elig &= cluster.admit_engine_mask(
                        j.engine, now, cluster.phase_of(j))
                if not elig.any():
                    continue
                # decode legs ship KV, not input — the simulator charges
                # that WAN surcharge at admission; don't charge both
                xfer = 0.0 if phase == "decode" else job_region_xfer_s(j)
                cand = np.where(elig, t, np.inf)
                wi = int(cand.argmin())
                if cand[wi] + xfer > j.t_qos - (now - j.arrival):
                    continue        # would violate even if it ran now
                w = names[wi]
                out.append(Assignment(j, w, cd.optimal(j.engine, w),
                                      xfer_s=xfer))
                placed.add(j.id)
                open_slots[wi] = False
                home_ok.clear()      # the consumed slot may back a memo
                self.spills += 1
                if disagg and phase == "prefill":
                    # the KV cache will live where the prefill ran —
                    # point the decode leg's home at it
                    router.home[j.id] = router.regions[rid[wi]]
