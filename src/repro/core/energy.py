"""Energy accounting (paper §5.4, Fig. 12).

TDP-methodology: energy = operating-point power x busy time, accumulated in
the simulator per worker pool (WAN-transfer seconds billed at the idle
floor, see ``simulator``).  Cloud (VM) energy is reported but flagged —
the paper omits cloud energy because VM attribution is not feasible; we keep
the same normalized-edge-energy headline plus the placement shares that
explain SLO-MAEL's higher overall footprint.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core.simulator import Cluster, JobResult
from repro.core.workers import default_fleet


def edge_energy(cluster: Cluster) -> Dict[str, float]:
    return {n: w.energy_j for n, w in cluster.workers.items()
            if w.pool.is_edge}


def idle_energy(cluster: Cluster) -> Dict[str, float]:
    """Per-worker static-floor joules burned while parked (settled by
    ``Simulator.run`` at end of run)."""
    return {n: w.idle_energy_j for n, w in cluster.workers.items()}


def normalized_edge_energy(clusters: Dict[str, Cluster]
                           ) -> Dict[str, Dict[str, float]]:
    """Per-policy edge energy, normalized by the per-pool max across
    policies (the paper's Fig. 12-left normalization).

    Policies may run disjoint fleets: a pool missing from a policy's
    cluster is *omitted* from that policy's row (it never existed there —
    reporting 0.0 would read as "ran cold"), and a pool whose peak across
    all policies is zero normalizes to 0.0 everywhere (nothing burned,
    not energy/1.0).
    """
    per_policy = {pol: edge_energy(c) for pol, c in clusters.items()}
    pools = set()
    for e in per_policy.values():
        pools |= set(e)
    peak = {p: max(e.get(p, 0.0) for e in per_policy.values())
            for p in pools}
    return {pol: {p: (0.0 if peak[p] <= 0.0 else e[p] / peak[p])
                  for p in pools if p in e}
            for pol, e in per_policy.items()}


def _is_edge_worker(worker: str, pools) -> bool:
    pool = pools.get(worker)
    if pool is None:
        # synth_fleet replicas ("cloud-pod__2") and elastic clones
        # ("edge-large__clone1") share the archetype's profile — and its
        # edge-ness
        pool = pools.get(worker.split("__")[0])
    return pool.is_edge if pool is not None else True


def offload_fraction(results: Sequence[JobResult],
                     cluster: Optional[Cluster] = None) -> float:
    """Fraction of jobs offloaded to (non-edge) cloud pools.

    Edge vs cloud resolves through ``WorkerPool.is_edge`` — pass the run's
    cluster so replicated (``cloud-pod__k``), regional and disaggregated
    fleets report correctly; without one, worker names fall back to the
    ``default_fleet`` archetypes (suffix-stripped).
    """
    if cluster is not None:
        pools = {n: ws.pool for n, ws in cluster.workers.items()}
    else:
        pools = {w.name: w for w in default_fleet()}
    cloud = sum(1 for r in results if not _is_edge_worker(r.worker, pools))
    return cloud / max(1, len(results))
