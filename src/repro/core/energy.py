"""Energy accounting (paper §5.4, Fig. 12).

TDP-methodology: energy = operating-point power x busy time, accumulated in
the simulator per worker pool.  Cloud (VM) energy is reported but flagged —
the paper omits cloud energy because VM attribution is not feasible; we keep
the same normalized-edge-energy headline plus the placement shares that
explain SLO-MAEL's higher overall footprint.
"""

from __future__ import annotations

from typing import Dict, Sequence

from repro.core.simulator import Cluster, JobResult


def edge_energy(cluster: Cluster) -> Dict[str, float]:
    return {n: w.energy_j for n, w in cluster.workers.items()
            if w.pool.is_edge}


def normalized_edge_energy(clusters: Dict[str, Cluster]
                           ) -> Dict[str, Dict[str, float]]:
    """Per-policy edge energy, normalized by the per-pool max across
    policies (the paper's Fig. 12-left normalization)."""
    pools = set()
    for c in clusters.values():
        pools |= set(edge_energy(c))
    peak = {p: max(edge_energy(c).get(p, 0.0) for c in clusters.values())
            or 1.0 for p in pools}
    return {pol: {p: edge_energy(c).get(p, 0.0) / peak[p] for p in pools}
            for pol, c in clusters.items()}


def offload_fraction(results: Sequence[JobResult]) -> float:
    """Fraction of jobs offloaded to the (non-edge) cloud."""
    cloud = sum(1 for r in results if r.worker == "cloud-pod")
    return cloud / max(1, len(results))
