"""Online re-characterization — the paper's offline/online loop, closed.

SynergAI's §4 characterization is an *offline* step: per-(engine, worker)
profiles are measured once and the Eq. 1-4 estimator trusts them for the
whole run.  The runtime scheduler is adaptive — every tick re-scores the
queue against live worker state — but the *profiles themselves* are
frozen, so when the world drifts away from them (an edge pool thermally
throttles, a colocated tenant steals cycles, a model update changes an
engine's throughput) every estimate on the drifted rows is silently
wrong: a throttled pool still *looks* fast, keeps winning Eq. 4's
argmin, and QoS violations pile up on it.

``OnlineRecharacterizer`` closes the loop without touching the offline
profiles (they stay the simulator's ground-truth physics).  It maintains
a per-policy *belief overlay* (``estimator.ProfileOverlay``):
multiplicative effective-rate scale factors per (engine, worker) that
the policy's estimator tables and score cache read through a
process-unique ``profile`` id.

**Detection** — two windowed signals, each anchored per regime:

- **Arrival-mix drift** — per-region engine shares over a fixed-size
  window, compared by total-variation distance against the *first*
  window of the current regime (a fixed anchor: smooth drift accumulates
  against it instead of being chased by a moving average).  ``confirm``
  consecutive over-threshold windows trigger.
- **Service residuals** — log(observed solo service / profile
  prediction) per completion.  The observable is ``JobResult.service_s
  / service_pred_s`` — the simulator records both the slowdown+noise
  solo service seconds and the profile model's own prediction for them,
  so the ratio is exactly ``slowdown * exec noise``, free of batch
  contention, transfer time and service-model approximation error.
  The prediction is read through the *current beliefs* (divided by the
  overlay's scale for that cell), so a corrected drift returns the
  residual to zero.  Each window compares the global mean and every
  well-sampled worker's and engine's mean-relative margin against the
  regime's first window; a per-worker rolling deque additionally fires
  as soon as any single pool accumulates ``min_count`` deviating
  samples, without waiting for the global window.  All bars scale with
  the anchor window's noise level (``z * s0 / sqrt(n)``).

**Refresh** — the cheap online re-profile: re-fit per-engine effective
service rates from the last-N completed ``JobResult``s.  The recent
residuals decompose hierarchically (sparse (engine, worker) cells
borrow strength from their margins)::

    log f_{e,w} = m + (mean_e - m) + (mean_w - m)

every effect measured relative to the anchor and installed only when it
clears the same z-significance bar the detector uses — a trigger with
no real physics deviation (an arrival-mix shift, say) refits to *zero
updates* and the schedule stays bit-for-bit unchanged.  Corrections
*compose* multiplicatively onto the already-installed scales
(``scale_{e,w} *= clamp(exp(-log f_{e,w}))``): residuals are
belief-relative, so a fully corrected drift goes quiet by itself while
an under-corrected one keeps deviating, re-fires, and converges on the
true factor.  A pool observed 3x slower than its profile is *believed*
3x slower, so Eq. 2's estimates match reality again and placement
routes around it.

``ProfileOverlay.apply`` bumps the overlay generation;
``ScoreCache.sync`` sees the ``profile_gen`` component of its key move
and reclaims exactly the refreshed engines' cached rows
(``_reclaim_profile``), so cached == uncached stays bit-for-bit through
any interleaving of refreshes, failures and elastic clones.

One instance may be shared by a whole policy tree
(``HierarchicalSynergAI`` passes itself to every per-region core): all
consumers read the same profile id, each region feeds its own mix
window, any region's trigger refreshes the shared overlay once.
"""

from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.engines import engine_catalogue
from repro.core.estimator import engine_rows, new_profile_id, profile_overlay


class _MixWindow:
    """One region's anchored arrival-mix drift test.

    Engine shares over ``window`` arrivals, total-variation distance
    against the regime's first window.  ``add`` returns True when
    ``confirm`` consecutive windows exceed ``threshold``."""

    def __init__(self, window: int, threshold: float, confirm: int):
        self.window = window
        self.threshold = threshold
        self.confirm = confirm
        self.counts: Dict[str, int] = {}
        self.n = 0
        self.anchor: Optional[Dict[str, float]] = None
        self.streak = 0
        self.last_tv = 0.0

    def add(self, engine: str) -> bool:
        self.counts[engine] = self.counts.get(engine, 0) + 1
        self.n += 1
        if self.n < self.window:
            return False
        shares = {e: c / self.n for e, c in self.counts.items()}
        self.counts = {}
        self.n = 0
        if self.anchor is None:
            self.anchor = shares
            return False
        keys = set(shares) | set(self.anchor)
        self.last_tv = 0.5 * sum(
            abs(shares.get(e, 0.0) - self.anchor.get(e, 0.0)) for e in keys)
        self.streak = self.streak + 1 if self.last_tv > self.threshold else 0
        return self.streak >= self.confirm

    def reset(self):
        """New regime (post-refresh): the next window re-anchors."""
        self.anchor = None
        self.streak = 0
        self.counts = {}
        self.n = 0


class _ResidWindow:
    """Anchored service-residual drift test over completions.

    Every ``window`` completions: the global mean log-residual plus
    each worker's and engine's margin *relative to the contemporaneous
    global mean* are compared against the regime's first window
    (relative margins cancel any bias common to the whole fleet).  The
    per-worker terms catch a localized degradation (one throttled pool)
    that the global mean would dilute.  A worker with only a few
    samples still counts — its bar scales with the anchor window's
    noise level (``z * s0 / sqrt(n)``), so a genuine 3x slowdown trips
    on a handful of completions while stationary noise stays ~z sigma
    below (z is deliberately high: the rolling test re-runs at every
    completion across the whole fleet, and the bar has to survive that
    many comparisons without a false fire)."""

    def __init__(self, window: int, threshold: float, min_count: int = 4,
                 z: float = 8.0, k_roll: int = 8):
        self.window = window
        self.threshold = threshold
        self.min_count = min_count
        self.z = z
        self.k_roll = k_roll
        # batched serving stretches every residual by the load-dependent
        # batch multiplier, so the *absolute* global-mean test is
        # confounded there and only runs in job mode; the per-worker and
        # per-engine tests compare margins *relative to the
        # contemporaneous global mean*, which cancels any bias common to
        # the whole fleet (load swings, batching) in both modes
        self.use_global = True
        self.buf: List[Tuple[str, str, float]] = []   # (engine, worker, lr)
        # anchor: (global mean m0, per-worker mean_w - m0,
        #          per-engine mean_e - m0, residual noise std)
        self.anchor: Optional[Tuple[float, Dict[str, float],
                                    Dict[str, float], float]] = None
        # the last completed window's raw samples — when a window fires,
        # these ARE the post-drift evidence, so the refresh re-fits from
        # them instead of a recency deque polluted by pre-drift history
        self.last_buf: Optional[List[Tuple[str, str, float]]] = None
        # per-worker rolling evidence, spanning window boundaries: a
        # badly degraded pool completes so few jobs it may never reach
        # min_count inside one global window — its own last ``k_roll``
        # samples still accumulate and trigger.  Cleared on every
        # refresh so the evidence is epoch-pure (post-correction only).
        self.wdq: Dict[str, Deque[float]] = {}
        # contemporaneous global mean for the rolling check
        self.gdq: Deque[float] = deque(maxlen=4 * k_roll)
        self.last_dev = 0.0

    def add(self, engine: str, worker: str, logresid: float) -> bool:
        self.buf.append((engine, worker, logresid))
        self.gdq.append(logresid)
        dq = self.wdq.get(worker)
        if dq is None:
            dq = self.wdq[worker] = deque(maxlen=self.k_roll)
        dq.append(logresid)
        if (self.anchor is not None and len(dq) >= self.min_count
                and len(self.gdq) >= 2 * self.k_roll):
            _m0, wrel0, _erel0, s0 = self.anchor
            m_roll = sum(self.gdq) / len(self.gdq)
            dev = abs((sum(dq) / len(dq) - m_roll)
                      - wrel0.get(worker, 0.0))
            if dev > max(self.threshold,
                         self.z * s0 / math.sqrt(len(dq))):
                self.last_dev = dev
                return True
        if len(self.buf) < self.window:
            return False
        wsum: Dict[str, float] = {}
        wcnt: Dict[str, int] = {}
        esum: Dict[str, float] = {}
        ecnt: Dict[str, int] = {}
        total = sq = 0.0
        for e, w, lr in self.buf:
            wsum[w] = wsum.get(w, 0.0) + lr
            wcnt[w] = wcnt.get(w, 0) + 1
            esum[e] = esum.get(e, 0.0) + lr
            ecnt[e] = ecnt.get(e, 0) + 1
            total += lr
            sq += lr * lr
        n = len(self.buf)
        m = total / n
        self.last_buf = self.buf
        self.buf = []
        if self.anchor is None:
            s0 = max(0.05, math.sqrt(max(0.0, sq / n - m * m)))
            self.anchor = (m,
                           {w: wsum[w] / wcnt[w] - m for w in wsum},
                           {e: esum[e] / ecnt[e] - m for e in esum},
                           s0)
            return False
        m0, wrel0, erel0, s0 = self.anchor
        fired = self.use_global and abs(m - m0) > self.threshold
        self.last_dev = abs(m - m0) if self.use_global else 0.0
        for margin, rel0 in (((wsum, wcnt), wrel0), ((esum, ecnt), erel0)):
            sums, counts = margin
            for k, c in counts.items():
                if c < self.min_count:
                    continue
                dev = abs((sums[k] / c - m) - rel0.get(k, 0.0))
                bar = max(self.threshold, self.z * s0 / math.sqrt(c))
                self.last_dev = max(self.last_dev, dev)
                if dev > bar:
                    fired = True
        return fired

    def worker_evidence(self) -> Dict[str, Tuple[float, int]]:
        """(mean, count) of each worker's rolling post-refresh samples —
        the refresh's fallback margin for pools too slow to reach
        ``min_count`` inside the firing window."""
        return {w: (sum(dq) / len(dq), len(dq))
                for w, dq in self.wdq.items() if dq}

    def epoch_reset(self):
        """Called after a successful refresh: the beliefs just moved, so
        every buffered belief-relative sample is from the old epoch.
        The anchor survives — residuals of a *corrected* regime return
        to it by construction."""
        self.buf = []
        self.wdq.clear()
        self.gdq.clear()

    def reset(self):
        self.anchor = None
        self.buf = []
        self.last_buf = None
        self.wdq.clear()
        self.gdq.clear()


class OnlineRecharacterizer:
    """Drift detection + estimator refresh for one policy (tree).

    Pass the same instance to ``SynergAI``, ``SloMael`` or
    ``HierarchicalSynergAI``; the policy calls ``observe_arrival`` /
    ``observe_complete`` from its simulator hooks and everything else is
    automatic.  ``seed`` is the oracle entry point for tests/benches: it
    installs the refresh computed from the *true* drift factors,
    skipping detection and re-fit latency entirely.

    Introspection: ``refreshes`` (count), ``triggered_at`` (sim times),
    ``last_reason`` (``"mix:<region>"``, ``"residual"`` or ``"seed"``),
    ``profile`` (the overlay id consumers score through).
    """

    def __init__(self, window: int = 128, threshold: float = 0.3,
                 confirm: int = 2, resid_threshold: float = 0.35,
                 resid_clamp: float = 8.0, detect: bool = True):
        self.window = int(window)
        self.threshold = float(threshold)
        self.confirm = int(confirm)
        self.resid_threshold = float(resid_threshold)
        self.resid_clamp = float(resid_clamp)
        self.detect = bool(detect)
        self.profile = new_profile_id()
        self._min_count = 4
        self._mix: Dict[str, _MixWindow] = {}
        self._resid = _ResidWindow(self.window, self.resid_threshold,
                                   self._min_count)
        self._widx: Optional[Dict[str, int]] = None
        self._widx_sig = None
        self._use_default = False
        self.refreshes = 0
        self.triggered_at: List[float] = []
        self.last_reason = ""

    # -- observation hooks (called by the policies) ---------------------

    def observe_arrival(self, job, cluster, now: float, region: str = ""):
        if not self.detect:
            return
        mw = self._mix.get(region)
        if mw is None:
            mw = self._mix[region] = _MixWindow(
                self.window, self.threshold, self.confirm)
        if mw.add(job.engine):
            self.last_reason = "mix:%s" % (region or "global")
            self.refresh(cluster, now)

    def observe_complete(self, result, cluster, now: float,
                         use_default: bool = False):
        if not self.detect:
            return
        self._use_default = use_default
        e = result.job.engine
        wi = self._worker_index(cluster).get(result.worker)
        if wi is None:
            return
        if (result.prefill_worker is not None
                and result.prefill_worker != result.worker):
            # disaggregated job served by two pools: the solo seconds mix
            # both workers' physics, so the sample attributes to neither
            return
        # observable: the job's *solo* service seconds against the
        # profile model's own prediction for it — their ratio is exactly
        # ``slowdown * exec noise``, free of batch contention, transfer
        # time and service-model approximation error.  The prediction is
        # read through the *current beliefs* (the profile overlay's
        # scale for this cell): a correct refresh drives future
        # residuals back to zero and the detector goes quiet, an
        # under-corrected one keeps deviating and re-fires — successive
        # compositions converge on the true factor
        obs, pred = result.service_s, result.service_pred_s
        if math.isnan(obs) or math.isnan(pred) or pred <= 0 or obs <= 0:
            return
        scale = float(profile_overlay(cluster.cd, self.profile)
                      .factors(e, cluster.arrays.names)[wi])
        if scale > 0:
            pred = pred / scale
        clamp = math.log(self.resid_clamp)
        lr = max(-clamp, min(clamp, math.log(obs / pred)))
        if self._resid.add(e, result.worker, lr):
            self.last_reason = "residual"
            self.refresh(cluster, now)

    # -- refresh ---------------------------------------------------------

    def refresh(self, cluster, now: float):
        """Re-fit effective service rates from the recent completions
        and compose the corrections onto the current beliefs.  A
        mix-triggered refresh re-anchors the mix windows (a new traffic
        regime); the residual anchor is never reset — residuals are
        belief-relative, so a fully corrected drift returns to the
        anchor level by itself and a partial one re-fires."""
        updates = self._refit(cluster)
        if updates:
            profile_overlay(cluster.cd, self.profile).apply(updates)
            self.refreshes += 1
            self.triggered_at.append(now)
            # beliefs moved: buffered belief-relative samples are from
            # the old epoch, drop them (the anchor stays)
            self._resid.epoch_reset()
        if self.last_reason.startswith("mix"):
            for mw in self._mix.values():
                mw.reset()

    def seed(self, cluster, worker_factors: Optional[Dict[str, float]]
             = None, engine_factors: Optional[Dict[str, float]] = None,
             use_default: bool = False):
        """Oracle: install the refresh for the *true* drift — observed
        slowdown factors per worker and/or per engine (1.0 = on-profile,
        3.0 = three times slower than characterized) — with no detection
        or re-fit latency.  The benchmark's upper bound."""
        self._use_default = use_default
        wf = worker_factors or {}
        ef = engine_factors or {}
        cd = cluster.cd
        names = cluster.arrays.names
        tok = cluster.worker_token
        updates: Dict[str, Dict[str, float]] = {}
        for e in engine_catalogue():
            qps, _pre, _f = engine_rows(cd, e, names,
                                        use_default=use_default, token=tok)
            scales = {}
            for i, w in enumerate(names):
                if qps[i] <= 0:
                    continue
                f = wf.get(w, 1.0) * ef.get(e, 1.0)
                if f != 1.0:
                    scales[w] = self._clamp_scale(1.0 / f)
            if scales:
                updates[e] = scales
        if updates:
            profile_overlay(cd, self.profile).apply(updates)
            self.refreshes += 1
            self.triggered_at.append(0.0)
            self.last_reason = "seed"

    def _refit(self, cluster) -> Dict[str, Dict[str, float]]:
        """Backfit residual decomposition over the firing window's
        samples (the post-drift evidence itself — a recency deque would
        dilute it with pre-drift history): ``log f_{e,w} = m + a_e +
        b_w`` with worker effects ``b_w = mean_w - m`` first, then
        engine effects net of them, ``a_e = mean_e(lr - m - b_w)``, so a
        throttled pool doesn't leak into the effect of every engine it
        served.  Margins with fewer than ``min_count`` samples
        contribute zero.  In batched serving the global ``m`` is
        dropped — the depth penalty already models the uniform batch
        bias."""
        # prefer the current epoch's buffer (post-last-refresh samples);
        # a window-close fire just moved it into last_buf, a rolling
        # fire mid-window may leave it short — fall back then
        buf = self._resid.buf
        data = buf if len(buf) >= 2 * self._min_count else (
            self._resid.last_buf or buf)
        if len(data) < 2 * self._min_count or self._resid.anchor is None:
            return {}
        # every effect is measured *relative to the anchor* (which holds
        # the no-drift residual level — the exec-noise log-mean is
        # -sigma^2/2, not 0 — plus any per-margin model bias) and
        # installed only when it clears the same z-significance bar the
        # detector uses: a trigger with no real physics deviation (e.g.
        # an arrival-mix shift) refits to *zero updates* and the
        # schedule stays bit-for-bit unchanged
        m0, wrel0, erel0, s0 = self._resid.anchor
        z = self._resid.z

        def gate(eff: float, c: int) -> float:
            return eff if abs(eff) > z * s0 / math.sqrt(c) else 0.0

        m = sum(lr for _e, _w, lr in data) / len(data)
        m_term = gate(m - m0, len(data))
        wsum: Dict[str, float] = {}
        wcnt: Dict[str, int] = {}
        for _e, w, lr in data:
            wsum[w] = wsum.get(w, 0.0) + lr
            wcnt[w] = wcnt.get(w, 0) + 1
        b = {}
        for w in wsum:
            if wcnt[w] >= self._min_count:
                eff = gate(wsum[w] / wcnt[w] - m - wrel0.get(w, 0.0),
                           wcnt[w])
                if eff:
                    b[w] = eff
        # the per-worker rolling deques override the window means: they
        # hold only the newest (post-previous-refresh) samples, so they
        # are less diluted by jobs dispatched before the drift onset
        # whose residuals straddle the window — and a pool too slow to
        # reach min_count inside the firing data still has its
        # cross-window evidence here
        for w, (wm, c) in self._resid.worker_evidence().items():
            if c >= self._min_count:
                eff = gate(wm - m - wrel0.get(w, 0.0), c)
                if eff:
                    b[w] = eff
                else:
                    b.pop(w, None)
        esum: Dict[str, float] = {}
        ecnt: Dict[str, int] = {}
        for e, w, lr in data:
            esum[e] = esum.get(e, 0.0) + lr - m - b.get(w, 0.0)
            ecnt[e] = ecnt.get(e, 0) + 1
        a = {}
        for e in esum:
            if ecnt[e] >= self._min_count:
                eff = gate(esum[e] / ecnt[e] - erel0.get(e, 0.0), ecnt[e])
                if eff:
                    a[e] = eff
        if not (m_term or a or b):
            return {}
        cd = cluster.cd
        names = cluster.arrays.names
        tok = cluster.worker_token
        ov = profile_overlay(cd, self.profile)
        updates: Dict[str, Dict[str, float]] = {}
        for e in engine_catalogue():
            qps, _pre, _f = engine_rows(cd, e, names,
                                        use_default=self._use_default,
                                        token=tok)
            base = ov.factors(e, names)
            scales = {}
            touched = False
            for i, w in enumerate(names):
                if qps[i] <= 0:
                    continue
                logf = m_term + a.get(e, 0.0) + b.get(w, 0.0)
                # belief-relative correction: compose onto the factor
                # already installed, so repeated refreshes converge on
                # the true drift instead of re-deriving it from scratch
                scales[w] = self._clamp_scale(float(base[i])
                                              * math.exp(-logf))
                if abs(logf) > 1e-9:
                    touched = True
            if touched and scales:
                updates[e] = scales
        return updates

    def _clamp_scale(self, s: float) -> float:
        return max(1.0 / self.resid_clamp, min(self.resid_clamp, s))

    def _worker_index(self, cluster) -> Dict[str, int]:
        sig = (cluster.serial, cluster._member_gen)
        if self._widx is None or self._widx_sig != sig:
            self._widx = {w: i
                          for i, w in enumerate(cluster.arrays.names)}
            self._widx_sig = sig
        return self._widx
