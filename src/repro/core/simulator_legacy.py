"""The seed's tick-scanning simulator loop, preserved as a reference.

``LegacySimulator`` keeps the original ``Simulator.run`` structure: every
iteration rescans all workers, remaining failures and running jobs to find
the next event.  It exists for two reasons:

1. It is the *semantics oracle* — the event-heap engine in
   ``repro.core.simulator`` must reproduce its ``JobResult`` stream exactly
   (see ``tests/test_simulator_engine.py``).
2. It is the "old" side of the old-vs-new wall-clock comparison in
   ``benchmarks/scheduler_experiments.py``.

All per-assignment mechanics (``_start``, ``_speculate``, ``_elastic``) are
inherited, so the two engines share a single implementation of execution
noise, stragglers, speculation and elastic scaling; with ``self._heap``
left as ``None`` the event-heap notification hooks are no-ops here.
"""

from __future__ import annotations

import math
import time
from typing import Dict, List, Sequence

from repro.core.job import Job
from repro.core.simulator import JobResult, Simulator


class LegacySimulator(Simulator):
    name = "legacy"

    def run(self, jobs: Sequence[Job]) -> List[JobResult]:
        if getattr(self, "serving", "job") != "job":
            raise NotImplementedError(
                "LegacySimulator predates the serving bridge; "
                "serving='batched' runs on the event-heap Simulator only")
        # new run, new world (see Simulator.run): flush score caches
        self.cluster._fail_gen += 1
        pending = sorted(jobs, key=lambda j: j.arrival)
        queue: List[Job] = []
        results: List[JobResult] = []
        running: Dict[int, JobResult] = {}
        first_attempt: Dict[int, float] = {}
        decision_time: Dict[int, float] = {}
        failures = list(self.failures)
        now = 0.0
        n_total = len(pending)

        def next_event() -> float:
            cands = []
            if pending:
                cands.append(pending[0].arrival)
            busy = [w.busy_until for w in self.cluster.workers.values()
                    if w.busy_until > now]
            cands += busy
            fail = [f.at for f in failures if f.at > now]
            cands += fail
            recov = [w.failed_until for w in self.cluster.workers.values()
                     if w.failed_until > now]
            cands += recov
            if queue and self.tick:
                cands.append(now + self.tick)
            if running and self.speculative and self.tick:
                cands.append(now + self.tick)  # straggler watchdog
            return min(cands) if cands else math.inf

        guard = 0
        while len(results) < n_total:
            guard += 1
            assert guard < 2_000_000, "simulator livelock"
            # 1) deliver arrivals
            while pending and pending[0].arrival <= now + 1e-12:
                job = pending.pop(0)
                queue.append(job)
                self.policy.on_arrival(job, self.cluster, now)
            # 2) worker failures: kill the running job, re-queue it
            while failures and failures[0].at <= now + 1e-12:
                f = failures.pop(0)
                w = self.cluster.workers[f.worker]
                w.failed_until = f.at + f.duration
                for jid, rec in list(running.items()):
                    if rec.worker == f.worker and rec.end > now:
                        del running[jid]
                        w.busy_until = now
                        queue.append(rec.job)   # checkpoint-restart: requeue
            # 3) complete finished jobs
            for jid, rec in list(running.items()):
                if rec.end <= now + 1e-12:
                    del running[jid]
                    results.append(rec)
                    w = self.cluster.workers[rec.worker]
                    w.last_freed = rec.end
            # 3b) straggler mitigation
            if self.speculative:
                self._speculate(now, running)
            # 3c) elastic scaling
            if self.elastic_max:
                self._elastic(now, queue)
            # 4) ask the policy for assignments
            t0 = time.perf_counter()
            assignments = self.policy.schedule(now, queue, self.cluster)
            dt = time.perf_counter() - t0
            for a in assignments:
                decision_time[a.job.id] = (decision_time.get(a.job.id, 0.0)
                                           + dt / max(1, len(assignments)))
            # track blocked head-of-line attempts (scheduling overhead)
            if not assignments and queue:
                for j in queue[:1]:
                    first_attempt.setdefault(j.id, now)
            for a in assignments:
                self._start(a, now, queue, running, first_attempt,
                            decision_time)
            # 5) advance time
            nxt = next_event()
            if nxt is math.inf and not running and queue:
                # every queued job is infeasible everywhere -> drop loudly
                raise RuntimeError(
                    f"stuck: {[j.engine for j in queue]} infeasible")
            if nxt is math.inf:
                break
            now = max(now, nxt)
        return results
