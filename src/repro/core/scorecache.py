"""Persistent cross-tick score cache for the scheduling hot path
(docs/performance.md).

``SynergAI`` re-scores the whole queue on every simulator tick, but the
quantities behind Eq. 2 are *time-invariant per (job, worker-set)*:
``t_estimated[j, w] = preproc + queries / qps`` never changes while a job
waits.  Only Eq. 1's remaining budget — and everything derived from it
(acceptability, urgency, doom) — decays with the clock.  At fleet scale
(10k queued jobs x 64 pools) rebuilding the full ``[J, W]`` matrix each
tick dominates the per-decision cost, which is exactly the sublinearity
argument PerLLM (arXiv:2405.14636) makes for edge-cloud schedulers.

``ScoreCache`` therefore persists the estimate rows across ticks, keyed
by job id, in a slot pool that survives queue churn:

* **arrivals** append rows (one batched ``score_matrices`` gather per
  tick covers every new job);
* **placements / finishes** just leave their slot behind; slots are
  reclaimed lazily, so a failure-requeued job or a disaggregated decode
  leg that re-enters the queue finds its row still warm;
* **elastic provisioning** (clone pools appended to the fleet) extends
  the cached rows by the new columns only;
* **fleet-generation changes** — failures (``Cluster.fail_gen``) or any
  non-append membership change — flush the cache outright.  Failure
  state never enters these rows, so the flush is pure conservatism: the
  invalidation rule stays one comparison instead of a proof.

Alongside the ``[W]`` rows the cache pins each job's static scalars
(``t_qos``, ``arrival``, ``min_w t_estimated``, streaming deadlines,
decoded-token counts), so a plain tick recomputes the time-decaying
quantities with O(J) vector ops and never touches the matrix at all.
The row values are produced by the exact expressions of
``estimator.estimate_matrix`` / ``phase_split_matrices``, which is what
keeps cached and uncached schedules bit-for-bit identical
(``tests/test_scorecache.py``).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.engines import engine_catalogue
from repro.core.estimator import (energy_matrix, phase_split_matrices,
                                  profile_gen, profile_overlay,
                                  score_matrices)

_GROW = 256          # minimum slot-pool growth (amortized doubling)


class ScoreCache:
    def __init__(self, use_default: bool = False, profile: int = 0):
        self.use_default = use_default
        # profile overlay id (online re-characterization): rows are built
        # from that profile's belief-scaled tables, and the overlay's
        # generation joins the cache key below.  0 (pristine) keeps the
        # generation pinned at 0 — historical behavior, bit-for-bit.
        self.profile = profile
        # cache identity: (cluster serial, interned worker tuple, failure
        # generation, profile generation) — any mismatch is an
        # invalidation event
        self._key = None
        self._names: tuple = ()
        self._W = 0
        self._slot: Dict[int, int] = {}     # job id -> row slot
        self._free: List[int] = []          # recycled slots
        self._next = 0                      # high-water mark of the pool
        self._cap = 0
        self._have_phase = False            # pre/dec rows materialized
        self._have_energy = False           # energy rows materialized
        self._alloc(0, 0)
        # introspection (tests, docs, bench)
        self.flushes = 0
        self.col_extends = 0
        self.rows_computed = 0
        self.profile_reclaims = 0           # slots dropped by a refresh
        self.releases = 0                   # slots freed on terminal exit

    # ------------------------------------------------------------------
    # storage

    def _alloc(self, cap: int, W: int):
        self._cap = cap
        self._t = np.empty((cap, W))        # Eq. 2 full-service rows
        self._min = np.empty(cap)           # min_w of each row
        self._amin = np.empty(cap, np.intp)  # a column attaining that min
        self._pre = np.empty((cap, W)) if self._have_phase else None
        self._dec = np.empty((cap, W)) if self._have_phase else None
        self._ene = np.empty((cap, W)) if self._have_energy else None
        self._qos = np.empty(cap)           # static job scalars
        self._arr = np.empty(cap)
        self._ttft_qos = np.empty(cap)
        self._tpot_qos = np.empty(cap)
        self._dtok = np.empty(cap)
        self._has_ttft = np.empty(cap, bool)
        self._has_tpot = np.empty(cap, bool)
        self._eng: List[Optional[str]] = [None] * cap  # slot -> engine

    def _flush(self, W: int):
        if self._slot:
            self.flushes += 1
        self._slot = {}
        self._free = []
        self._next = 0
        self._have_phase = False
        self._have_energy = False
        self._W = W
        self._alloc(0, W)

    def _grow(self, need: int):
        new_cap = max(self._cap * 2, self._cap + need, _GROW)
        old = self._cap

        def wider(a, shape):
            b = np.empty(shape, a.dtype)
            b[:old] = a
            return b

        self._cap = new_cap
        self._t = wider(self._t, (new_cap, self._W))
        self._min = wider(self._min, new_cap)
        self._amin = wider(self._amin, new_cap)
        if self._have_phase:
            self._pre = wider(self._pre, (new_cap, self._W))
            self._dec = wider(self._dec, (new_cap, self._W))
        if self._have_energy:
            self._ene = wider(self._ene, (new_cap, self._W))
        self._qos = wider(self._qos, new_cap)
        self._arr = wider(self._arr, new_cap)
        self._ttft_qos = wider(self._ttft_qos, new_cap)
        self._tpot_qos = wider(self._tpot_qos, new_cap)
        self._dtok = wider(self._dtok, new_cap)
        self._has_ttft = wider(self._has_ttft, new_cap)
        self._has_tpot = wider(self._has_tpot, new_cap)
        self._eng = self._eng + [None] * (new_cap - old)

    def _reclaim(self, queue):
        """Drop slots whose jobs left the queue (placed / finished)."""
        keep = {j.id for j in queue}
        gone = [jid for jid in self._slot if jid not in keep]
        for jid in gone:
            self._free.append(self._slot.pop(jid))

    def release(self, jid: int) -> bool:
        """Reclaim-on-shed invalidation rule: a job that reached a
        *terminal* outcome without completing (shed / abandoned / failed
        out of its retry budget) never returns to the queue, so its row
        is freed eagerly instead of waiting for the lazy ``_reclaim``
        surplus trigger.  Keeping a dead row warm is harmless for
        correctness but under sustained shedding the surplus would churn
        the slot pool; this keeps the live-row set tracking the queue.
        Returns True when a slot was actually freed."""
        s = self._slot.pop(jid, None)
        if s is None:
            return False
        self._free.append(s)
        self.releases += 1
        return True

    def _reclaim_profile(self, cd, seen_gen: int):
        """Selective profile invalidation: drop exactly the slots whose
        engine was refreshed after ``seen_gen`` (the overlay generation
        this cache last synced at).  Every other row is untouched — the
        minimal-flush rule ``tests/test_recharacterize.py`` pins."""
        touched = profile_overlay(cd, self.profile).touched
        gone = [jid for jid, s in self._slot.items()
                if touched.get(self._eng[s], 0) > seen_gen]
        for jid in gone:
            self._free.append(self._slot.pop(jid))
        self.profile_reclaims += len(gone)

    # ------------------------------------------------------------------
    # synchronization

    def sync(self, cd, queue, cluster) -> np.ndarray:
        """Reconcile the cache with this tick's queue; returns the [J]
        slot indices of ``queue`` (in order) into the row pool."""
        names = cluster.arrays.names
        key = (cluster.serial, cluster.worker_token, cluster.fail_gen,
               profile_gen(cd, self.profile))
        if key != self._key:
            old = self._key
            if old is not None and old[:3] == key[:3]:
                # same cluster, same workers, no failures: only the
                # profile generation moved — an online re-profile.  The
                # overlay's touched log names exactly the refreshed
                # engines; drop only their slots (the rows of every other
                # engine still match the tables bit-for-bit).
                self._reclaim_profile(cd, old[3])
            elif (old is not None and old[0] == key[0] and old[2] == key[2]
                    and old[3] == key[3]
                    and len(names) > len(self._names)
                    and tuple(names[:len(self._names)]) == self._names):
                # same cluster, no failures, same profile, workers
                # appended at the end: elastic provisioning — extend the
                # columns in place
                self._extend_columns(cd, queue, cluster, names)
            else:
                self._flush(len(names))
            self._key = key
            self._names = tuple(names)
        J = len(queue)
        slot = self._slot
        slots = np.fromiter((slot.get(j.id, -1) for j in queue),
                            dtype=np.intp, count=J)
        miss = np.nonzero(slots < 0)[0]
        if miss.size:
            self._insert([queue[i] for i in miss], cd, cluster, slots, miss)
        # lazy slot reclamation: departed rows are left warm (a requeued
        # job reuses its row) until they outnumber the live queue
        if len(slot) - J > max(_GROW, J):
            self._reclaim(queue)
        return slots

    def _row_values(self, jobs, cd, cluster):
        """The exact ``estimate_matrix`` expressions for a batch of jobs:
        [n, W] full-service times (inf where infeasible) + row minima."""
        qps, pre = score_matrices(cd, jobs, list(self._names),
                                  self.use_default,
                                  token=cluster.worker_token,
                                  profile=self.profile)
        q = np.fromiter((float(j.queries) for j in jobs),
                        dtype=np.float64, count=len(jobs))
        with np.errstate(divide="ignore", invalid="ignore"):
            t = np.where(qps > 0, pre + q[:, None] / qps, np.inf)
        return t

    def _insert(self, jobs, cd, cluster, slots, miss):
        n = len(jobs)
        self.rows_computed += n
        dest = np.empty(n, dtype=np.intp)
        free = self._free
        for k in range(n):
            if free:
                dest[k] = free.pop()
            else:
                if self._next >= self._cap:
                    self._grow(n - k)
                dest[k] = self._next
                self._next += 1
        t = self._row_values(jobs, cd, cluster)
        self._t[dest] = t
        self._min[dest] = t.min(axis=1)
        self._amin[dest] = t.argmin(axis=1) if t.shape[1] else 0
        if self._have_phase:
            pre_m, dec_m = phase_split_matrices(
                cd, jobs, list(self._names), self.use_default,
                token=cluster.worker_token, profile=self.profile)
            self._pre[dest] = pre_m
            self._dec[dest] = dec_m
        if self._have_energy:
            self._ene[dest] = energy_matrix(
                cd, jobs, list(self._names), self.use_default,
                token=cluster.worker_token, profile=self.profile)
        engines = engine_catalogue()
        for k, (s, j) in enumerate(zip(dest, jobs)):
            r = j.request
            self._eng[s] = j.engine
            self._qos[s] = j.t_qos
            self._arr[s] = j.arrival
            has_ttft = r is not None and r.ttft_qos is not None
            has_tpot = r is not None and r.tpot_qos is not None
            self._has_ttft[s] = has_ttft
            self._has_tpot[s] = has_tpot
            self._ttft_qos[s] = r.ttft_qos if has_ttft else np.inf
            self._tpot_qos[s] = r.tpot_qos if has_tpot else np.inf
            self._dtok[s] = (
                float(j.queries * engines[j.engine].decode_len)
                if j.engine in engines
                else (float(r.decode_tokens)
                      if r is not None and r.decode_tokens > 0 else np.inf))
            self._slot[j.id] = s
            slots[miss[k]] = s

    def _extend_columns(self, cd, queue, cluster, names):
        """Elastic provisioning appended pools: widen every live row by
        the new columns (recomputing only those), keep everything else."""
        self.col_extends += 1
        old_W = self._W
        new_names = list(names[old_W:])
        W = len(names)
        # rows for jobs no longer queued can't be extended (their Job
        # objects are gone) — reclaim them first
        self._reclaim(queue)

        def widen(a, fill=np.inf):
            b = np.full((self._cap, W), fill)
            b[:, :old_W] = a
            return b

        self._t = widen(self._t)
        if self._have_phase:
            self._pre = widen(self._pre)
            self._dec = widen(self._dec)
        if self._have_energy:
            self._ene = widen(self._ene)
        self._W = W
        live = [(self._slot[j.id], j) for j in queue
                if j.id in self._slot]
        if live:
            sl = np.array([s for s, _ in live], dtype=np.intp)
            jobs = [j for _, j in live]
            qps, pre = score_matrices(cd, jobs, new_names,
                                      self.use_default,
                                      profile=self.profile)
            q = np.fromiter((float(j.queries) for j in jobs),
                            dtype=np.float64, count=len(jobs))
            with np.errstate(divide="ignore", invalid="ignore"):
                t_new = np.where(qps > 0, pre + q[:, None] / qps, np.inf)
            self._t[sl, old_W:] = t_new
            # min over (old row, new columns) == min over the full row;
            # the argmin hint moves only on a strict improvement (ties
            # keep the old column — any minimizing index is valid)
            new_min = t_new.min(axis=1)
            better = new_min < self._min[sl]
            self._amin[sl] = np.where(
                better, old_W + t_new.argmin(axis=1), self._amin[sl])
            self._min[sl] = np.minimum(self._min[sl], new_min)
            if self._have_phase:
                pre_m, dec_m = phase_split_matrices(cd, jobs, new_names,
                                                    self.use_default,
                                                    profile=self.profile)
                self._pre[sl, old_W:] = pre_m
                self._dec[sl, old_W:] = dec_m
            if self._have_energy:
                self._ene[sl, old_W:] = energy_matrix(
                    cd, jobs, new_names, self.use_default,
                    profile=self.profile)

    def ensure_phase_rows(self, cd, queue, slots, cluster):
        """Materialize the prefill/decode split rows (streaming QoS /
        disaggregated scoring) for every live job; later inserts keep
        them up to date.  No-op once enabled."""
        if self._have_phase:
            return
        # stale (departed) slots can't be backfilled — drop them so a
        # requeued job recomputes all three rows together
        self._reclaim(queue)
        self._have_phase = True
        self._pre = np.full((self._cap, self._W), np.inf)
        self._dec = np.full((self._cap, self._W), np.inf)
        if len(queue):
            pre_m, dec_m = phase_split_matrices(
                cd, queue, list(self._names), self.use_default,
                token=cluster.worker_token, profile=self.profile)
            self._pre[slots] = pre_m
            self._dec[slots] = dec_m

    def ensure_energy_rows(self, cd, queue, slots, cluster):
        """Materialize the estimated whole-job energy rows
        (``estimator.energy_matrix``: queries x joules/query, inf where
        infeasible) for every live job — the row source behind
        ``SynergAI(energy_weight=...)``.  Lazy exactly like the phase
        rows: never touched at weight 0, kept up to date by later
        inserts/column extensions, flushed with everything else, and
        subject to the same invalidation rules.  No-op once enabled."""
        if self._have_energy:
            return
        # stale (departed) slots can't be backfilled — drop them so a
        # requeued job recomputes all rows together
        self._reclaim(queue)
        self._have_energy = True
        self._ene = np.full((self._cap, self._W), np.inf)
        if len(queue):
            self._ene[slots] = energy_matrix(
                cd, queue, list(self._names), self.use_default,
                token=cluster.worker_token, profile=self.profile)

    # ------------------------------------------------------------------
    # views (all take the slot vector returned by ``sync``)

    def t_remaining(self, slots, now: float) -> np.ndarray:
        """Eq. 1 for the whole queue, from the cached static scalars."""
        return self._qos[slots] - (now - self._arr[slots])

    def min_estimate(self, slots) -> np.ndarray:
        return self._min[slots]

    def argmin_estimate(self, slots) -> np.ndarray:
        """A column attaining each row's minimum — the fast-path hint
        behind incremental depth-penalty doom: a job whose cheapest
        worker carries no penalty is certainly not doomed, so only jobs
        whose argmin column sits on a live batch gather their row."""
        return self._amin[slots]

    def row(self, s: int) -> np.ndarray:
        """One job's cached [W] estimate row (a view, not a copy)."""
        return self._t[s]

    def t_matrix(self, slots) -> np.ndarray:
        return self._t[slots]

    def phase_matrices(self, slots):
        return self._pre[slots], self._dec[slots]

    def energy_matrix(self, slots) -> np.ndarray:
        return self._ene[slots]

    def energy_row(self, s: int) -> np.ndarray:
        """One job's cached [W] estimated-joules row (a view)."""
        return self._ene[s]

    def waiting(self, slots, now: float) -> np.ndarray:
        return now - self._arr[slots]

    def has_ttft(self, slots) -> np.ndarray:
        return self._has_ttft[slots]

    def has_tpot(self, slots) -> np.ndarray:
        return self._has_tpot[slots]

    def ttft_qos(self, slots) -> np.ndarray:
        return self._ttft_qos[slots]

    def tpot_qos(self, slots) -> np.ndarray:
        return self._tpot_qos[slots]

    def dtok(self, slots) -> np.ndarray:
        return self._dtok[slots]
