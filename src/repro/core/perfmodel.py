"""Roofline performance model: QPS / preprocessing / energy per
(engine x worker x operating mode x chips-per-replica).

This is the measurement instrument of the offline phase.  On real hardware
the numbers would come from profiling runs (as in the paper); in this
container they come from a three-term roofline over analytic FLOPs/bytes —
the same three terms the dry-run extracts from compiled HLO (§Roofline in
EXPERIMENTS.md), so the scheduler is agnostic to the source.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.constants import (ENGINE_INIT_S, HOST_TOKENIZE_S_PER_MB,
                                  ICI_BW, ICI_LINKS, MODEL_LOAD_GBPS,
                                  OperatingMode)
from repro.core.engines import EngineSpec
from repro.core.workers import WorkerPool

HOP_LATENCY_S = 1e-6          # per-ICI-hop latency
STEP_OVERHEAD_S = 30e-6       # host dispatch per executed step
HBM_UTIL = 0.9                # usable fraction of HBM


@dataclasses.dataclass(frozen=True)
class EngineProfile:
    """Analytic per-query workload numbers for one engine."""

    weights_bytes: float
    prefill_flops: float          # per microbatch of queries
    prefill_bytes: float
    decode_flops_per_step: float  # per microbatch decode step
    decode_bytes_per_step: float
    kv_bytes: float               # cache footprint at full context
    coll_bytes_per_step: float    # TP all-reduce payload per layer-pass
    n_steps: int                  # decode steps per query
    microbatch: int


def profile_engine(engine: EngineSpec) -> EngineProfile:
    cfg = engine.cfg
    mb = engine.microbatch
    P, G = engine.prefill_len, engine.decode_len
    bpp = engine.bytes_per_param
    n_active = cfg.active_param_count
    n_total = cfg.param_count
    L, D, H, K, hd = (cfg.n_layers, cfg.d_model, cfg.n_heads,
                      cfg.n_kv_heads, cfg.head_dim)

    weights = n_total * bpp
    ctx = P + G

    # attention score+value FLOPs (quadratic part)
    if cfg.sub_quadratic and cfg.sliding_window:
        eff_ctx = min(ctx, cfg.sliding_window)
    elif cfg.family == "ssm":
        eff_ctx = 0  # recurrence counted via params
    else:
        eff_ctx = ctx
    attn_prefill = 4 * L * H * hd * P * min(P, eff_ctx or P) * mb
    prefill_flops = 2 * n_active * P * mb + attn_prefill
    prefill_bytes = weights + 4 * P * mb * D * L * bpp

    kv_per_tok = (2 * L * K * hd * bpp if cfg.family != "ssm"
                  else 0.0)
    if cfg.mla is not None:
        kv_per_tok = L * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * bpp
    if cfg.family == "ssm":
        hd_r = cfg.ssm.rwkv_head_dim
        kv_state = L * (D // hd_r) * hd_r * hd_r * 4  # f32 state
    else:
        kv_state = kv_per_tok * min(ctx, eff_ctx or ctx)
    kv_bytes = kv_state * mb

    attn_decode = 4 * L * H * hd * (eff_ctx or 1) * mb
    decode_flops = 2 * n_active * mb + attn_decode
    # decode streams every live weight + reads the cache once
    decode_bytes = weights + kv_bytes + 2 * mb * D * L * bpp

    # tensor-parallel payload: 2 all-reduces of [mb, D] per layer
    coll_bytes = 4 * L * mb * D * 2.0

    return EngineProfile(weights, prefill_flops, prefill_bytes,
                         decode_flops, decode_bytes, kv_bytes, coll_bytes,
                         G, mb)


@dataclasses.dataclass(frozen=True)
class ConfigPoint:
    """One point of the per-worker configuration space."""

    mode: OperatingMode
    chips_per_replica: int

    def key(self) -> str:
        return f"{self.mode.name}/r{self.chips_per_replica}"


@dataclasses.dataclass(frozen=True)
class PerfEstimate:
    qps: float                    # queries per second (0 if infeasible)
    query_time_s: float
    preproc_s: float
    power_w: float
    energy_per_query_j: float
    feasible: bool
    bottleneck: str
    decode_frac: float = 0.85     # share of query_time_s in per-token decode
    idle_power_w: float = 0.0     # static floor of the slice at this mode


def estimate(engine: EngineSpec, worker: WorkerPool,
             point: ConfigPoint) -> PerfEstimate:
    prof = profile_engine(engine)
    mode = point.mode
    r = point.chips_per_replica
    chips_online = min(mode.chips_online, worker.n_chips)
    if r > chips_online:
        return PerfEstimate(0.0, math.inf, math.inf, 0.0, math.inf, False,
                            "infeasible:chips")
    # replica must fit: weights + cache + ~20% activations headroom
    need = (prof.weights_bytes + prof.kv_bytes) * 1.2
    if need > r * worker.chip_hbm_bytes * HBM_UTIL:
        return PerfEstimate(0.0, math.inf, math.inf, 0.0, math.inf, False,
                            "infeasible:hbm")

    c = mode.effective_clock()
    peak = worker.chip_flops * (2.0 if engine.precision == "int8" else 1.0)
    flops_rate = r * peak * c
    hbm_rate = r * worker.chip_hbm_bw * c
    ici_rate = ICI_BW * ICI_LINKS / 2  # per-chip usable collective bandwidth

    def phase(flops, byts, steps=1):
        compute = flops / flops_rate
        memory = byts / hbm_rate
        if r > 1:
            ring = 2 * (r - 1) / r
            coll = (prof.coll_bytes_per_step * ring / r) / ici_rate
            coll += 2 * engine.cfg.n_layers * (r - 1) * HOP_LATENCY_S
        else:
            coll = 0.0
        t = max(compute, memory, coll) + STEP_OVERHEAD_S
        dom = max((compute, "compute"), (memory, "memory"),
                  (coll, "collective"))[1]
        return t * steps, dom

    t_prefill, dom_p = phase(prof.prefill_flops, prof.prefill_bytes)
    t_dec_step, dom_d = phase(prof.decode_flops_per_step,
                              prof.decode_bytes_per_step)
    t_decode = prof.n_steps * t_dec_step
    query_time = t_prefill + t_decode
    qps = prof.microbatch / query_time
    decode_frac = t_decode / query_time

    preproc = (ENGINE_INIT_S + prof.weights_bytes / MODEL_LOAD_GBPS
               + HOST_TOKENIZE_S_PER_MB
               * (prof.microbatch * engine.prefill_len * 4 / 1e6))
    power = mode.power_w()
    energy = power * query_time / prof.microbatch
    bottleneck = dom_d if t_decode > t_prefill else dom_p
    return PerfEstimate(qps, query_time, preproc, power, energy, True,
                        bottleneck, decode_frac, mode.idle_power_w())


def config_space(engine: EngineSpec, worker: WorkerPool):
    """All (mode x chips-per-replica) points for a worker."""
    points = []
    for mode in worker.modes:
        online = min(mode.chips_online, worker.n_chips)
        r = 1
        while r <= online:
            points.append(ConfigPoint(mode, r))
            r *= 2
        if online not in [p.chips_per_replica for p in points
                          if p.mode == mode]:
            points.append(ConfigPoint(mode, online))
    return points
