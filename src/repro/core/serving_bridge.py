"""Batch/queue-aware serving bridge: continuous batching inside the
cluster simulator.

The job-level simulator treats a job as an opaque duration — ``exec_time``
seconds of exclusive worker occupancy.  Real inference engines
(``repro.serving.engine.InferenceEngine``) serve *batched* traffic: a
prefill pass admits a request into the running batch, per-token decode
steps serve every batch member together, and the batch is bounded by the
KV-cache bytes that fit next to the weights.  This module is the bridge
between the two: a token-level request model plus the profile math behind
``repro.core.simulator.BatchedWorkerSim``, the continuous-batching service
model selected with ``Simulator(..., serving="batched")``.

Model (see ``docs/serving_bridge.md`` for the full design note):

* **Requests** — ``repro.core.job.Request`` carries a job's total prompt
  and decode token counts.  ``repro.core.workload.attach_requests``
  Pareto-samples them around each engine's profiled per-query shape.
* **Rates from the ConfigDict** — each ``Entry`` stores ``qps`` and
  ``decode_frac`` (share of query time spent in per-token decode), so the
  solo token rates are ``prefill_rate = prefill_len * qps / (1 - df)`` and
  ``decode_rate = decode_len * qps / df``.  A job with the engine-default
  token counts therefore takes exactly ``exec_time(entry, queries)``
  seconds when served alone — job-level and token-level modes agree at
  batch size 1.
* **Continuous batching** — a batch of ``b`` same-engine jobs drains each
  member at multiplier ``m(b) = 1 / (1 + alpha * (b - 1))`` of its solo
  rate, i.e. aggregate throughput ``b * m(b)`` grows sublinearly with
  ``alpha`` taken from the entry's profiled bottleneck (memory-bound
  decode batches almost for free; compute-bound engines pay more).
* **Batch formation** — a worker admits a job iff the batch is empty or
  (same engine) and (``len(batch) < max_batch``) and one more microbatch
  KV cache fits: ``kv_limit = floor((hbm / 1.2 - weights) / kv_bytes)``,
  the analytic counterpart of ``InferenceEngine.cache_footprint`` built
  from ``repro.core.perfmodel.profile_engine``.

The simulator re-estimates every member's completion on each batch change
and feeds the new times through the event heap; schedulers see the batch
through ``Cluster.depth_penalty`` (queue-depth-adjusted latency,
``1 + alpha * b`` for joining a batch of ``b``) and ``Cluster.admit_ok``
(same-engine / slot / KV eligibility — and, under prefill/decode-
disaggregated pools, the phase-role match).  The prefill/decode split
also powers the streaming-QoS view (per-request TTFT/TPOT with
``Request.ttft_qos`` / ``tpot_qos`` deadlines) and the disaggregated
handoff cost (``kv_transfer_s``); design note ``docs/serving_bridge.md``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

from repro.core.configdict import Entry
from repro.core.engines import EngineSpec
from repro.core.job import Request, exec_time
from repro.core.perfmodel import HBM_UTIL, profile_engine
from repro.core.workers import WorkerPool

# batching efficiency per profiled bottleneck: the marginal cost ``alpha``
# of one extra batch member, relative to its solo service rate.  Decode on
# a memory-bound engine streams the same weights for every member, so an
# extra member is nearly free; compute-bound engines pay close to the
# member's full FLOP cost.
BATCH_ALPHA = {"memory": 0.15, "collective": 0.35, "compute": 0.6}
DEFAULT_ALPHA = 0.5

# prefill->decode KV handoff link for disaggregated pools (pool roles in
# ``repro.core.workers.WorkerPool.role``): an edge<->cloud datacenter link,
# far slower than on-package HBM but wide enough that steady-state cache
# streaming overlaps decode.
DISAGG_XFER_GBPS = 10e9        # bytes/s
DISAGG_XFER_LAT_S = 0.005      # one-way link latency


def batch_multiplier(alpha: float, b: int) -> float:
    """Per-member service-rate multiplier at batch size ``b`` (solo = 1)."""
    if b <= 1:
        return 1.0
    return 1.0 / (1.0 + alpha * (b - 1))


def batch_throughput(alpha: float, b: int) -> float:
    """Aggregate batch throughput in units of one solo stream."""
    return b * batch_multiplier(alpha, b)


def default_request(spec: EngineSpec, queries: int) -> Request:
    """The engine-default token counts for a job of ``queries`` queries."""
    return Request(queries * spec.prefill_len, queries * spec.decode_len)


_profile = functools.lru_cache(maxsize=None)(profile_engine)


def decode_fraction(entry: Entry) -> float:
    """Entry.decode_frac clamped away from 0/1 so both token rates stay
    finite (degenerate all-prefill / all-decode profiles)."""
    return min(max(entry.decode_frac, 0.05), 0.95)


def prefill_prefix(entry: Entry, queries: int) -> float:
    """Solo seconds to the first decoded token for ``queries`` queries at
    the engine-default token counts: the admission + prefill share of
    ``exec_time``.  The single scalar source for every TTFT estimate
    (job-mode metrics, speculation, SLO-MAEL planning); the vectorized
    counterparts are ``job.streaming_threshold`` and
    ``estimator.phase_split_matrices``."""
    full = exec_time(entry, queries)
    return min(full, entry.preproc_s + (queries / entry.qps)
               * (1.0 - decode_fraction(entry)))


@dataclasses.dataclass(frozen=True)
class BatchProfile:
    """Per-(entry, engine, pool) serving rates and batch budgets."""

    prefill_rate: float     # prompt tokens / s, job served alone
    decode_rate: float      # decode tokens / s, job served alone
    kv_limit: int           # max concurrent jobs by KV-cache bytes
    kv_job_bytes: float     # one microbatch cache (per in-flight job)
    alpha: float            # marginal batching cost (bottleneck-derived)


@functools.lru_cache(maxsize=None)
def batch_profile(entry: Entry, spec: EngineSpec,
                  pool: WorkerPool) -> BatchProfile:
    """Token rates + batch budgets for one (engine, worker) deployment.

    Rates are calibrated so the engine-default token counts reproduce the
    profiled ``exec_time`` exactly; the KV budget mirrors the feasibility
    check in ``repro.core.perfmodel.estimate`` (weights + caches + 20%
    activation headroom must fit the replica's HBM).
    """
    df = decode_fraction(entry)
    prefill_rate = spec.prefill_len * entry.qps / (1.0 - df)
    decode_rate = spec.decode_len * entry.qps / df
    prof = _profile(spec)
    budget = entry.chips_per_replica * pool.chip_hbm_bytes * HBM_UTIL
    free = budget / 1.2 - prof.weights_bytes
    if prof.kv_bytes > 0:
        kv_limit = max(1, int(free // prof.kv_bytes))
    else:
        kv_limit = 1 << 30
    alpha = BATCH_ALPHA.get(entry.bottleneck, DEFAULT_ALPHA)
    return BatchProfile(prefill_rate, decode_rate, kv_limit,
                        prof.kv_bytes, alpha)


def solo_service(entry: Entry, prof: BatchProfile,
                 request: Optional[Request], queries: int):
    """(work_s, prefill_s): a job's total solo service seconds, and the
    prefix of that spent in admission + prefill (the rest is per-token
    decode).

    Without a ``Request`` the total is ``exec_time(entry, queries)``
    bit-for-bit, so forcing ``max_batch=1`` reproduces the job-level
    simulator exactly.  With a ``Request`` the token counts modulate the
    service time through the calibrated rates.
    """
    if request is None:
        return exec_time(entry, queries), prefill_prefix(entry, queries)
    prefill = entry.preproc_s + request.prompt_tokens / prof.prefill_rate
    return prefill + request.decode_tokens / prof.decode_rate, prefill


def kv_transfer_s(prof: BatchProfile) -> float:
    """Prefill -> decode handoff delay for one job under disaggregated
    pools: one microbatch KV cache (``prof.kv_job_bytes``, from
    ``perfmodel.profile_engine``) over the disaggregation link.  That is
    the pipeline-fill cost — later microbatches stream while earlier ones
    decode, so the job pays the link once, not per query.

    The staging is *pull-style*: the cache is parked on the prefill pool
    until the decode placement is known, and the decode pool pulls it at
    admission — so a decode leg that lands back on the same
    ``role="both"`` pool pays nothing (the cache never moves), and a
    prefill-pool failure before the pull loses the parked cache (the job
    re-prefills).  The simulator charges this delay as the head of the
    decode member's service."""
    return DISAGG_XFER_LAT_S + prof.kv_job_bytes / DISAGG_XFER_GBPS


def kv_region_transfer_s(prof: BatchProfile) -> float:
    """``kv_transfer_s`` over the inter-region WAN link instead of the
    in-region disaggregation fabric: what a decode leg pays when it lands
    in a *different region* than its prefill pool."""
    from repro.core.constants import REGION_XFER_GBPS, REGION_XFER_LAT_S
    return REGION_XFER_LAT_S + prof.kv_job_bytes / REGION_XFER_GBPS


def region_xfer_extra_s(prof: BatchProfile) -> float:
    """The WAN surcharge on a cross-region KV handoff: the inter-region
    transfer minus the in-region one already charged at admission (never
    negative — the WAN link is strictly worse on both axes)."""
    return max(0.0, kv_region_transfer_s(prof) - kv_transfer_s(prof))


def region_transfer_s(payload_bytes: float) -> float:
    """Seconds to ship ``payload_bytes`` over the inter-region link —
    the REGION_XFER model behind cross-region *placement* (a spilled job's
    input leaves its staged region)."""
    from repro.core.constants import REGION_XFER_GBPS, REGION_XFER_LAT_S
    return REGION_XFER_LAT_S + payload_bytes / REGION_XFER_GBPS


def job_region_xfer_s(job, engines: Optional[dict] = None) -> float:
    """Cross-region input-shipping cost for one job: its prompt tokens
    (the ``Request`` when present, else the engine-default shape) at
    ``TOKEN_BYTES`` each over the REGION_XFER link.  Decode legs of
    disaggregated jobs ship KV instead (``region_xfer_extra_s``, charged
    by the simulator at decode admission) — don't charge both."""
    from repro.core.constants import TOKEN_BYTES
    if job.request is not None:
        tokens = job.request.prompt_tokens
    else:
        if engines is None:
            from repro.core.engines import engine_catalogue
            engines = engine_catalogue()
        spec = engines.get(job.engine)
        tokens = job.queries * spec.prefill_len if spec is not None else 0
    return region_transfer_s(tokens * TOKEN_BYTES)


def batch_stats(cluster) -> Dict[str, Dict[str, float]]:
    """Per-worker serving-bridge stats for demos and benchmarks."""
    from repro.core.simulator import BatchedWorkerSim
    out: Dict[str, Dict[str, float]] = {}
    for name, ws in cluster.workers.items():
        if isinstance(ws, BatchedWorkerSim) and ws.admitted:
            out[name] = {
                "admitted": ws.admitted,
                "peak_batch": ws.peak_batch,
                "prefill_tokens": ws.prefill_tokens,
                "decoded_tokens": ws.decoded_tokens,
                "abandoned": ws.abandoned,
            }
    return out


def __getattr__(name):
    # BatchedWorkerSim lives next to WorkerSim in repro.core.simulator (the
    # simulator imports this module's math at load time, so the class
    # can't live here without an import cycle); re-export it lazily so
    # ``from repro.core.serving_bridge import BatchedWorkerSim`` works.
    if name in ("BatchedWorkerSim", "_InFlight"):
        from repro.core import simulator
        return getattr(simulator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
