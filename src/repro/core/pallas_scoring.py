"""SynergAI scoring on the Pallas kernel — a drop-in ``score_fn``.

``make_pallas_score_fn`` builds the dense ``[J, W]`` qps/preproc matrices
from the Configuration Dictionary (cached rows shared with the numpy
estimator via ``score_matrices``), runs
``repro.kernels.scheduler_score`` — interpret mode on CPU, compiled on
TPU — and adapts the outputs to ``ScoreResult`` so that

    SynergAI(score_fn=make_pallas_score_fn())

is a drop-in replacement for the default numpy path.  Parity (identical
assignments at fleet scale, padding edges included) is enforced by
``tests/test_pallas_parity.py`` over profiled catalogues.  One caveat:
the kernel scores in float32, so a job whose remaining QoS budget ties
its estimated time to the last float64 bit can flip between acceptable
and doomed relative to the numpy scorer — real profiles keep orders of
magnitude more margin than that, but exact boundary ties are not part of
the guarantee.
"""

from __future__ import annotations

import numpy as np

from repro.core.estimator import ScoreResult, score_matrices


def make_pallas_score_fn(bj: int = 128, interpret: bool = True):
    from repro.kernels.scheduler_score import scheduler_score

    def score_fn(cd, jobs, workers, now, use_default=False) -> ScoreResult:
        t_rem = np.array([j.t_qos - (now - j.arrival) for j in jobs])
        if not jobs:
            z = np.zeros((0, len(workers)))
            return ScoreResult(list(workers), z, t_rem, z.astype(bool),
                               np.zeros(0, np.int64), np.zeros(0),
                               np.zeros(0, bool))
        qps, pre = score_matrices(cd, jobs, workers, use_default)
        q = np.array([float(j.queries) for j in jobs], np.float32)
        est, best, urg, acc = scheduler_score(
            qps.astype(np.float32), pre.astype(np.float32), q,
            t_rem.astype(np.float32), bj=bj, interpret=interpret)
        # BIG-sentinel entries (qps <= 0) become inf so candidate_order's
        # feasibility filter behaves exactly like the numpy path
        t_est = np.where(qps > 0, np.asarray(est, np.float64), np.inf)
        acceptable = np.asarray(acc).astype(bool)
        return ScoreResult(list(workers), t_est, t_rem, acceptable,
                           np.asarray(best, np.int64),
                           np.asarray(urg, np.float64),
                           ~acceptable.any(axis=1))

    return score_fn
