"""SynergAI scoring on the Pallas kernels — drop-in ``score_fn``s.

``make_pallas_score_fn()`` builds the dense ``[J, W]`` qps/preproc
matrices from the Configuration Dictionary (cached rows shared with the
numpy estimator via ``score_matrices``), runs
``repro.kernels.scheduler_score`` — interpret mode on CPU, compiled on
TPU — and adapts the outputs to ``ScoreResult`` so that

    SynergAI(score_fn=make_pallas_score_fn())

is a drop-in replacement for the default numpy path.

``make_pallas_score_fn(v2=True)`` returns the *fused* backend instead:
``repro.kernels.scheduler_score.scheduler_score_v2`` folds the batched
queue-depth penalty, the prefill/decode phase slicing of disaggregated
pools, and the TTFT/TPOT streaming gates into the same kernel pass, so

    SynergAI(score_fn=make_pallas_score_fn(v2=True))

covers ``serving="batched"`` + streaming scoring on-accelerator with no
numpy post-processing.  The fused callable carries ``fused = True`` and
is invoked by ``SynergAI`` with the cached solo matrices
(``repro.core.scorecache``) plus the per-tick cluster vectors — see
``SynergAI._schedule_fused`` for the exact contract.

Parity (identical assignments at fleet scale, padding edges included) is
enforced by ``tests/test_pallas_parity.py`` over profiled catalogues.
One caveat: the kernels score in float32, so a job whose remaining QoS
budget ties its estimated time to the last float64 bit can flip between
acceptable and doomed relative to the numpy scorer — real profiles keep
orders of magnitude more margin than that, but exact boundary ties are
not part of the guarantee.
"""

from __future__ import annotations

import numpy as np

from repro.core.estimator import ScoreResult, score_matrices


def make_pallas_score_fn(bj: int = 128, interpret: bool = True,
                         v2: bool = False, device_cache: bool = False):
    if device_cache:
        return _make_device_marker(bj, interpret)
    if v2:
        return _make_fused_score_fn(bj, interpret)
    from repro.kernels.scheduler_score import scheduler_score

    def score_fn(cd, jobs, workers, now, use_default=False,
                 token=None) -> ScoreResult:
        if not jobs:
            return ScoreResult.empty(workers)
        t_rem = np.array([j.t_qos - (now - j.arrival) for j in jobs])
        qps, pre = score_matrices(cd, jobs, workers, use_default, token)
        q = np.array([float(j.queries) for j in jobs], np.float32)
        est, best, urg, acc = scheduler_score(
            qps.astype(np.float32), pre.astype(np.float32), q,
            t_rem.astype(np.float32), bj=bj, interpret=interpret)
        # BIG-sentinel entries (qps <= 0) become inf so candidate_order's
        # feasibility filter behaves exactly like the numpy path
        t_est = np.where(qps > 0, np.asarray(est, np.float64), np.inf)
        acceptable = np.asarray(acc).astype(bool)
        return ScoreResult(list(workers), t_est, t_rem, acceptable,
                           np.asarray(best, np.int64),
                           np.asarray(urg, np.float64),
                           ~acceptable.any(axis=1))

    score_fn.takes_token = True
    return score_fn


def _make_device_marker(bj: int, interpret):
    """``make_pallas_score_fn(device_cache=True)`` — the device-resident
    backend.  Unlike the other variants this is a *marker*, not a scoring
    callable: ``SynergAI`` consumes its attributes to build a
    ``repro.core.devicecache.DeviceScoreCache`` (persistent on-device row
    pools) and routes every tick through the fused ``scheduler_tick``
    kernel dispatch, so no host-side score function ever runs.
    ``interpret=None`` auto-selects (compiled on TPU, interpret
    elsewhere)."""
    def device_score(*_a, **_k):
        raise TypeError(
            "make_pallas_score_fn(device_cache=True) returns a backend "
            "marker consumed by SynergAI, not a callable score_fn — the "
            "tick runs through DeviceScoreCache.device_tick")
    device_score.device_cache = True
    device_score.takes_profile = True
    device_score.bj = bj
    device_score.interpret = interpret
    return device_score


def _make_fused_score_fn(bj: int, interpret: bool):
    from repro.kernels.scheduler_score import scheduler_score_v2

    def fused_score(t_solo, pre_m, dec_m, t_rem, pen, phase, has_ttft,
                    has_tpot, ttft_rem, tpot_qos, dtok):
        """(t_eff, acceptable, urgency, doomed) — the fused batched +
        streaming + disaggregated scoring pass, as float64/bool numpy
        (``inf`` marks infeasible pairs, exactly like the numpy path)."""
        f32 = lambda a: np.asarray(a, np.float32)
        est, acc, urg, doom = scheduler_score_v2(
            f32(t_solo), f32(pre_m), f32(dec_m), f32(t_rem), f32(pen),
            np.asarray(phase, np.int32), np.asarray(has_ttft, np.int32),
            np.asarray(has_tpot, np.int32), f32(ttft_rem), f32(tpot_qos),
            f32(dtok), bj=bj, interpret=interpret)
        return (np.asarray(est, np.float64),
                np.asarray(acc).astype(bool),
                np.asarray(urg, np.float64),
                np.asarray(doom).astype(bool))

    fused_score.fused = True
    return fused_score
