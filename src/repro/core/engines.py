"""Inference-engine catalogue (paper Table 1 analogue).

An engine = (architecture x precision x request shape).  Twelve engines
mirror the paper's twelve MLPerf engine variants; quantized variants play
the role of the paper's quantized MobileNets.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict

from repro.configs.registry import ARCHS, get_config


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    name: str
    arch: str
    precision: str = "bf16"          # bf16 | int8
    prefill_len: int = 512           # tokens of prompt per query
    decode_len: int = 128            # generated tokens per query
    microbatch: int = 8              # requests served together

    @property
    def bytes_per_param(self) -> float:
        return 1.0 if self.precision == "int8" else 2.0

    @property
    def cfg(self):
        return get_config(self.arch)


def default_engines() -> Dict[str, EngineSpec]:
    engines = [
        EngineSpec("danube-1.8b/bf16", "h2o-danube-1.8b"),
        EngineSpec("gemma-2b/bf16", "gemma-2b"),
        EngineSpec("gemma-2b/int8", "gemma-2b", precision="int8"),
        EngineSpec("qwen3-32b/bf16", "qwen3-32b", microbatch=4),
        EngineSpec("qwen3-4b/bf16", "qwen3-4b"),
        EngineSpec("qwen3-4b/int8", "qwen3-4b", precision="int8"),
        EngineSpec("rwkv6-1.6b/bf16", "rwkv6-1.6b"),
        EngineSpec("llama32-vision/bf16", "llama-3.2-vision-11b",
                   microbatch=4),
        EngineSpec("phi3.5-moe/bf16", "phi3.5-moe-42b-a6.6b", microbatch=4),
        EngineSpec("deepseek-v2/int8", "deepseek-v2-236b", precision="int8",
                   microbatch=2),
        EngineSpec("hymba-1.5b/bf16", "hymba-1.5b"),
        EngineSpec("seamless-m4t/bf16", "seamless-m4t-medium",
                   prefill_len=1024, decode_len=64),
    ]
    return {e.name: e for e in engines}


@functools.lru_cache(maxsize=None)
def engine_catalogue() -> Dict[str, EngineSpec]:
    """Cached ``default_engines()`` for per-tick / per-arrival hot paths
    (scheduler streaming gates).  Treat the returned dict as read-only —
    callers that want their own copy use ``default_engines()``."""
    return default_engines()
