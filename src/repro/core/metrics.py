"""Evaluation metrics (paper §5.1): violations, waiting, end-to-end,
excess time, tail latency, scheduling overhead, energy, placement."""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.simulator import Cluster, JobResult


def summarize(results: Sequence[JobResult]) -> Dict[str, float]:
    e2e = np.array([r.e2e for r in results])
    waiting = np.array([r.waiting for r in results])
    excess = np.array([r.excess for r in results])
    overhead = np.array([r.overhead_s + r.decision_s for r in results])
    violated = np.array([r.violated for r in results])
    return {
        "jobs": len(results),
        "violations": int(violated.sum()),
        "e2e_avg_s": float(e2e.mean()),
        "e2e_min_s": float(e2e.min()),
        "e2e_max_s": float(e2e.max()),
        "e2e_p99_s": float(np.percentile(e2e, 99)),
        "waiting_avg_s": float(waiting.mean()),
        "excess_avg_s": float(excess[excess > 0].mean()
                              if (excess > 0).any() else 0.0),
        "overhead_avg_s": float(overhead.mean()),
        "overhead_median_s": float(np.median(overhead)),
        "overhead_max_s": float(overhead.max()),
        "overhead_p99_s": float(np.percentile(overhead, 99)),
    }


def placement(results: Sequence[JobResult]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for r in results:
        out[r.worker] = out.get(r.worker, 0) + 1
    total = sum(out.values())
    return {w: c / total for w, c in sorted(out.items())}


def energy_by_pool(cluster: Cluster) -> Dict[str, float]:
    return {name: ws.energy_j for name, ws in cluster.workers.items()}
