"""Evaluation metrics (paper §5.1): violations, waiting, end-to-end,
excess time, tail latency, scheduling overhead, energy, placement — plus
the streaming-QoS view (TTFT/TPOT averages, tails and deadline misses),
the terminal-outcome taxonomy with goodput (docs/robustness.md), and
per-tenant breakdowns."""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.simulator import Cluster, JobResult

#: every terminal state a job can reach (JobResult.outcome refined by
#: ``outcome_of`` — served results carry ``""`` and split into
#: completed/violated by the QoS check)
OUTCOMES = ("completed", "violated", "shed", "abandoned", "failed")


def outcome_of(r: JobResult) -> str:
    """The result's place in the terminal-outcome taxonomy: a non-served
    result reports its own outcome (``shed`` / ``abandoned`` /
    ``failed``), a served one refines into ``completed`` or
    ``violated``."""
    return r.outcome if r.outcome else (
        "violated" if r.violated else "completed")


def summarize(results: Sequence[JobResult]) -> Dict[str, float]:
    # shed/abandoned/failed jobs were never served: latency statistics
    # cover the served results only (bit-identical to the historical
    # summary when every job was served)
    served = [r for r in results if not r.outcome]
    counts = {o: 0 for o in OUTCOMES}
    for r in results:
        counts[outcome_of(r)] += 1
    e2e = np.array([r.e2e for r in served] or [0.0])
    waiting = np.array([r.waiting for r in served] or [0.0])
    excess = np.array([r.excess for r in served] or [0.0])
    overhead = np.array([r.overhead_s + r.decision_s for r in served]
                        or [0.0])
    out = {
        "jobs": len(results),
        "violations": counts["violated"],
        "e2e_avg_s": float(e2e.mean()),
        "e2e_min_s": float(e2e.min()),
        "e2e_max_s": float(e2e.max()),
        "e2e_p99_s": float(np.percentile(e2e, 99)),
        "waiting_avg_s": float(waiting.mean()),
        "excess_avg_s": float(excess[excess > 0].mean()
                              if (excess > 0).any() else 0.0),
        "overhead_avg_s": float(overhead.mean()),
        "overhead_median_s": float(np.median(overhead)),
        "overhead_max_s": float(overhead.max()),
        "overhead_p99_s": float(np.percentile(overhead, 99)),
        # streaming QoS: deadline misses count even where the metric
        # itself is NaN-guarded away (a NaN never violates)
        "ttft_violations": sum(r.ttft_violated for r in served),
        "tpot_violations": sum(r.tpot_violated for r in served),
    }
    for o in OUTCOMES:
        out[o] = counts[o]
    # goodput: within-QoS completions per second of trace span — the
    # overload-control headline (shedding trades raw throughput for
    # completions that still mean something to the client)
    if results:
        span = (max(r.end for r in results)
                - min(r.job.arrival for r in results))
        out["goodput_jps"] = (counts["completed"] / span
                              if span > 0 else 0.0)
    else:
        out["goodput_jps"] = 0.0
    ttft = np.array([r.ttft for r in served] or [np.inf])
    tpot = np.array([r.tpot for r in served] or [np.inf])
    if np.isfinite(ttft).any():
        t = ttft[np.isfinite(ttft)]
        out["ttft_avg_s"] = float(t.mean())
        out["ttft_p99_s"] = float(np.percentile(t, 99))
    if np.isfinite(tpot).any():
        t = tpot[np.isfinite(tpot)]
        out["tpot_avg_s"] = float(t.mean())
        out["tpot_p99_s"] = float(np.percentile(t, 99))
    return out


def summarize_by_tenant(results: Sequence[JobResult]
                        ) -> Dict[str, Dict[str, float]]:
    """Per-traffic-class ``summarize`` keyed by ``Job.tenant`` (jobs from
    hand-built lists land under ``""``)."""
    groups: Dict[str, List[JobResult]] = {}
    for r in results:
        groups.setdefault(r.job.tenant, []).append(r)
    return {name: summarize(rs) for name, rs in sorted(groups.items())}


def placement(results: Sequence[JobResult]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for r in results:
        if not r.worker:        # shed/abandoned/failed: never placed
            continue
        out[r.worker] = out.get(r.worker, 0) + 1
    total = sum(out.values())
    return {w: c / total for w, c in sorted(out.items())}


def energy_by_pool(cluster: Cluster) -> Dict[str, float]:
    return {name: ws.energy_j for name, ws in cluster.workers.items()}
