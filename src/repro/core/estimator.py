"""Execution Time Estimator + QoS Violation Detection (paper Eq. 1-4),
vectorized over the (jobs x workers) matrix.

The numpy path is authoritative; ``repro.kernels.scheduler_score`` is the
TPU Pallas version of the same scoring used at fleet scale (J, W large), and
is validated against this module in the kernel tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.configdict import ConfigDict
from repro.core.job import Job

NEG = np.float64(np.inf)


@dataclasses.dataclass
class ScoreResult:
    workers: List[str]
    t_estimated: np.ndarray        # [J, W]  (inf where infeasible)
    t_remaining: np.ndarray        # [J]
    acceptable: np.ndarray         # [J, W] bool (Eq. 3)
    best_worker: np.ndarray        # [J] int index into workers (Eq. 4; -1 none)
    urgency: np.ndarray            # [J]  (lower == more urgent)
    doomed: np.ndarray             # [J] bool — no acceptable worker


def estimate_matrix(cd: ConfigDict, jobs: Sequence[Job], workers: List[str],
                    now: float, use_default: bool = False) -> ScoreResult:
    """Vectorized Eq. 1-4 over all queued jobs and all workers."""
    J, W = len(jobs), len(workers)
    t_est = np.full((J, W), np.inf)
    for ji, job in enumerate(jobs):
        for wi, w in enumerate(workers):
            ent = (cd.default_entry(job.engine, w) if use_default
                   else cd.optimal(job.engine, w))
            if ent is None or ent.qps <= 0:
                continue
            t_est[ji, wi] = ent.preproc_s + job.queries / ent.qps  # Eq. 2
    t_rem = np.array([j.t_qos - (now - j.arrival) for j in jobs])  # Eq. 1
    acceptable = t_rem[:, None] >= t_est                           # Eq. 3
    # Eq. 4: argmin over acceptable workers; fall back to global argmin of
    # feasible workers when nothing is acceptable (doomed jobs still run).
    masked = np.where(acceptable, t_est, np.inf)
    best = np.where(np.isfinite(masked).any(1), masked.argmin(1),
                    np.where(np.isfinite(t_est).any(1), t_est.argmin(1), -1))
    min_est = np.where(np.isfinite(t_est).any(1), np.nanmin(
        np.where(np.isfinite(t_est), t_est, np.nan), axis=1), np.inf)
    urgency = t_rem - min_est       # -> 0 means about to violate
    doomed = ~acceptable.any(axis=1)
    return ScoreResult(workers, t_est, t_rem, acceptable,
                       best.astype(np.int64), urgency, doomed)


def candidate_order(score: ScoreResult, ji: int,
                    busy_wait: Optional[np.ndarray] = None) -> List[int]:
    """Per-job worker candidates (paper: the sorted (w, c*) list).

    Non-doomed jobs only consider their *acceptable* set — if none of those
    workers are free the job waits rather than burning its QoS budget on a
    worker that cannot meet it.  Doomed jobs (nothing acceptable) minimize
    expected *completion*: candidates are ordered by (current busy wait +
    T_estimated) so a doomed job waits for a fast worker instead of seizing
    a far slower idle one and blocking it for everyone else.
    """
    t = score.t_estimated[ji]
    if score.doomed[ji]:
        cost = t + (busy_wait if busy_wait is not None else 0.0)
        order = np.argsort(cost, kind="stable")
        return [int(w) for w in order if np.isfinite(t[w])]
    order = np.argsort(t, kind="stable")
    feasible = [int(w) for w in order if np.isfinite(t[w])]
    return [w for w in feasible if score.acceptable[ji, w]]
