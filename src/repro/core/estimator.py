"""Execution Time Estimator + QoS Violation Detection (paper Eq. 1-4),
vectorized over the (jobs x workers) matrix.

The numpy path is authoritative; ``repro.kernels.scheduler_score`` is the
TPU Pallas version of the same scoring used at fleet scale (J, W large), and
is validated against this module in the kernel tests.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.configdict import ConfigDict
from repro.core.job import Job

NEG = np.float64(np.inf)

# ---------------------------------------------------------------------------
# profile overlays (online re-characterization, docs/scenarios.md)
#
# A profile overlay is a per-consumer set of *belief* corrections over the
# offline profile: per-(engine, worker) multiplicative factors on the
# profiled qps.  Overlays never touch the ConfigDict entries themselves —
# the simulator's ground-truth execution times stay exactly the offline
# characterization — they only scale the [E, W] rows the schedulers score
# with.  Profile id 0 is the pristine profile (no overlay, no extra cache
# key component, bit-for-bit the historical tables); nonzero ids are
# allocated per ``OnlineRecharacterizer`` so two policies sharing one
# ConfigDict never see each other's refreshes.

_PROFILE_IDS = itertools.count(1)


def new_profile_id() -> int:
    """A process-unique nonzero profile id (one per overlay consumer)."""
    return next(_PROFILE_IDS)


class ProfileOverlay:
    """Mutable per-(engine, worker) qps scale factors for one profile id,
    plus the generation bookkeeping score caches invalidate against:
    ``gen`` bumps once per ``apply`` and ``touched[engine]`` records the
    generation that last refreshed each engine, so a cache can reclaim
    exactly the refreshed engines' rows and nothing else."""

    def __init__(self, cd: ConfigDict, pid: int):
        self.cd = cd
        self.pid = pid
        self.gen = 0
        self.scale: Dict[str, Dict[str, float]] = {}
        self.touched: Dict[str, int] = {}

    def factors(self, engine: str, workers: Sequence[str]) -> np.ndarray:
        """[W] qps scale vector for ``engine`` over ``workers``."""
        s = self.scale.get(engine)
        if not s:
            return np.ones(len(workers))
        return np.fromiter((s.get(w, 1.0) for w in workers),
                           dtype=np.float64, count=len(workers))

    def apply(self, updates: Dict[str, Dict[str, float]]) -> int:
        """One refresh: install new scale maps for ``updates``' engines,
        bump the generation, and write the refreshed rows through every
        already-built table of this profile (region slices read through
        their parent's arrays, so they update for free).  Returns the new
        generation."""
        if not updates:
            return self.gen
        self.gen += 1
        for engine, factors in updates.items():
            self.scale[engine] = dict(factors)
            self.touched[engine] = self.gen
        for tab in self.cd.__dict__.get("_row_cache", {}).values():
            if getattr(tab, "profile", 0) == self.pid:
                for engine in updates:
                    tab._refresh_engine(engine)
        return self.gen


def profile_overlay(cd: ConfigDict, pid: int) -> ProfileOverlay:
    """The overlay for ``pid`` on ``cd`` (created on first use)."""
    overlays = cd.__dict__.setdefault("_profile_overlays", {})
    ov = overlays.get(pid)
    if ov is None:
        ov = overlays[pid] = ProfileOverlay(cd, pid)
    return ov


def profile_gen(cd: ConfigDict, pid: int) -> int:
    """Generation counter of profile ``pid`` on ``cd`` — the score-cache
    invalidation token mirroring ``Cluster.fleet_gen``/``fail_gen``.
    Always 0 for the pristine profile (id 0) and for overlays that never
    refreshed, so pristine cache keys are unchanged."""
    if not pid:
        return 0
    ov = cd.__dict__.get("_profile_overlays", {}).get(pid)
    return ov.gen if ov is not None else 0


@dataclasses.dataclass
class ScoreResult:
    workers: List[str]
    t_estimated: np.ndarray        # [J, W]  (inf where infeasible)
    t_remaining: np.ndarray        # [J]
    acceptable: np.ndarray         # [J, W] bool (Eq. 3)
    best_worker: np.ndarray        # [J] int index into workers (Eq. 4; -1 none)
    urgency: np.ndarray            # [J]  (lower == more urgent)
    doomed: np.ndarray             # [J] bool — no acceptable worker

    @classmethod
    def empty(cls, workers: Sequence[str]) -> "ScoreResult":
        """The shaped zero-job result every scoring backend shares: all
        per-job axes are length 0, the worker axis keeps its width so
        downstream matrix consumers see consistent shapes."""
        z = np.zeros((0, len(workers)))
        return cls(list(workers), z, np.zeros(0), z.astype(bool),
                   np.zeros(0, np.int64), np.zeros(0),
                   np.zeros(0, bool))


class _EngineTable:
    """Stacked per-engine (qps, preproc) rows over a fixed worker list.

    The scheduler re-scores the whole queue every tick; at fleet scale that
    makes the [J, W] matrix build the hot path.  Engine rows are profiled
    once into a dense [E, W] table, and each call gathers job rows with a
    single C-speed fancy index instead of J x W ConfigDict lookups."""

    def __init__(self, cd: ConfigDict, workers: List[str],
                 use_default: bool, profile: int = 0):
        self.cd = cd
        self.workers = list(workers)
        self.use_default = use_default
        self.profile = profile
        self.index: Dict[str, int] = {}
        self.qps = np.empty((0, len(workers)))
        self.pre = np.empty((0, len(workers)))
        self.frac = np.empty((0, len(workers)))   # decode_frac (clamped)
        self.epq = np.empty((0, len(workers)))    # joules per query (c*)

    def _profiled_row(self, engine: str):
        from repro.core.serving_bridge import decode_fraction
        W = len(self.workers)
        q = np.zeros(W)
        p = np.zeros(W)
        d = np.zeros(W)
        e = np.zeros(W)
        for wi, w in enumerate(self.workers):
            ent = (self.cd.default_entry(engine, w) if self.use_default
                   else self.cd.optimal(engine, w))
            if ent is not None and ent.qps > 0:
                q[wi] = ent.qps
                p[wi] = ent.preproc_s
                d[wi] = decode_fraction(ent)
                e[wi] = ent.energy_per_query_j
        if self.profile:
            # overlays are *throughput* beliefs; the profiled joules/query
            # stay the offline physics (mode power x query time)
            q *= profile_overlay(self.cd, self.profile).factors(
                engine, self.workers)
        return q, p, d, e

    def _add(self, engine: str):
        q, p, d, e = self._profiled_row(engine)
        self.index[engine] = len(self.qps)
        self.qps = np.vstack([self.qps, q[None]])
        self.pre = np.vstack([self.pre, p[None]])
        self.frac = np.vstack([self.frac, d[None]])
        self.epq = np.vstack([self.epq, e[None]])

    def _refresh_engine(self, engine: str):
        """Rebuild one engine's row in place from the ConfigDict and the
        current overlay factors (``ProfileOverlay.apply`` write-through;
        region slices read these arrays and see the update for free)."""
        i = self.index.get(engine)
        if i is None:
            return
        q, p, d, e = self._profiled_row(engine)
        self.qps[i] = q
        self.pre[i] = p
        self.frac[i] = d
        self.epq[i] = e

    def _rows(self, jobs: Sequence[Job]) -> np.ndarray:
        """[J] row indices into the [E, W] tables, profiling any engine
        on first sighting (shared by ``gather`` and the region-sliced
        views, which reuse these rows instead of re-profiling)."""
        idx = self.index
        try:
            return np.fromiter((idx[j.engine] for j in jobs),
                               dtype=np.intp, count=len(jobs))
        except KeyError:     # first sighting of an engine: profile it
            for job in jobs:
                if job.engine not in idx:
                    self._add(job.engine)
            return np.fromiter((idx[j.engine] for j in jobs),
                               dtype=np.intp, count=len(jobs))

    def gather(self, jobs: Sequence[Job]):
        rows = self._rows(jobs)
        return self.qps[rows], self.pre[rows], self.frac[rows]

    def gather_energy(self, jobs: Sequence[Job]) -> np.ndarray:
        """[J, W] joules/query at each worker's optimal configuration
        (0 marks infeasible pairs, matching ``qps == 0``)."""
        # bind rows first: a first-sighted engine rebinds self.epq
        rows = self._rows(jobs)
        return self.epq[rows]

    def row(self, engine: str):
        """One engine's (qps, preproc, decode_frac) rows over the worker
        list — the per-arrival gather used by SLO-MAEL's vectorized
        planner (profiles the engine on first sighting, like gather)."""
        i = self.index.get(engine)
        if i is None:
            self._add(engine)
            i = self.index[engine]
        return self.qps[i], self.pre[i], self.frac[i]

    def row_energy(self, engine: str) -> np.ndarray:
        """One engine's joules/query vector over the worker list."""
        i = self.index.get(engine)
        if i is None:
            self._add(engine)
            i = self.index[engine]
        return self.epq[i]


class _SlicedEngineTable:
    """A region's column slice of a parent ``_EngineTable``.

    Region-local scoring (``repro.core.hierarchy``) scores the same
    engines over a *subset* of the fleet's workers.  Every (engine,
    worker) cell of the parent table is profiled independently, so a
    column slice of the parent's [E, W] rows is bit-identical to a table
    profiled fresh over the region's worker list — this view shares the
    parent's rows (no re-profiling, no re-gathering) and slices with one
    fancy index per call.  Duck-typed to ``_EngineTable``'s read API."""

    def __init__(self, parent: _EngineTable, idx: np.ndarray):
        self.parent = parent
        self.idx = np.asarray(idx, dtype=np.intp)
        self.workers = [parent.workers[i] for i in self.idx]
        self.use_default = parent.use_default
        self.profile = parent.profile

    def _refresh_engine(self, engine: str):
        """No-op: slices hold no rows — they read the parent's arrays,
        which ``ProfileOverlay.apply`` already refreshed."""

    def gather(self, jobs: Sequence[Job]):
        p = self.parent
        rows = p._rows(jobs)[:, None]
        cols = self.idx
        return p.qps[rows, cols], p.pre[rows, cols], p.frac[rows, cols]

    def gather_energy(self, jobs: Sequence[Job]) -> np.ndarray:
        p = self.parent
        rows = p._rows(jobs)        # may rebind p.epq (first sighting)
        return p.epq[rows[:, None], self.idx]

    def row(self, engine: str):
        q, p, d = self.parent.row(engine)
        return q[self.idx], p[self.idx], d[self.idx]

    def row_energy(self, engine: str) -> np.ndarray:
        return self.parent.row_energy(engine)[self.idx]


# Interned worker tuples: the row cache below used to be keyed by
# ``(use_default, tuple(workers))`` — hashing a hundreds-of-strings tuple
# on every scheduler tick.  Interning maps each distinct worker tuple to a
# small int once, scoped to the ConfigDict (so the table dies with it);
# per-tick callers (``Cluster.worker_token``) hold the int and skip the
# tuple hash entirely, while one-shot callers still land on the same
# cache entry through a single interning lookup.


def intern_worker_tuple(cd: ConfigDict, workers) -> int:
    """The generation id of a worker list on ``cd``: equal lists → equal
    token (tokens from different ConfigDicts are unrelated — every cache
    keyed by them lives on the same ConfigDict)."""
    tokens = cd.__dict__.setdefault("_worker_tokens", {})
    t = tuple(workers)
    tok = tokens.get(t)
    if tok is None:
        tok = tokens[t] = len(tokens)
    return tok


def _table(cd: ConfigDict, workers: List[str], use_default: bool,
           token: Optional[int] = None, profile: int = 0) -> _EngineTable:
    """The per-(use_default, worker-tuple[, profile]) ``_EngineTable``,
    cached on the ConfigDict (one cache shared by every matrix builder
    below).  ``token`` is the pre-interned worker-tuple id
    (``intern_worker_tuple``); passing it skips re-hashing the tuple on
    the per-tick hot path.  ``profile`` selects a ``ProfileOverlay``'s
    belief-scaled tables; 0 (pristine) keeps the historical 2-tuple key,
    so pre-overlay cache entries are untouched."""
    cache = cd.__dict__.setdefault("_row_cache", {})
    tok = intern_worker_tuple(cd, workers) if token is None else token
    key = (use_default, tok) if not profile else (use_default, tok, profile)
    tab = cache.get(key)
    if tab is None:
        tab = cache[key] = _EngineTable(cd, workers, use_default, profile)
    return tab


def register_region_table(cd: ConfigDict, workers: Sequence[str],
                          region_idx, use_default: bool = False,
                          token: Optional[int] = None,
                          profile: int = 0) -> int:
    """Install a region's column-sliced view of the full-fleet row table
    under the region worker tuple's interned token, and return that
    token.  After this, every matrix builder above called with the
    region's worker list (or its token) lands on the shared slice —
    region-local scoring never re-profiles or re-gathers what the flat
    table already holds.  Safe to share the cache slot with flat callers:
    the sliced values agree bit-for-bit with a fresh region table."""
    parent = _table(cd, list(workers), use_default, token, profile)
    idx = np.asarray(region_idx, dtype=np.intp)
    rtok = intern_worker_tuple(cd, [workers[i] for i in idx])
    cache = cd.__dict__.setdefault("_row_cache", {})
    key = ((use_default, rtok) if not profile
           else (use_default, rtok, profile))
    if key not in cache:
        cache[key] = _SlicedEngineTable(parent, idx)
    return rtok


def engine_rows(cd: ConfigDict, engine: str, workers: List[str],
                use_default: bool = False, token: Optional[int] = None,
                profile: int = 0):
    """One engine's (qps, preproc, decode_frac) vectors over ``workers``
    (``qps == 0`` marks infeasible pools), from the shared row cache."""
    return _table(cd, workers, use_default, token, profile).row(engine)


def score_matrices(cd: ConfigDict, jobs: Sequence[Job], workers: List[str],
                   use_default: bool = False, token: Optional[int] = None,
                   profile: int = 0):
    """[J, W] qps / preproc matrices from the Configuration Dictionary
    (``qps == 0`` marks infeasible pairs), cached per worker tuple on the
    ConfigDict.  Shared input builder for the numpy scorer below and the
    Pallas kernel path (``repro.core.pallas_scoring``)."""
    return _table(cd, workers, use_default, token, profile).gather(jobs)[:2]


def phase_split_matrices(cd: ConfigDict, jobs: Sequence[Job],
                         workers: List[str], use_default: bool = False,
                         token: Optional[int] = None, profile: int = 0):
    """[J, W] (prefill_s, decode_s) solo-service matrices (inf where
    infeasible): the prefill prefix ``pre + (q/qps) * (1 - decode_frac)``
    — a worker's TTFT contribution — and the per-token decode remainder
    ``(q/qps) * decode_frac``.  Their sum is Eq. 2's ``t_estimated``; the
    split is what streaming-QoS gating and phase-aware placement under
    disaggregated pools score against (shares the per-worker-tuple row
    cache with ``score_matrices``)."""
    qps, pre, frac = _table(cd, workers, use_default, token,
                            profile).gather(jobs)
    q = np.fromiter((float(j.queries) for j in jobs), dtype=np.float64,
                    count=len(jobs))
    with np.errstate(divide="ignore", invalid="ignore"):
        exec_q = q[:, None] / qps
        prefill = np.where(qps > 0, pre + exec_q * (1.0 - frac), np.inf)
        decode = np.where(qps > 0, exec_q * frac, np.inf)
    return prefill, decode


def energy_matrix(cd: ConfigDict, jobs: Sequence[Job], workers: List[str],
                  use_default: bool = False, token: Optional[int] = None,
                  profile: int = 0) -> np.ndarray:
    """[J, W] estimated whole-job joules: ``queries x joules/query`` at
    each worker's profiled optimal configuration, ``inf`` where the pair
    is infeasible (mirroring Eq. 2's inf cells, so the energy term never
    resurrects an infeasible placement).  This is the row source behind
    ``SynergAI(energy_weight=...)``'s weighted energy/carbon term; shares
    the per-worker-tuple row cache with ``score_matrices``."""
    epq = _table(cd, workers, use_default, token, profile).gather_energy(jobs)
    q = np.fromiter((float(j.queries) for j in jobs), dtype=np.float64,
                    count=len(jobs))
    return np.where(epq > 0, q[:, None] * epq, np.inf)


def estimate_matrix(cd: ConfigDict, jobs: Sequence[Job], workers: List[str],
                    now: float, use_default: bool = False,
                    token: Optional[int] = None,
                    profile: int = 0) -> ScoreResult:
    """Vectorized Eq. 1-4 over all queued jobs and all workers."""
    J = len(jobs)
    if not J:
        return ScoreResult.empty(workers)
    qps, pre = score_matrices(cd, jobs, workers, use_default, token,
                              profile)
    q = np.fromiter((float(j.queries) for j in jobs), dtype=np.float64,
                    count=J)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_est = np.where(qps > 0, pre + q[:, None] / qps, np.inf)  # Eq. 2
    t_rem = np.fromiter((j.t_qos - (now - j.arrival) for j in jobs),
                        dtype=np.float64, count=J)                 # Eq. 1
    acceptable = t_rem[:, None] >= t_est                           # Eq. 3
    # Eq. 4: argmin over acceptable workers; fall back to global argmin of
    # feasible workers when nothing is acceptable (doomed jobs still run).
    masked = np.where(acceptable, t_est, np.inf)
    min_est = t_est.min(axis=1)     # inf where nothing is feasible
    best = np.where(np.isfinite(masked.min(axis=1)), masked.argmin(1),
                    np.where(np.isfinite(min_est), t_est.argmin(1), -1))
    urgency = t_rem - min_est       # -> 0 means about to violate
    doomed = ~acceptable.any(axis=1)
    return ScoreResult(workers, t_est, t_rem, acceptable,
                       best.astype(np.int64), urgency, doomed)


# score_fn protocol markers: SynergAI forwards the cluster's interned
# worker token — and, when a recharacterizer is attached, the profile
# overlay id — to backends that advertise support for them
estimate_matrix.takes_token = True
estimate_matrix.takes_profile = True


def candidate_order(score: ScoreResult, ji: int,
                    busy_wait: Optional[np.ndarray] = None) -> List[int]:
    """Per-job worker candidates (paper: the sorted (w, c*) list).

    Non-doomed jobs only consider their *acceptable* set — if none of those
    workers are free the job waits rather than burning its QoS budget on a
    worker that cannot meet it.  Doomed jobs (nothing acceptable) minimize
    expected *completion*: candidates are ordered by (current busy wait +
    T_estimated) so a doomed job waits for a fast worker instead of seizing
    a far slower idle one and blocking it for everyone else.
    """
    t = score.t_estimated[ji]
    if score.doomed[ji]:
        cost = t + (busy_wait if busy_wait is not None else 0.0)
        order = np.argsort(cost, kind="stable")
        return [int(w) for w in order if np.isfinite(t[w])]
    order = np.argsort(t, kind="stable")
    feasible = [int(w) for w in order if np.isfinite(t[w])]
    return [w for w in feasible if score.acceptable[ji, w]]
