"""The Configuration Dictionary — output of the offline phase (paper §4.1,
block 1E).  For every (engine, worker) it stores the optimal configuration
c*_{j,w} (max QPS), the profiled pre-processing time, and the full DSE table
for the characterization benchmarks."""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class Entry:
    engine: str
    worker: str
    mode: str
    chips_per_replica: int
    qps: float
    query_time_s: float
    preproc_s: float
    power_w: float
    energy_per_query_j: float
    bottleneck: str
    # fraction of query_time_s spent in the per-token decode phase (vs the
    # prefill phase) — the serving bridge splits exec_time into token rates
    # with it.  Defaulted so ConfigDicts serialized before the field existed
    # still load.
    decode_frac: float = 0.85
    # static/idle power floor of the slice at this mode (W) — what a busy
    # worker burns during WAN-transfer seconds and an idle worker burns
    # while parked.  Defaulted for the same serialization reason.
    idle_power_w: float = 0.0


class ConfigDict:
    def __init__(self):
        self.best: Dict[str, Dict[str, Entry]] = {}       # engine -> worker -> c*
        self.default: Dict[str, Dict[str, Entry]] = {}    # default-config perf
        self.table: list[Entry] = []                      # full DSE table

    def add(self, entry: Entry, is_best=False, is_default=False):
        self.table.append(entry)
        if is_best:
            self.best.setdefault(entry.engine, {})[entry.worker] = entry
        if is_default:
            self.default.setdefault(entry.engine, {})[entry.worker] = entry

    def optimal(self, engine: str, worker: str) -> Optional[Entry]:
        # elastic clones are named "<pool>__<n>" and share the pool profile
        return self.best.get(engine, {}).get(worker.split("__")[0])

    def default_entry(self, engine: str, worker: str) -> Optional[Entry]:
        return self.default.get(engine, {}).get(worker.split("__")[0])

    def workers_for(self, engine: str) -> list[str]:
        return sorted(self.best.get(engine, {}),
                      key=lambda w: -self.best[engine][w].qps)

    # ---- persistence -------------------------------------------------------
    def to_json(self, path: str):
        blob = {
            "best": {e: {w: dataclasses.asdict(ent) for w, ent in ws.items()}
                     for e, ws in self.best.items()},
            "default": {e: {w: dataclasses.asdict(ent)
                            for w, ent in ws.items()}
                        for e, ws in self.default.items()},
            "table": [dataclasses.asdict(e) for e in self.table],
        }
        with open(path, "w") as f:
            json.dump(blob, f, indent=1)

    @classmethod
    def from_json(cls, path: str) -> "ConfigDict":
        with open(path) as f:
            blob = json.load(f)
        cd = cls()
        cd.table = [Entry(**e) for e in blob["table"]]
        cd.best = {e: {w: Entry(**ent) for w, ent in ws.items()}
                   for e, ws in blob["best"].items()}
        cd.default = {e: {w: Entry(**ent) for w, ent in ws.items()}
                      for e, ws in blob["default"].items()}
        return cd
