"""AdamW with warmup-cosine schedule, built from scratch (no optax here)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, opt_state["step"])
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gn}
