"""Fault-tolerant checkpointing: atomic save, restore, resume discovery.

Pure numpy .npz snapshots of the flattened train-state pytree with a JSON
treedef manifest; writes are crash-safe (tmp file + atomic rename) and old
checkpoints are garbage-collected.  This is the checkpoint/restart leg of the
fault-tolerance story (the scheduler-level failure handling lives in
``repro.core.simulator``).
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, state: Any, keep: int = 3) -> str:
    """Atomically write ``ckpt_<step>.npz``; prune to the newest ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten_with_paths(state)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        final = os.path.join(ckpt_dir, f"ckpt_{step}.npz")
        os.replace(tmp, final)  # atomic on POSIX
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    manifest = {"latest_step": step}
    mtmp = os.path.join(ckpt_dir, "manifest.json.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, "manifest.json"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        try:
            os.unlink(os.path.join(ckpt_dir, f"ckpt_{s}.npz"))
        except OSError:
            pass


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    with np.load(os.path.join(ckpt_dir, f"ckpt_{step}.npz")) as data:
        arrays = dict(data)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
