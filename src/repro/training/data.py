"""Synthetic-but-learnable token pipeline (no external datasets offline).

Produces deterministic, seeded batches with Zipf-distributed unigrams plus a
copy/induction structure (so a real LM can actually reduce loss on it), with
background prefetch — a realistic stand-in for a production input pipeline.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Order-2 Markov-ish stream: next token = f(prev) with noise."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab)
        ranks = np.arange(1, vocab + 1)
        p = 1.0 / ranks ** 1.1
        self.unigram = p / p.sum()
        self.rng = np.random.default_rng(seed + 1)

    def sample(self, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = self.rng.choice(self.vocab, size=batch, p=self.unigram)
        noise = self.rng.random((batch, seq))
        fresh = self.rng.choice(self.vocab, size=(batch, seq),
                                p=self.unigram)
        for t in range(seq):
            det = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.8, det, fresh[:, t])
        return toks


class DataLoader:
    """Background-thread prefetching loader yielding {tokens, labels}."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 prefetch: int = 2, extra_fn=None):
        self.gen = SyntheticLM(vocab, seed)
        self.batch, self.seq = batch, seq
        self.extra_fn = extra_fn
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self):
        toks = self.gen.sample(self.batch, self.seq)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.extra_fn is not None:
            out.update(self.extra_fn(self.batch, self.seq))
        return out

    def _worker(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._make(), timeout=0.2)
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
