"""The train step lowered by the dry-run and driven by the train launcher."""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def init_train_state(model: Model, key, opt_cfg: AdamWConfig | None = None):
    params = model.init_params(key)
    return {"params": params, "opt": init_opt_state(params)}


def make_train_step(model: Model, opt_cfg: AdamWConfig | None = None,
                    grad_shardings=None, accum_steps: int = 1):
    """``grad_shardings``: optional NamedSharding pytree matching params.
    Constraining the grads forces GSPMD to reduce-scatter them straight
    into the (ZeRO) optimizer sharding instead of materializing replicated
    gradients (ZeRO-2).

    ``accum_steps > 1``: microbatched gradient accumulation (scan over
    microbatches) — the v5e recipe for models whose activations don't fit
    at the full global batch (deepseek-236B).  Grads accumulate in f32 in
    the ZeRO sharding.
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def constrain(grads):
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        return grads

    def grad_of(params, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        return loss, constrain(grads)

    def train_step(state, batch):
        params = state["params"]
        if accum_steps == 1:
            loss, grads = grad_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((accum_steps, a.shape[0] // accum_steps)
                                    + a.shape[1:])
                if a.ndim >= 1 and a.shape and a.shape[0] % accum_steps == 0
                else jnp.broadcast_to(a, (accum_steps,) + a.shape), batch)

            def acc_step(carry, mb):
                loss_sum, gacc = carry
                loss, grads = grad_of(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (loss_sum + loss, constrain(gacc)), None

            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, gacc), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss_sum / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, gacc)
        params, opt, metrics = adamw_update(opt_cfg, params, grads,
                                            state["opt"])
        metrics = dict(metrics, loss=loss)
        return {"params": params, "opt": opt}, metrics

    return train_step
