"""Batched inference engine: prefill + decode loop over the Model API.

This is the per-replica execution engine that SynergAI schedules.  One
``InferenceEngine`` corresponds to one deployed "inference engine" in the
paper's terminology: (architecture x serving configuration) on one worker.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.serving import sampling
from repro.serving.kvcache import cache_bytes, pad_cache


@dataclasses.dataclass
class EngineStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    batches: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0


class InferenceEngine:
    """Greedy/stochastic batched generation with a persistent KV cache."""

    def __init__(self, model: Model, params, max_len: int = 256,
                 sampler: Callable = sampling.greedy, donate: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.sampler = sampler
        self.stats = EngineStats()
        self._prefill = jax.jit(model.prefill)
        # donate the cache buffers across steps
        self._decode = jax.jit(model.decode,
                               donate_argnums=(1,) if donate else ())

    def generate(self, batch: dict, n_tokens: int, key=None):
        """batch: model input_specs-shaped dict with real arrays.

        Returns tokens [B, n_tokens].
        """
        B = (batch.get("tokens") if "tokens" in batch
             else batch["token"]).shape[0]
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, batch)
        prompt_len = batch["tokens"].shape[1]
        ctx_len = (batch["audio_embeds"].shape[1]
                   if "audio_embeds" in batch else None)
        template = self.model.init_cache(B, self.max_len, ctx_len)
        caches = pad_cache(caches, template)
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        self.stats.prefill_tokens += B * prompt_len

        t0 = time.perf_counter()
        outs = []
        key = key if key is not None else jax.random.PRNGKey(0)
        tok = self.sampler(logits, key)
        for i in range(n_tokens):
            outs.append(tok)
            if i == n_tokens - 1:
                break
            step = {"token": tok[:, None],
                    "pos": jnp.int32(prompt_len + i)}
            logits, caches = self._decode(self.params, caches, step)
            key, sub = jax.random.split(key)
            tok = self.sampler(logits, sub)
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.decoded_tokens += B * n_tokens
        self.stats.batches += 1
        return jnp.stack(outs, axis=1)

    def cache_footprint(self, B: int) -> int:
        shapes = jax.eval_shape(lambda: self.model.init_cache(B, self.max_len))
        import numpy as np
        return int(sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(shapes)))
