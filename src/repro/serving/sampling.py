"""Token sampling policies for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, key=None):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits, key, temp=1.0):
    return jax.random.categorical(key, logits / jnp.maximum(temp, 1e-6),
                                  axis=-1).astype(jnp.int32)


def top_k(logits, key, k=40, temp=1.0):
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals / jnp.maximum(temp, 1e-6),
                                    axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0]
