"""Cache utilities: buffer extension, size accounting."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pad_cache(caches, template):
    """Embed prefill-produced caches into decode-sized buffers.

    ``template`` comes from ``model.init_cache(B, buf_len)``.  Leaves whose
    shapes already match (ring buffers, recurrent states, cross-attn caches)
    are kept; sequence buffers are written into the zeroed template at
    offset 0.
    """

    def one(c, t):
        if c is None:
            return t
        if c.shape == t.shape:
            return c.astype(t.dtype)
        assert len(c.shape) == len(t.shape), (c.shape, t.shape)
        start = (0,) * c.ndim
        return jax.lax.dynamic_update_slice(t, c.astype(t.dtype), start)

    return jax.tree.map(one, caches, template,
                        is_leaf=lambda x: x is None)


def cache_bytes(caches) -> int:
    leaves = jax.tree.leaves(caches)
    return int(sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves))
